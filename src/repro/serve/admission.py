"""Admission control, typed serving errors, and serving telemetry.

The overload-hardening layer of `QueryServer` (docs/architecture.md §10):

  * `AdmissionController` — a bounded pending-request budget with
    per-tenant fairness.  Requests carry an optional `tenant` and
    `priority`; a request past the budget (or past its tenant's fair
    share) is rejected with a typed `Overloaded` error *at submit time*
    instead of queueing unboundedly.  Priority > 0 requests bypass the
    tenant cap and may dip into a reserved headroom above the budget, so
    a latency-critical tenant still gets through a burst of bulk traffic.
  * typed errors — `Overloaded` (admission rejection), `DeadlineExceeded`
    (a request's deadline passed before its group executed), and
    `TransientError` (the retryable fault class: the server's bounded
    retry-with-backoff only replays a group whose failure is transient,
    mirroring `runtime/fault_tolerance.py`'s restore-and-replay idiom).
  * `RateEMA` — exponentially weighted arrival-interval tracker (the
    `StragglerStats` idiom pointed at arrivals instead of step times);
    drives the adaptive coalescing window.
  * `LatencyHistogram` — log2-bucketed latency histogram with p50/p99
    readout, embedded in `ServerStats`.
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Optional


class Overloaded(RuntimeError):
    """Admission rejected the request: the server's pending budget (or
    this tenant's fair share of it) is exhausted."""

    def __init__(self, message: str, *, tenant: Optional[str] = None,
                 reason: str = "budget"):
        super().__init__(message)
        self.tenant = tenant
        self.reason = reason        # 'budget' | 'fairness'


class DeadlineExceeded(RuntimeError):
    """The request's deadline passed before its group executed."""


class TransientError(RuntimeError):
    """A fault the server may retry: the failed group is replayed once
    (with backoff) against the same compiled entry — restore-and-replay,
    like `TrainDriver`'s checkpoint recovery, but the 'checkpoint' is the
    window's request list, which execution never mutates."""


@dataclasses.dataclass
class RateEMA:
    """EMA of inter-arrival times (`StragglerStats.observe` pointed at
    arrivals): `interval()` is the smoothed gap between requests, from
    which the server derives its coalescing-window length."""
    alpha: float = 0.1
    ema: float = 0.0
    count: int = 0
    last: Optional[float] = None

    def observe(self, now: float) -> None:
        if self.last is None:
            self.last = now
            return
        dt = max(now - self.last, 1e-9)
        self.last = now
        self.ema = dt if self.count == 0 \
            else (1.0 - self.alpha) * self.ema + self.alpha * dt
        self.count += 1

    def interval(self) -> Optional[float]:
        return self.ema if self.count else None

    def rate(self) -> float:
        """Smoothed arrivals per second (0.0 until two arrivals seen)."""
        return 1.0 / self.ema if self.count else 0.0


@dataclasses.dataclass
class LatencyHistogram:
    """Log2-bucketed latency histogram: bucket i covers
    [2^i, 2^(i+1)) microseconds, so p50/p99 readouts carry at most one
    octave of quantization error — plenty for an overload dashboard, and
    O(1) memory regardless of traffic."""
    counts: list = dataclasses.field(default_factory=lambda: [0] * 32)
    count: int = 0
    total_s: float = 0.0

    def observe(self, seconds: float) -> None:
        us = max(seconds * 1e6, 1.0)
        i = min(int(math.log2(us)), len(self.counts) - 1)
        self.counts[i] += 1
        self.count += 1
        self.total_s += seconds

    def quantile(self, q: float) -> float:
        """Approximate quantile in seconds (geometric bucket midpoint)."""
        if self.count == 0:
            return 0.0
        target = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= target:
                return (2.0 ** (i + 0.5)) * 1e-6
        return (2.0 ** len(self.counts)) * 1e-6

    def p50(self) -> float:
        return self.quantile(0.50)

    def p99(self) -> float:
        return self.quantile(0.99)

    def mean(self) -> float:
        return self.total_s / self.count if self.count else 0.0


class AdmissionController:
    """Bounded pending budget with per-tenant fairness and priorities.

    Contract (docs §10):

      * at most `budget` requests may be pending (admitted, future not yet
        resolved) at once; request `budget + 1` is rejected with
        `Overloaded(reason='budget')`;
      * a named tenant may hold at most `ceil(tenant_frac * budget)`
        pending slots, so one bulk tenant cannot starve the others even
        below the global budget — excess is rejected with
        `Overloaded(reason='fairness')`.  Anonymous requests
        (`tenant=None`) are exempt from the per-tenant cap and bounded
        only by the global budget;
      * `priority > 0` requests bypass the tenant cap and may use a
        reserved `headroom` above the budget (default budget/4), so
        latency-critical traffic is the last to be shed.

    Thread-safe: `admit`/`release` take an internal lock (releases run on
    future done-callbacks, i.e. arbitrary threads).
    """

    def __init__(self, budget: int = 256, tenant_frac: float = 0.5,
                 headroom: Optional[int] = None):
        if budget < 1:
            raise ValueError(f"budget must be >= 1 (got {budget})")
        self.budget = budget
        self.tenant_cap = max(1, math.ceil(tenant_frac * budget))
        self.headroom = budget // 4 if headroom is None else headroom
        self._lock = threading.Lock()
        self._pending = 0
        self._per_tenant: dict[Optional[str], int] = {}

    def admit(self, tenant: Optional[str] = None, priority: int = 0) -> int:
        """Claim one pending slot (returns the pre-admission pending
        count) or raise `Overloaded`.  Callers MUST pair every successful
        admit with exactly one `release` — the server wires it to the
        request future's done-callback, which fires on every resolution
        path (result, error, rejection at close)."""
        with self._lock:
            limit = self.budget + (self.headroom if priority > 0 else 0)
            if self._pending >= limit:
                raise Overloaded(
                    f"pending budget exhausted ({self._pending} >= {limit})",
                    tenant=tenant, reason="budget")
            if tenant is not None and priority <= 0 and \
                    self._per_tenant.get(tenant, 0) >= self.tenant_cap:
                raise Overloaded(
                    f"tenant {tenant!r} at its fair share "
                    f"({self.tenant_cap} of {self.budget})",
                    tenant=tenant, reason="fairness")
            before = self._pending
            self._pending += 1
            self._per_tenant[tenant] = self._per_tenant.get(tenant, 0) + 1
            return before

    def release(self, tenant: Optional[str] = None) -> None:
        with self._lock:
            self._pending = max(self._pending - 1, 0)
            n = self._per_tenant.get(tenant, 0) - 1
            if n <= 0:
                self._per_tenant.pop(tenant, None)
            else:
                self._per_tenant[tenant] = n

    def load(self) -> float:
        """Current pending fraction of the budget (>= 1.0 = saturated).
        The degradation ladder keys its rungs off this value."""
        with self._lock:
            return self._pending / self.budget

    def pending(self) -> int:
        with self._lock:
            return self._pending
