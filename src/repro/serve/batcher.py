"""Continuous-batching request server (slot-based, MaxText/vLLM style).

A fixed pool of B slots shares one KV cache; each slot holds an
independent request at its own position.  Admission fills free slots from
the queue (prefill writes that slot's cache region), and every engine tick
decodes one token for all live slots in a single batched `decode_step`.
Completed slots free immediately — no head-of-line blocking on long
generations.

The engine is deliberately synchronous/deterministic (tick-driven) so it
can be tested exhaustively on CPU; a production front-end wraps `tick()`
in an event loop.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.sharding import Ctx
from repro.models.transformer import decode_step, init_cache


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (P,) int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, ctx: Ctx, *, slots: int,
                 max_len: int, stop_token: Optional[int] = None):
        self.params = params
        self.cfg = cfg
        self.ctx = ctx
        self.slots = slots
        self.max_len = max_len
        self.stop_token = stop_token
        self.cache = init_cache(cfg, slots, max_len,
                                s_enc=8 if cfg.encoder_layers else 0)
        self.slot_req: list[Optional[Request]] = [None] * slots
        self.slot_pos = np.zeros(slots, dtype=np.int32)
        self.slot_limit = np.zeros(slots, dtype=np.int32)
        self.queue: list[Request] = []
        self._decode = jax.jit(
            lambda p, t, c, pos: decode_step(p, t, c, pos, cfg, ctx))
        self.ticks = 0

    # -- client API -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for s in range(self.slots):
            if self.slot_req[s] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            # per-slot prefill: feed prompt tokens through decode_step one at
            # a time into this slot's cache region (simple, correct; batched
            # chunk-prefill is the production fast path).
            for i, tok in enumerate(req.prompt):
                toks = np.zeros(self.slots, dtype=np.int32)
                toks[s] = tok
                logits, self.cache = self._decode(
                    self.params, jnp.asarray(toks), self.cache,
                    jnp.int32(i))
            self.slot_req[s] = req
            self.slot_pos[s] = len(req.prompt)
            self.slot_limit[s] = len(req.prompt) + req.max_new
            nxt = int(np.argmax(np.asarray(logits)[s]))
            req.out.append(nxt)

    # -- engine tick ------------------------------------------------------------
    def tick(self) -> int:
        """Admit + decode one token for all live slots.  Returns #live."""
        self._admit()
        live = [s for s in range(self.slots) if self.slot_req[s] is not None]
        if not live:
            return 0
        toks = np.zeros(self.slots, dtype=np.int32)
        for s in live:
            toks[s] = self.slot_req[s].out[-1]
        pos = int(self.slot_pos[live[0]])   # homogeneous-pos simplification
        logits, self.cache = self._decode(self.params, jnp.asarray(toks),
                                          self.cache, jnp.int32(pos))
        logits = np.asarray(logits)
        for s in live:
            req = self.slot_req[s]
            nxt = int(np.argmax(logits[s]))
            req.out.append(nxt)
            self.slot_pos[s] += 1
            if (self.slot_pos[s] >= self.slot_limit[s]
                    or nxt == self.stop_token
                    or self.slot_pos[s] >= self.max_len - 1):
                req.done = True
                self.slot_req[s] = None
        self.ticks += 1
        return len(live)

    def run_until_drained(self, max_ticks: int = 10_000) -> None:
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and self.ticks < max_ticks:
            self.tick()
