"""Deterministic chaos harness for `QueryServer`.

Fault injection rides the server's constructor hooks — `compile_hook`
(called by the owning group just before a cold compile) and `exec_hook`
(called once per execution attempt just before the vmapped dispatch) —
so the server under test is the production class, not a fork.  The
schedule is precomputed from a seed: event i of each hook either fires
or not by table lookup, so a failing tier-1 run replays exactly from its
seed (modulo thread interleaving, which may reorder *which group* draws
event i but never the event stream itself).

Three fault families:

  * compile faults (`ChaosCompileFault`, non-transient) — the owning
    group's compilation raises, exercising the in-flight-dedup recovery
    path (a parked waiter becomes the new owner) and error accounting;
  * transient execution faults (`TransientError`) — injected only on
    attempt 0, so the server's bounded retry always lands: a retried
    transient fault MUST succeed, which the harness asserts;
  * slow executions — a sleep before dispatch, standing in for a
    straggling device, to shake out deadline and close() races.

`run_chaos` is the closed-loop harness: it drives a seeded mixed
workload (two plan shapes × several runtime bindings × rotating
tenants) through a chaos-hooked server, optionally closes mid-window,
and returns a report with the invariants tier-1 asserts — every future
resolved, retried transients succeeded, `ServerStats` balances exactly,
and zero result drift vs the Volcano oracle.
"""
from __future__ import annotations

import threading
import time
from typing import Optional

import numpy as np

from repro.serve.admission import DeadlineExceeded, Overloaded, TransientError


class ChaosCompileFault(RuntimeError):
    """Injected compile failure (non-transient: the group fails, the next
    group for the key re-owns the compilation)."""


class ChaosSchedule:
    """Seeded fault schedule over hook-call indices.

    `compile_fails` / `exec_faults` / `slows` are sets of call indices
    (per hook, counted independently) at which the fault fires.  Build
    one explicitly for guaranteed-injection tests, or via `seeded()` for
    rate-driven schedules that replay exactly from the seed.
    """

    def __init__(self, *, compile_fails=(), exec_faults=(), slows=(),
                 slow_s: float = 0.01):
        self.compile_fails = frozenset(compile_fails)
        self.exec_faults = frozenset(exec_faults)
        self.slows = frozenset(slows)
        self.slow_s = slow_s
        self.injected = {"compile_fail": 0, "exec_fault": 0, "slow": 0}
        self._lock = threading.Lock()
        self._compile_calls = 0
        self._exec_calls = 0

    @classmethod
    def seeded(cls, seed: int, *, n_events: int = 64,
               compile_fail_rate: float = 0.25, exec_fault_rate: float = 0.2,
               slow_rate: float = 0.2, slow_s: float = 0.01) -> "ChaosSchedule":
        """Draw per-index fault tables from one seed.  Same seed, same
        schedule — the replay property the tier-1 chaos test relies on."""
        rng = np.random.default_rng(seed)
        compile_fails = set(np.flatnonzero(
            rng.random(n_events) < compile_fail_rate).tolist())
        draws = rng.random(n_events)
        exec_faults = set(np.flatnonzero(draws < exec_fault_rate).tolist())
        slows = set(np.flatnonzero(
            (draws >= exec_fault_rate)
            & (draws < exec_fault_rate + slow_rate)).tolist())
        return cls(compile_fails=compile_fails, exec_faults=exec_faults,
                   slows=slows, slow_s=slow_s)

    # -- the two server hooks -------------------------------------------------
    def compile_hook(self, key) -> None:
        with self._lock:
            i = self._compile_calls
            self._compile_calls += 1
            fail = i in self.compile_fails
            if fail:
                self.injected["compile_fail"] += 1
        if fail:
            raise ChaosCompileFault(f"chaos: compile fault at call {i}")

    def exec_hook(self, key, attempt: int) -> None:
        if attempt > 0:
            # retries are never re-injected: the faults are *transient*
            # by construction, so "retried transient faults succeed" is a
            # property the harness can assert deterministically.
            return
        with self._lock:
            i = self._exec_calls
            self._exec_calls += 1
            fault = i in self.exec_faults
            slow = i in self.slows
            if fault:
                self.injected["exec_fault"] += 1
            elif slow:
                self.injected["slow"] += 1
        if fault:
            raise TransientError(f"chaos: transient execution fault "
                                 f"at call {i}")
        if slow:
            time.sleep(self.slow_s)


def run_chaos(db, settings=None, *, seed: int = 0, n_requests: int = 48,
              schedule: Optional[ChaosSchedule] = None,
              close_mid_window: bool = True, check_oracle: bool = True,
              budget: int = 64, max_batch: int = 4, window_s: float = 0.002,
              close_timeout_s: float = 30.0, **server_kw) -> dict:
    """Drive a seeded mixed workload through a chaos-hooked server and
    report the resolution/accounting invariants.

    Returns a dict with the schedule's injected-fault counts, the final
    `ServerStats`, per-outcome future counts, `all_resolved`,
    `balanced` (submitted == completed + errors + rejected + cancelled +
    grace_expired, exactly), and `oracle_drift` (completed results that
    differ from the Volcano oracle under the same bindings — must be 0).
    """
    from repro.core import VolcanoEngine, preset
    from repro.relational.queries import PARAM_ALT_BINDINGS, PARAM_QUERIES
    from repro.serve.query_server import QueryServer

    settings = settings or preset("opt")
    sched = schedule or ChaosSchedule.seeded(seed)
    rng = np.random.default_rng(seed + 1)

    # two plan shapes x a few runtime bindings each: enough key diversity
    # to exercise coalescing, dedup, and degraded-plan entries at once
    shapes = []
    for qname in ("q6", "q3"):
        build, defaults = PARAM_QUERIES[qname]
        alt = dict(defaults, **PARAM_ALT_BINDINGS[qname])
        shapes.append((qname, build, [defaults, alt]))

    srv = QueryServer(db, settings,
                      compile_hook=sched.compile_hook,
                      exec_hook=sched.exec_hook,
                      max_batch=max_batch, window_s=window_s,
                      budget=budget, close_timeout_s=close_timeout_s,
                      **server_kw)
    tenants = ["alpha", "beta", "gamma", None]
    requests = []   # (future, qname, bindings) for resolved-future audit
    rejected_inline = 0
    for i in range(n_requests):
        qname, build, bindings_pool = shapes[int(rng.integers(len(shapes)))]
        bindings = bindings_pool[int(rng.integers(len(bindings_pool)))]
        tenant = tenants[i % len(tenants)]
        priority = 1 if i % 7 == 0 else 0
        try:
            fut = srv.submit(build(), bindings, tenant=tenant,
                             priority=priority)
            requests.append((fut, qname, bindings))
        except Overloaded:
            rejected_inline += 1
        if i % 5 == 4:
            time.sleep(window_s / 2)   # let some windows tick naturally
    if close_mid_window:
        srv.close()     # windows may still be open: the mid-window race
    else:
        srv.drain()
        srv.close()

    outcomes = {"completed": 0, "transient": 0, "compile_fault": 0,
                "deadline": 0, "closed": 0, "other_error": 0}
    unresolved = 0
    oracle_drift = 0
    oracle = VolcanoEngine(db) if check_oracle else None
    expected: dict[tuple, dict] = {}
    for fut, qname, bindings in requests:
        if not fut.done():
            unresolved += 1
            continue
        exc = fut.exception()
        if exc is None:
            outcomes["completed"] += 1
            if oracle is not None:
                okey = (qname, tuple(sorted(bindings.items())))
                if okey not in expected:
                    build = PARAM_QUERIES[qname][0]
                    expected[okey] = oracle.execute(build(), bindings)
                want, got = expected[okey], fut.result()
                same = set(got) == set(want) and all(
                    np.allclose(np.asarray(got[c], dtype=np.float64),
                                np.asarray(want[c], dtype=np.float64),
                                rtol=1e-4, atol=1e-4)
                    for c in got)
                if not same:
                    oracle_drift += 1
        elif isinstance(exc, TransientError):
            outcomes["transient"] += 1
        elif isinstance(exc, ChaosCompileFault):
            outcomes["compile_fault"] += 1
        elif isinstance(exc, DeadlineExceeded):
            outcomes["deadline"] += 1
        elif "closed" in str(exc):
            outcomes["closed"] += 1
        else:
            outcomes["other_error"] += 1

    st = srv.stats
    balanced = (st.submitted == st.completed + st.errors + st.rejected
                + st.cancelled + st.grace_expired)
    return {
        "injected": dict(sched.injected),
        "stats": st,
        "outcomes": outcomes,
        "rejected_inline": rejected_inline,
        "all_resolved": unresolved == 0,
        "balanced": balanced,
        "oracle_drift": oracle_drift,
        # retry accounting: every injected transient exec fault triggers
        # exactly one retry (injection never fires on attempt > 0), and a
        # retried group must succeed — so no future may carry a
        # TransientError.
        "retried_ok": (st.retries == sched.injected["exec_fault"]
                       and outcomes["transient"] == 0),
    }
