"""Concurrent parameterized query server over the plan cache.

The analytics twin of `batcher.py`'s serving engine: requests arrive
concurrently, each naming a plan + parameter bindings; execution goes
through a shared `PlanCache` so only the first request for a plan shape
pays staging + XLA JIT, and *in-flight* compilations are deduplicated — a
request arriving while another request is already compiling the same key
parks on that compilation instead of starting a second one.

Execution is *coalesced*, mirroring `batcher.py`'s tick discipline:
requests arriving within one window (`window_s`) that share a plan key
are grouped into a single batch, executed as ONE vmapped XLA dispatch
(`CompiledQuery.run_many` via `PlanCache.run_many`), and their results
scattered back to the per-request futures.  A window flushes when it
fills (`max_batch`), when its deadline expires (the flusher thread's
tick), or when `flush()`/`drain()` forces it — `drain` flushes partial
windows, so no request can hang because traffic stopped mid-tick.

Two driving styles:

  * `submit()` returns a `concurrent.futures.Future`; the flusher groups
    and a thread pool overlaps compilations and batch executions.
  * `serve_batch()` submits a list of requests, flushes, and collects in
    order — the deterministic form the tests exercise.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import (Future, InvalidStateError,
                                ThreadPoolExecutor, wait)
from typing import Callable, Optional

from repro.core import ir
from repro.core.passes.pipeline import Settings, preset
from repro.core.plan_cache import PlanCache


@dataclasses.dataclass
class ServerStats:
    submitted: int = 0
    completed: int = 0
    errors: int = 0
    shared_compiles: int = 0   # groups that parked on an in-flight compile
    batches: int = 0           # dispatched groups (including singletons)
    coalesced: int = 0         # requests that shared a vmapped dispatch
    # adaptive capacity feedback, passed through from the shared
    # PlanCache after each group (re-plans from observed overflows,
    # shrinks from sustained underuse — see CacheStats)
    replans: int = 0
    shrinks: int = 0


@dataclasses.dataclass
class _Window:
    """One coalescing window: all pending requests for one plan key."""
    plan: ir.Plan                    # prepared (structurally bound) plan
    owned: bool                      # plan is a private copy
    deadline: float                  # monotonic flush time
    entries: list = dataclasses.field(default_factory=list)  # (runtime, fut)


class QueryServer:
    def __init__(self, db, settings: Optional[Settings] = None, *,
                 cache: Optional[PlanCache] = None, max_workers: int = 4,
                 compile_hook: Optional[Callable] = None,
                 window_s: float = 0.0025, max_batch: int = 64):
        self.db = db
        self.settings = settings or preset("opt")
        self.cache = cache or PlanCache(db)
        self.stats = ServerStats()
        self.compile_hook = compile_hook   # test seam: called pre-compile
        self.window_s = window_s
        self.max_batch = max_batch
        self._pool = ThreadPoolExecutor(max_workers=max_workers,
                                        thread_name_prefix="query-server")
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._windows: dict[tuple, _Window] = {}
        self._inflight: dict[tuple, threading.Event] = {}
        self._futures: list[Future] = []
        self._closed = False
        self._flusher = threading.Thread(target=self._flush_loop,
                                         name="query-server-flusher",
                                         daemon=True)
        self._flusher.start()

    # -- client API -----------------------------------------------------------
    def submit(self, plan: ir.Plan, bindings: Optional[dict] = None,
               mode: str = "residual") -> Future:
        if self._closed:
            raise RuntimeError("server is closed")
        # one canonicalization per request: compile-time params are baked
        # into the plan here, so the key both dedups compilation and
        # partitions the coalescing windows by plan structure.
        key, prepared, runtime, owned = self.cache._prepare(
            plan, self.settings, bindings, mode)
        fut: Future = Future()
        full = None
        with self._cv:
            if self._closed:   # re-check under the lock: close() races us
                raise RuntimeError("server is closed")
            self.stats.submitted += 1
            # completed futures (and their pinned results) don't accumulate
            self._futures = [f for f in self._futures if not f.done()]
            self._futures.append(fut)
            w = self._windows.get(key)
            if w is None:
                w = _Window(prepared, owned,
                            time.monotonic() + self.window_s)
                self._windows[key] = w
            w.entries.append((runtime, fut))
            if len(w.entries) >= self.max_batch:
                full = self._windows.pop(key)
            else:
                self._cv.notify()
        if full is not None:
            self._dispatch(key, full)
        return fut

    def serve_batch(self, requests) -> list:
        """Submit (plan, bindings) pairs together, flush, drain in order."""
        futs = [self.submit(plan, bindings) for plan, bindings in requests]
        self.flush()
        return [f.result() for f in futs]

    def flush(self) -> None:
        """Dispatch every open window now, full or not (a forced tick)."""
        with self._cv:
            popped = list(self._windows.items())
            self._windows.clear()
        for key, w in popped:
            self._dispatch(key, w)

    def drain(self) -> None:
        """Flush partial windows and wait for every outstanding request —
        traffic stopping mid-tick must never leave a future hanging."""
        self.flush()
        with self._cv:
            pending = list(self._futures)
        # wait() tolerates cancelled futures, unlike f.exception(); request
        # errors stay parked on the futures for their owners to observe.
        wait(pending)
        with self._cv:
            self._futures = [f for f in self._futures if not f.done()]

    def close(self) -> None:
        """Close the server: no new submissions, then settle every
        outstanding request — flush pending windows, wait for their
        futures, and *fail* anything that still hasn't resolved.  A
        future returned by `submit()` must never stay pending after
        `close()` returns, no matter how the shutdown races an open
        window (e.g. one popped by the flusher but not yet dispatched
        when the pool goes down)."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        self.flush()
        with self._cv:
            pending = list(self._futures)
        # bounded, unlike drain(): a window dropped by a shutdown race
        # must not park close() forever — anything still unresolved after
        # the grace period is failed below instead of waited on
        wait(pending, timeout=60)
        self._pool.shutdown(wait=True)
        self._flusher.join(timeout=5)
        # belt and suspenders: a window that slipped past drain (popped
        # after the final flush) or a future the pool never ran would
        # otherwise hang its owner forever — resolve them with an error.
        with self._cv:
            leftovers = list(self._windows.values())
            self._windows.clear()
            unresolved = [f for f in self._futures if not f.done()]
            self._futures = []
        exc = RuntimeError("server closed with the request unresolved")
        for w in leftovers:
            with self._lock:
                self.stats.errors += len(w.entries)
            self._fail_window(w, exc)
        for f in unresolved:
            try:
                if f.set_running_or_notify_cancel():
                    f.set_exception(exc)
            except (InvalidStateError, RuntimeError):
                pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- coalescing tick ------------------------------------------------------
    def _flush_loop(self):
        """Flusher thread: dispatch each window when its deadline passes
        (the tick), sleeping until the next deadline otherwise."""
        while True:
            popped = []
            with self._cv:
                if self._closed and not self._windows:
                    return
                now = time.monotonic()
                due = [k for k, w in self._windows.items()
                       if w.deadline <= now]
                for k in due:
                    popped.append((k, self._windows.pop(k)))
                if not popped:
                    nxt = min((w.deadline for w in self._windows.values()),
                              default=None)
                    self._cv.wait(None if nxt is None
                                  else max(0.0, nxt - now))
                    continue
            for key, w in popped:
                self._dispatch(key, w)

    def _dispatch(self, key: tuple, window: _Window) -> None:
        try:
            self._pool.submit(self._run_group, key, window)
        except RuntimeError as e:
            # pool already shut down (a submit raced close()): fail the
            # window's requests instead of stranding their futures — and
            # never let the exception kill the flusher thread.
            with self._lock:
                self.stats.errors += len(window.entries)
            self._fail_window(window, e)

    @staticmethod
    def _complete(fut: Future, result) -> None:
        """Finish one request future under the executor state protocol.

        These futures are created by `submit()`, not by an executor, so a
        client `cancel()` leaves them in CANCELLED — a state
        `concurrent.futures.wait` does NOT count as complete until
        `set_running_or_notify_cancel()` advances it to
        CANCELLED_AND_NOTIFIED.  Skipping that call deadlocks `drain()`
        on any cancelled request."""
        if fut.set_running_or_notify_cancel():
            fut.set_result(result)

    @staticmethod
    def _fail_window(window: _Window, exc: BaseException) -> None:
        for _, fut in window.entries:
            # same atomic claim as _complete: a cancel() racing a plain
            # done()/cancelled() check could make set_exception raise and
            # strand the rest of the window
            try:
                if fut.set_running_or_notify_cancel():
                    fut.set_exception(exc)
            except (InvalidStateError, RuntimeError):
                # already finished or notified: nothing to deliver (CPython
                # raises plain RuntimeError for that state, not
                # InvalidStateError)
                pass

    # -- group execution ------------------------------------------------------
    def _run_group(self, key, window: _Window):
        try:
            # dedup loop: parked groups re-enter after the owner finishes,
            # so if the owner's compilation *failed* (cache still cold) one
            # waiter becomes the new owner instead of every waiter
            # compiling at once.
            first_runtime = window.entries[0][0]
            cq = None
            while cq is None:
                owner, event = False, None
                with self._lock:
                    event = self._inflight.get(key)
                    if event is None and not self.cache.contains(key):
                        event = threading.Event()
                        self._inflight[key] = event
                        owner = True
                    elif event is not None:
                        self.stats.shared_compiles += 1
                if owner:
                    try:
                        if self.compile_hook is not None:
                            self.compile_hook(key)
                        cq = self.cache._get_prepared(
                            key, window.plan, first_runtime, window.owned,
                            self.settings)
                    finally:
                        with self._lock:
                            self._inflight.pop(key, None)
                        event.set()
                elif event is not None:
                    event.wait()   # then re-check: hit, or take ownership
                else:
                    cq = self.cache._get_prepared(
                        key, window.plan, first_runtime, window.owned,
                        self.settings)
            runtimes = [r for r, _ in window.entries]
            if len(runtimes) == 1:
                results = [cq.run(runtimes[0])]
                self.cache._note_compaction(cq, 1)
            else:
                # one vmapped XLA dispatch for the whole group
                results = self.cache.run_many(cq, runtimes)
            with self._lock:
                self.stats.completed += len(results)
                self.stats.batches += 1
                if len(results) > 1:
                    self.stats.coalesced += len(results)
                self.stats.replans = self.cache.stats.replans
                self.stats.shrinks = self.cache.stats.shrinks
            for (_, fut), res in zip(window.entries, results):
                # a client may have cancelled its future while the window
                # was pending; that must not poison the rest of the group
                self._complete(fut, res)
        except BaseException as e:
            with self._lock:
                self.stats.errors += len(window.entries)
            self._fail_window(window, e)
