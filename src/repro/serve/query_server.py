"""Concurrent parameterized query server over the plan cache.

The analytics twin of `batcher.py`'s serving engine: requests arrive
concurrently, each naming a plan + parameter bindings; execution goes
through a shared `PlanCache` so only the first request for a plan shape
pays staging + XLA JIT, and *in-flight* compilations are deduplicated — a
request arriving while another request is already compiling the same key
parks on that compilation instead of starting a second one, then executes
through the (now warm) cache.

Two driving styles, mirroring `batcher.py`'s tick discipline:

  * `submit()` returns a `concurrent.futures.Future`; a thread pool
    overlaps compilations and executions (bind+run of distinct compiled
    queries is embarrassingly parallel on CPU).
  * `serve_batch()` submits a list of requests and drains — the
    deterministic form the tests exercise.
"""
from __future__ import annotations

import dataclasses
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Optional

from repro.core import ir
from repro.core.passes.pipeline import Settings, preset
from repro.core.plan_cache import PlanCache


@dataclasses.dataclass
class ServerStats:
    submitted: int = 0
    completed: int = 0
    errors: int = 0
    shared_compiles: int = 0   # requests that parked on an in-flight compile


class QueryServer:
    def __init__(self, db, settings: Optional[Settings] = None, *,
                 cache: Optional[PlanCache] = None, max_workers: int = 4,
                 compile_hook: Optional[Callable] = None):
        self.db = db
        self.settings = settings or preset("opt")
        self.cache = cache or PlanCache(db)
        self.stats = ServerStats()
        self.compile_hook = compile_hook   # test seam: called pre-compile
        self._pool = ThreadPoolExecutor(max_workers=max_workers,
                                        thread_name_prefix="query-server")
        self._lock = threading.Lock()
        self._inflight: dict[tuple, threading.Event] = {}
        self._futures: list[Future] = []
        self._closed = False

    # -- client API -----------------------------------------------------------
    def submit(self, plan: ir.Plan, bindings: Optional[dict] = None,
               mode: str = "residual") -> Future:
        if self._closed:
            raise RuntimeError("server is closed")
        fut = self._pool.submit(self._handle, plan, bindings, mode)
        with self._lock:
            self.stats.submitted += 1
            # completed futures (and their pinned results) don't accumulate
            self._futures = [f for f in self._futures if not f.done()]
            self._futures.append(fut)
        return fut

    def serve_batch(self, requests) -> list:
        """Submit (plan, bindings) pairs together and drain in order."""
        futs = [self.submit(plan, bindings) for plan, bindings in requests]
        return [f.result() for f in futs]

    def drain(self) -> None:
        with self._lock:
            pending = list(self._futures)
        for f in pending:
            f.exception()   # wait; errors surface via the future
        with self._lock:
            self._futures = [f for f in self._futures if not f.done()]

    def close(self) -> None:
        self._closed = True
        self._pool.shutdown(wait=True)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- request path ---------------------------------------------------------
    def _handle(self, plan, bindings, mode):
        try:
            # one canonicalization per request: the (key, plan, runtime)
            # triple feeds dedup, compile, and execute below.
            key, prepared, runtime, owned = self.cache._prepare(
                plan, self.settings, bindings, mode)
            # dedup loop: parked requests re-enter after the owner finishes,
            # so if the owner's compilation *failed* (cache still cold) one
            # waiter becomes the new owner instead of every waiter compiling
            # at once.
            cq = None
            while cq is None:
                owner, event = False, None
                with self._lock:
                    event = self._inflight.get(key)
                    if event is None and not self.cache.contains(key):
                        event = threading.Event()
                        self._inflight[key] = event
                        owner = True
                    elif event is not None:
                        self.stats.shared_compiles += 1
                if owner:
                    try:
                        if self.compile_hook is not None:
                            self.compile_hook(key)
                        cq = self.cache._get_prepared(key, prepared, runtime,
                                                      owned, self.settings)
                    finally:
                        with self._lock:
                            self._inflight.pop(key, None)
                        event.set()
                elif event is not None:
                    event.wait()   # then re-check: hit, or take ownership
                else:
                    cq = self.cache._get_prepared(key, prepared, runtime,
                                                  owned, self.settings)
            result = cq.run(runtime)
            with self._lock:
                self.stats.completed += 1
            return result
        except BaseException:
            with self._lock:
                self.stats.errors += 1
            raise
