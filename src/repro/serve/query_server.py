"""Concurrent parameterized query server over the plan cache.

The analytics twin of `batcher.py`'s serving engine: requests arrive
concurrently, each naming a plan + parameter bindings; execution goes
through a shared `PlanCache` so only the first request for a plan shape
pays staging + XLA JIT, and *in-flight* compilations are deduplicated — a
request arriving while another request is already compiling the same key
parks on that compilation instead of starting a second one.

Execution is *coalesced*, mirroring `batcher.py`'s tick discipline:
requests arriving within one window that share a plan key are grouped
into a single batch, executed as ONE vmapped XLA dispatch
(`CompiledQuery.run_many` via `PlanCache.run_many`), and their results
scattered back to the per-request futures.  A window flushes when it
fills (`max_batch`), when its deadline expires (the flusher thread's
tick), or when `flush()`/`drain()` forces it — `drain` flushes partial
windows, so no request can hang because traffic stopped mid-tick.  The
window length adapts to the observed arrival rate (an EMA of
inter-arrival gaps, the `StragglerStats` idiom): sparse traffic widens
the window to coalesce more, dense traffic narrows it toward the time a
full batch takes to arrive.

Overload hardening (docs/architecture.md §10):

  * admission control — a bounded pending budget with per-tenant
    fairness and priorities (`serve/admission.py`); a request past the
    budget raises a typed `Overloaded` at submit time instead of
    queueing unboundedly;
  * per-request deadlines — `submit(..., timeout_s=)`; a request whose
    deadline passes before its group executes fails with
    `DeadlineExceeded` (counted in `deadline_misses`) without poisoning
    the rest of the group;
  * bounded retry — a group whose execution raises a `TransientError`
    is replayed up to `max_retries` times with exponential backoff
    against the same compiled entry (restore-and-replay, mirroring
    `runtime/fault_tolerance.py`; the window's request list is the
    checkpoint and execution never mutates it);
  * a degradation ladder keyed off the admission load, expressed as
    *tier demotion* over the same `core.tiering.TierLadder` the plan
    cache promotes along (docs §11): first shed to smaller coalescing
    buckets (lower latency, less batching), then demote the execution
    tier to the ladder's interpret rung (mask-only settings — same
    results, no compaction machinery, a distinct cheaper plan-cache
    entry), and only then reject;
  * chaos seams — `compile_hook(key)` fires in the owning group just
    before a cold compile, `exec_hook(key, attempt)` before every
    execution attempt; `serve/chaos.py` drives both from a seeded
    schedule.

Tiered serving (opt-in, `tiered=True`; docs §11): a cold plan shape is
served immediately from the best *ready* execution tier — the Volcano
oracle on request 1 — while the cache's background promoter compiles the
target tier and hot-swaps it in; no request ever blocks on XLA
compilation.  `warm_state_path` persists the compaction feedback store
and warm metadata on `close()` and restores them at construction, so a
restarted server answers request 1 at the pre-restart converged
capacities (pair with `persist.enable_compilation_cache` to also reuse
the XLA executables themselves).

Two driving styles:

  * `submit()` returns a `concurrent.futures.Future`; the flusher groups
    and a thread pool overlaps compilations and batch executions.
  * `serve_batch()` submits a list of requests, flushes, and collects in
    order — the deterministic form the tests exercise.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import (Future, InvalidStateError,
                                ThreadPoolExecutor, wait)
from typing import Callable, Optional

from repro.core import ir, tiering
from repro.core.passes.pipeline import Settings, preset
from repro.core.plan_cache import PlanCache
from repro.serve.admission import (AdmissionController, DeadlineExceeded,
                                   LatencyHistogram, Overloaded, RateEMA,
                                   TransientError)

_UNSET = object()


@dataclasses.dataclass
class ServerStats:
    submitted: int = 0         # every submit() that passed the closed check
    completed: int = 0         # futures delivered a result
    errors: int = 0            # futures delivered an exception (incl.
    #                            deadline misses; NOT grace expiries)
    rejected: int = 0          # admission rejections (typed Overloaded)
    cancelled: int = 0         # futures the client cancelled while pending
    grace_expired: int = 0     # futures failed because close()'s grace
    #                            period ran out (kept out of `errors` so
    #                            shutdown debt is visible on its own)
    shared_compiles: int = 0   # groups that parked on an in-flight compile
    batches: int = 0           # dispatched groups (including singletons)
    coalesced: int = 0         # requests that shared a vmapped dispatch
    # degradation ladder + fault handling
    shed_batch: int = 0        # requests served under shrunken windows
    shed_plan: int = 0         # requests served via degraded mask-only plans
    retries: int = 0           # group replays after a TransientError
    deadline_misses: int = 0   # requests failed with DeadlineExceeded
    # tiered serving: dispatched groups by the execution tier that
    # actually served them (empty unless tiered=True)
    tier_served: dict = dataclasses.field(default_factory=dict)
    # adaptive capacity feedback, passed through from the shared
    # PlanCache after each group (re-plans from observed overflows,
    # shrinks from sustained underuse — see CacheStats)
    replans: int = 0
    shrinks: int = 0
    # completion latency (submit -> result) of successful requests
    latency: LatencyHistogram = dataclasses.field(
        default_factory=LatencyHistogram)

    def outstanding(self) -> int:
        """Requests admitted but not yet resolved.  Zero once the server
        is closed: every submitted request ends in exactly one of
        completed / errors / rejected / cancelled / grace_expired."""
        return (self.submitted - self.completed - self.errors
                - self.rejected - self.cancelled - self.grace_expired)


@dataclasses.dataclass
class _Entry:
    """One admitted request inside a window."""
    runtime: dict                    # runtime bindings
    fut: Future
    deadline: Optional[float]        # monotonic; None = no deadline
    tenant: Optional[str]
    t_submit: float                  # monotonic submit time (latency)


@dataclasses.dataclass
class _Window:
    """One coalescing window: all pending requests for one plan key."""
    plan: ir.Plan                    # prepared (structurally bound) plan
    owned: bool                      # plan is a private copy
    deadline: float                  # monotonic flush time
    settings: Settings               # full or degraded (ladder rung 2)
    max_batch: int                   # full or shrunken (ladder rung 1)
    entries: list = dataclasses.field(default_factory=list)  # [_Entry]


class QueryServer:
    def __init__(self, db, settings: Optional[Settings] = None, *,
                 cache: Optional[PlanCache] = None, max_workers: int = 4,
                 compile_hook: Optional[Callable] = None,
                 exec_hook: Optional[Callable] = None,
                 window_s: float = 0.0025, max_batch: int = 64,
                 adaptive_window: bool = True,
                 budget: int = 256, tenant_frac: float = 0.5,
                 priority_headroom: Optional[int] = None,
                 degradation: bool = True,
                 shed_batch_load: float = 0.5, shed_plan_load: float = 0.75,
                 default_timeout_s: Optional[float] = None,
                 max_retries: int = 1, retry_backoff_s: float = 0.02,
                 close_timeout_s: float = 60.0,
                 tiered: bool = False,
                 warm_state_path: Optional[str] = None):
        self.db = db
        self.settings = settings or preset("opt")
        self.tiered = tiered
        self.warm_state_path = warm_state_path
        self.cache = cache or PlanCache(db, tiered=tiered)
        self.stats = ServerStats()
        self.compile_hook = compile_hook   # chaos seam: pre-cold-compile
        self.exec_hook = exec_hook         # chaos seam: pre-execution
        self.window_s = window_s
        self.max_batch = max_batch
        self.adaptive_window = adaptive_window
        self.admission = AdmissionController(budget, tenant_frac,
                                             priority_headroom)
        self.degradation = degradation
        self.shed_batch_load = shed_batch_load
        self.shed_plan_load = shed_plan_load
        self.default_timeout_s = default_timeout_s
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        self.close_timeout_s = close_timeout_s
        # the SAME ladder object the plan cache promotes along: overload
        # demotes the serving tier one rung below the target (the
        # interpret/mask-only rung for compiled targets), so degradation
        # and promotion are two directions over one abstraction.
        self.ladder = tiering.TierLadder(self.settings)
        if self.ladder.target.rank > tiering.INTERPRET.rank:
            self._degraded_settings = \
                self.ladder.settings_for(tiering.INTERPRET)
        else:
            # interpret-or-lower target: there is no cheaper tier worth
            # demoting to, rung 2 degenerates to the base settings
            self._degraded_settings = self.settings
        if warm_state_path is not None:
            self.cache.load(warm_state_path)
        self._arrivals = RateEMA()
        self._pool = ThreadPoolExecutor(max_workers=max_workers,
                                        thread_name_prefix="query-server")
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._windows: dict[tuple, _Window] = {}
        self._inflight: dict[tuple, threading.Event] = {}
        self._futures: list[Future] = []
        self._closed = False
        self._flusher = threading.Thread(target=self._flush_loop,
                                         name="query-server-flusher",
                                         daemon=True)
        self._flusher.start()

    # -- client API -----------------------------------------------------------
    def submit(self, plan: ir.Plan, bindings: Optional[dict] = None,
               mode: str = "residual", *, tenant: Optional[str] = None,
               priority: int = 0, timeout_s=_UNSET) -> Future:
        if self._closed:
            raise RuntimeError("server is closed")
        now = time.monotonic()
        timeout = self.default_timeout_s if timeout_s is _UNSET else timeout_s
        deadline = None if timeout is None else now + timeout
        # degradation rung from the load *before* this request admits —
        # it decides the settings, which decide the plan key, so it must
        # be read before _prepare (a concurrent submit may shift the load
        # by one; the rungs are heuristics, not invariants).
        level = self._level()
        settings = self._degraded_settings if level >= 2 else self.settings
        # one canonicalization per request: compile-time params are baked
        # into the plan here, so the key both dedups compilation and
        # partitions the coalescing windows by plan structure.  Binding
        # errors (missing params) raise here, before any accounting.
        key, prepared, runtime, owned = self.cache._prepare(
            plan, settings, bindings, mode)
        fut: Future = Future()
        entry = _Entry(runtime, fut, deadline, tenant, now)
        full = None
        with self._cv:
            if self._closed:   # re-check under the lock: close() races us
                raise RuntimeError("server is closed")
            self.stats.submitted += 1
            self._arrivals.observe(now)
            try:
                self.admission.admit(tenant, priority)
            except Overloaded:
                self.stats.rejected += 1
                raise
            if level >= 2:
                self.stats.shed_plan += 1
            elif level >= 1:
                self.stats.shed_batch += 1
            # completed futures (and their pinned results) don't accumulate
            self._futures = [f for f in self._futures if not f.done()]
            self._futures.append(fut)
            w = self._windows.get(key)
            if w is None:
                w = _Window(prepared, owned, now + self._window_len(level),
                            settings, self._batch_cap(level))
                self._windows[key] = w
            w.entries.append(entry)
            if len(w.entries) >= w.max_batch:
                full = self._windows.pop(key)
            else:
                self._cv.notify()
        # the admission slot frees on ANY resolution (result, error,
        # cancel, close); successful completions also feed the latency
        # histogram here, since every resolution path runs the callbacks
        fut.add_done_callback(self._release_cb(tenant, now))
        if level >= 2:
            self.cache.note_degraded()
        if full is not None:
            self._dispatch(key, full)
        return fut

    def serve_batch(self, requests) -> list:
        """Submit (plan, bindings) pairs together, flush, drain in order."""
        futs = [self.submit(plan, bindings) for plan, bindings in requests]
        self.flush()
        return [f.result() for f in futs]

    def flush(self) -> None:
        """Dispatch every open window now, full or not (a forced tick)."""
        with self._cv:
            popped = list(self._windows.items())
            self._windows.clear()
        for key, w in popped:
            self._dispatch(key, w)

    def prewarm(self, requests) -> int:
        """Eagerly warm the cache for (plan, bindings) shapes a previous
        process knew to be hot (restored via `warm_state_path`); returns
        the number of shapes warmed.  Tiered servers kick the background
        promoter and return immediately; non-tiered servers compile
        synchronously.  Shapes with no warm hint are skipped — prewarm
        never compiles speculatively."""
        n = 0
        for plan, bindings in requests:
            if not self.cache.is_warm(plan, self.settings, bindings):
                continue
            if self.tiered:
                self.cache.get_tiered(plan, self.settings, bindings)
            else:
                self.cache.get(plan, self.settings, bindings)
            n += 1
        return n

    def drain(self) -> None:
        """Flush partial windows and wait for every outstanding request —
        traffic stopping mid-tick must never leave a future hanging."""
        self.flush()
        with self._cv:
            pending = list(self._futures)
        # wait() tolerates cancelled futures, unlike f.exception(); request
        # errors stay parked on the futures for their owners to observe.
        wait(pending)
        with self._cv:
            self._futures = [f for f in self._futures if not f.done()]

    def close(self) -> None:
        """Close the server: no new submissions, then settle every
        outstanding request — flush pending windows, wait up to
        `close_timeout_s` for their futures, and *fail* anything that
        still hasn't resolved.  A future returned by `submit()` must
        never stay pending after `close()` returns, no matter how the
        shutdown races an open window (e.g. one popped by the flusher but
        not yet dispatched when the pool goes down).  Requests failed
        because the grace period ran out are counted in
        `stats.grace_expired`, not folded into `errors`."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        self.flush()
        with self._cv:
            pending = list(self._futures)
        # bounded, unlike drain(): a stuck worker (or a window dropped by
        # a shutdown race) must not park close() forever — anything still
        # unresolved after the grace period is failed below instead of
        # waited on.
        wait(pending, timeout=self.close_timeout_s)
        expired = [f for f in pending if not f.done()]
        if expired:
            graced = cancelled = 0
            exc = RuntimeError("request unresolved after the close() "
                               f"grace period ({self.close_timeout_s}s)")
            for f in expired:
                st = self._settle(f, exc=exc)
                if st == "done":
                    graced += 1
                elif st == "cancelled":
                    cancelled += 1
            with self._lock:
                self.stats.grace_expired += graced
                self.stats.cancelled += cancelled
            # don't wait for whatever wedged those futures: a stuck
            # worker settling one of them later hits the already-resolved
            # guard and counts nothing
            self._pool.shutdown(wait=False)
        else:
            self._pool.shutdown(wait=True)
        self._flusher.join(timeout=5)
        # belt and suspenders: a window that slipped past the final flush
        # (popped by the flusher after it, or created by a racing submit)
        # would otherwise hang its owner forever — resolve it with an
        # error.
        with self._cv:
            leftovers = list(self._windows.values())
            self._windows.clear()
        exc = RuntimeError("server closed with the request unresolved")
        for w in leftovers:
            n = self._settle_entries(w.entries, exc)
            with self._lock:
                self.stats.errors += n
        with self._cv:
            unresolved = [f for f in self._futures if not f.done()]
            self._futures = []
        for f in unresolved:
            if self._settle(f, exc=exc) == "done":
                with self._lock:
                    self.stats.grace_expired += 1
        # persist warm state last, after every group has executed and fed
        # the compaction feedback store; a failed save must not turn a
        # clean shutdown into a crash (next start is simply cold).
        if self.warm_state_path is not None:
            try:
                self.cache.save(self.warm_state_path)
            except OSError:
                pass
        self.cache.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- adaptive window + degradation ladder ---------------------------------
    def _level(self) -> int:
        """Current degradation rung: 0 = full fidelity, 1 = shrunken
        coalescing buckets, 2 = degraded mask-only plans.  Rung 3
        (reject) lives in the admission controller itself."""
        if not self.degradation:
            return 0
        load = self.admission.load()
        if load >= self.shed_plan_load:
            return 2
        if load >= self.shed_batch_load:
            return 1
        return 0

    def _window_len(self, level: int) -> float:
        """Coalescing window for a new window opened now: the EMA of
        inter-arrival gaps scaled to the time a full batch takes to
        arrive, clamped to [window_s/8, window_s*4]; under overload
        (rung >= 1) quartered again — smaller buckets drain the queue in
        more, smaller dispatches."""
        w = self.window_s
        if self.adaptive_window:
            iv = self._arrivals.interval()
            if iv is not None:
                w = min(max(iv * self.max_batch, self.window_s / 8),
                        self.window_s * 4)
        if level >= 1:
            w /= 4
        return w

    def _batch_cap(self, level: int) -> int:
        return self.max_batch if level < 1 else max(1, self.max_batch // 4)

    def _release_cb(self, tenant: Optional[str], t_submit: float):
        def _done(f: Future) -> None:
            self.admission.release(tenant)
            if not f.cancelled() and f.exception() is None:
                dt = time.monotonic() - t_submit
                with self._lock:
                    self.stats.latency.observe(dt)
        return _done

    # -- coalescing tick ------------------------------------------------------
    def _flush_loop(self):
        """Flusher thread: dispatch each window when its deadline passes
        (the tick), sleeping until the next deadline otherwise."""
        while True:
            popped = []
            with self._cv:
                if self._closed and not self._windows:
                    return
                now = time.monotonic()
                due = [k for k, w in self._windows.items()
                       if w.deadline <= now]
                for k in due:
                    popped.append((k, self._windows.pop(k)))
                if not popped:
                    nxt = min((w.deadline for w in self._windows.values()),
                              default=None)
                    self._cv.wait(None if nxt is None
                                  else max(0.0, nxt - now))
                    continue
            for key, w in popped:
                self._dispatch(key, w)

    def _dispatch(self, key: tuple, window: _Window) -> None:
        try:
            self._pool.submit(self._run_group, key, window)
        except RuntimeError as e:
            # pool already shut down (a submit raced close()): fail the
            # window's requests instead of stranding their futures — and
            # never let the exception kill the flusher thread.
            n = self._settle_entries(window.entries, e)
            with self._lock:
                self.stats.errors += n

    # -- future settlement ----------------------------------------------------
    @staticmethod
    def _settle(fut: Future, result=None, exc=None) -> str:
        """Resolve one request future under the executor state protocol;
        returns 'done' (delivered), 'cancelled', or 'stale'.

        These futures are created by `submit()`, not by an executor, so a
        client `cancel()` leaves them in CANCELLED — a state
        `concurrent.futures.wait` does NOT count as complete until
        `set_running_or_notify_cancel()` advances it to
        CANCELLED_AND_NOTIFIED.  Skipping that call deadlocks `drain()`
        on any cancelled request.  'stale' covers a future some other
        path already resolved (e.g. a grace-expired future a late worker
        finally reached — CPython raises a plain RuntimeError for that
        state, not InvalidStateError)."""
        try:
            if fut.set_running_or_notify_cancel():
                if exc is not None:
                    fut.set_exception(exc)
                else:
                    fut.set_result(result)
                return "done"
            return "cancelled"
        except (InvalidStateError, RuntimeError):
            return "stale"

    def _settle_entries(self, entries: list, exc: BaseException) -> int:
        """Fail every entry's future; returns the number actually
        delivered (cancelled ones are counted in stats here, stale ones
        were already accounted by whoever resolved them)."""
        delivered = cancelled = 0
        for e in entries:
            st = self._settle(e.fut, exc=exc)
            if st == "done":
                delivered += 1
            elif st == "cancelled":
                cancelled += 1
        if cancelled:
            with self._lock:
                self.stats.cancelled += cancelled
        return delivered

    def _expire(self, entries: list) -> list:
        """Split off entries whose deadline already passed and fail them
        with DeadlineExceeded; returns the still-live entries.  An
        expired request costs its own future, never the group's."""
        now = time.monotonic()
        live = [e for e in entries
                if e.deadline is None or e.deadline > now]
        if len(live) == len(entries):
            return entries
        dead = [e for e in entries
                if not (e.deadline is None or e.deadline > now)]
        n = self._settle_entries(
            dead, DeadlineExceeded(
                "deadline passed before the request's group executed"))
        with self._lock:
            self.stats.deadline_misses += n
            self.stats.errors += n
        return live

    # -- group execution ------------------------------------------------------
    def _resolve_compiled(self, key, window: _Window, runtime: dict):
        """Compile-or-hit with in-flight dedup: parked groups re-enter
        after the owner finishes, so if the owner's compilation *failed*
        (cache still cold) one waiter becomes the new owner instead of
        every waiter compiling at once."""
        while True:
            owner, event = False, None
            with self._lock:
                event = self._inflight.get(key)
                if event is None and not self.cache.contains(key):
                    event = threading.Event()
                    self._inflight[key] = event
                    owner = True
                elif event is not None:
                    self.stats.shared_compiles += 1
            if owner:
                try:
                    if self.compile_hook is not None:
                        self.compile_hook(key)
                    return self.cache._get_prepared(
                        key, window.plan, runtime, window.owned,
                        window.settings)
                finally:
                    with self._lock:
                        self._inflight.pop(key, None)
                    event.set()
            elif event is not None:
                event.wait()   # then re-check: hit, or take ownership
            else:
                return self.cache._get_prepared(
                    key, window.plan, runtime, window.owned,
                    window.settings)

    def _run_group(self, key, window: _Window):
        entries = self._expire(window.entries)
        if not entries:
            return
        attempt = 0
        while True:
            try:
                if self.tiered:
                    # never block a request on XLA compilation: serve the
                    # best READY tier now, promotion happens off-thread
                    # (retries naturally pick up a freshly promoted tier)
                    cq = self.cache._get_tiered_prepared(
                        key, window.plan, entries[0].runtime, window.owned,
                        window.settings, compile_hook=self.compile_hook)[0]
                    with self._lock:
                        self.stats.tier_served[cq.tier_name] = \
                            self.stats.tier_served.get(cq.tier_name, 0) + 1
                else:
                    cq = self._resolve_compiled(key, window,
                                                entries[0].runtime)
                if self.exec_hook is not None:
                    self.exec_hook(key, attempt)
                runtimes = [e.runtime for e in entries]
                if len(runtimes) == 1:
                    results = [cq.run(runtimes[0])]
                    self.cache._note_compaction(cq, 1)
                else:
                    # one vmapped XLA dispatch for the whole group
                    results = self.cache.run_many(cq, runtimes)
                break
            except BaseException as e:
                if attempt < self.max_retries \
                        and isinstance(e, TransientError):
                    # bounded restore-and-replay (fault_tolerance.py's
                    # idiom): the window's request list is the checkpoint
                    # — execution never mutates it — so the replay is the
                    # same group minus anything whose deadline passed
                    # while we backed off.
                    with self._lock:
                        self.stats.retries += 1
                    time.sleep(self.retry_backoff_s * (2 ** attempt))
                    attempt += 1
                    entries = self._expire(entries)
                    if not entries:
                        return
                    continue
                n = self._settle_entries(entries, e)
                with self._lock:
                    self.stats.errors += n
                return
        delivered = cancelled = 0
        for e, res in zip(entries, results):
            # a client may have cancelled its future while the window
            # was pending; that must not poison the rest of the group
            st = self._settle(e.fut, result=res)
            if st == "done":
                delivered += 1
            elif st == "cancelled":
                cancelled += 1
        with self._lock:
            self.stats.completed += delivered
            self.stats.cancelled += cancelled
            self.stats.batches += 1
            if len(results) > 1:
                self.stats.coalesced += len(results)
            self.stats.replans = self.cache.stats.replans
            self.stats.shrinks = self.cache.stats.shrinks
