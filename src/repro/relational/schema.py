"""Relational schema metadata.

Column kinds:
  INT     — int32 scalar column (keys, quantities, sizes)
  FLOAT   — float32 scalar column (prices, discounts)
  DATE    — int32 days-since-1970 (TPC-H dates parse into this)
  CAT     — categorical string: stored as int32 dictionary codes with a
            small vocabulary (e.g. L_SHIPMODE).  The *unoptimized* engine
            configurations materialize a fixed-width uint8 char matrix and
            do strcmp-style byte comparisons; the StringDictionary pass
            keeps the int codes (paper §3.4).
  TEXT    — multi-word string: stored as an (nrows, max_words) int32 word-
            code matrix (word-tokenizing dictionary, paper §3.4 / Q13).

Primary/foreign keys are declared at schema definition time — the paper's
partitioning optimization (§3.2.1) is driven from exactly this annotation.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional


class ColKind(enum.Enum):
    INT = "int"
    FLOAT = "float"
    DATE = "date"
    CAT = "cat"
    TEXT = "text"


@dataclasses.dataclass(frozen=True)
class ColumnDef:
    name: str
    kind: ColKind
    # For CAT columns: declared max width of the char representation.
    char_width: int = 0
    # For TEXT columns: max number of words per row.
    max_words: int = 0


@dataclasses.dataclass(frozen=True)
class ForeignKey:
    column: str
    ref_table: str
    ref_column: str


@dataclasses.dataclass
class TableSchema:
    name: str
    columns: list[ColumnDef]
    primary_key: tuple[str, ...] = ()
    foreign_keys: tuple[ForeignKey, ...] = ()

    def __post_init__(self) -> None:
        self._by_name = {c.name: c for c in self.columns}

    def col(self, name: str) -> ColumnDef:
        return self._by_name[name]

    def has_col(self, name: str) -> bool:
        return name in self._by_name

    @property
    def column_names(self) -> list[str]:
        return [c.name for c in self.columns]

    def fk_for(self, column: str) -> Optional[ForeignKey]:
        for fk in self.foreign_keys:
            if fk.column == column:
                return fk
        return None


def days(date_str: str) -> int:
    """Parse 'YYYY-MM-DD' into int days since 1970-01-01 (host-side)."""
    import numpy as np

    return int(np.datetime64(date_str, "D").astype(np.int64))


# ---------------------------------------------------------------------------
# TPC-H schema (the attribute subset exercised by our query plans, plus a
# few extras so column pruning has something to prune).
# ---------------------------------------------------------------------------

def _c(name: str, kind: ColKind, **kw) -> ColumnDef:
    return ColumnDef(name, kind, **kw)


TPCH_SCHEMAS: dict[str, TableSchema] = {}


def _register(schema: TableSchema) -> TableSchema:
    TPCH_SCHEMAS[schema.name] = schema
    return schema


REGION = _register(TableSchema(
    "region",
    [
        _c("r_regionkey", ColKind.INT),
        _c("r_name", ColKind.CAT, char_width=16),
    ],
    primary_key=("r_regionkey",),
))

NATION = _register(TableSchema(
    "nation",
    [
        _c("n_nationkey", ColKind.INT),
        _c("n_name", ColKind.CAT, char_width=16),
        _c("n_regionkey", ColKind.INT),
    ],
    primary_key=("n_nationkey",),
    foreign_keys=(ForeignKey("n_regionkey", "region", "r_regionkey"),),
))

SUPPLIER = _register(TableSchema(
    "supplier",
    [
        _c("s_suppkey", ColKind.INT),
        _c("s_name", ColKind.CAT, char_width=20),
        _c("s_nationkey", ColKind.INT),
        _c("s_acctbal", ColKind.FLOAT),
        _c("s_comment", ColKind.TEXT, max_words=8),
    ],
    primary_key=("s_suppkey",),
    foreign_keys=(ForeignKey("s_nationkey", "nation", "n_nationkey"),),
))

CUSTOMER = _register(TableSchema(
    "customer",
    [
        _c("c_custkey", ColKind.INT),
        _c("c_name", ColKind.CAT, char_width=20),
        _c("c_nationkey", ColKind.INT),
        _c("c_acctbal", ColKind.FLOAT),
        _c("c_mktsegment", ColKind.CAT, char_width=12),
        _c("c_phone", ColKind.CAT, char_width=16),
        _c("c_comment", ColKind.TEXT, max_words=8),
    ],
    primary_key=("c_custkey",),
    foreign_keys=(ForeignKey("c_nationkey", "nation", "n_nationkey"),),
))

PART = _register(TableSchema(
    "part",
    [
        _c("p_partkey", ColKind.INT),
        _c("p_name", ColKind.TEXT, max_words=5),
        _c("p_mfgr", ColKind.CAT, char_width=16),
        _c("p_brand", ColKind.CAT, char_width=12),
        _c("p_type", ColKind.CAT, char_width=28),
        _c("p_size", ColKind.INT),
        _c("p_container", ColKind.CAT, char_width=12),
        _c("p_retailprice", ColKind.FLOAT),
    ],
    primary_key=("p_partkey",),
))

PARTSUPP = _register(TableSchema(
    "partsupp",
    [
        _c("ps_partkey", ColKind.INT),
        _c("ps_suppkey", ColKind.INT),
        _c("ps_availqty", ColKind.INT),
        _c("ps_supplycost", ColKind.FLOAT),
    ],
    primary_key=("ps_partkey", "ps_suppkey"),
    foreign_keys=(
        ForeignKey("ps_partkey", "part", "p_partkey"),
        ForeignKey("ps_suppkey", "supplier", "s_suppkey"),
    ),
))

ORDERS = _register(TableSchema(
    "orders",
    [
        _c("o_orderkey", ColKind.INT),
        _c("o_custkey", ColKind.INT),
        _c("o_orderstatus", ColKind.CAT, char_width=4),
        _c("o_totalprice", ColKind.FLOAT),
        _c("o_orderdate", ColKind.DATE),
        _c("o_orderpriority", ColKind.CAT, char_width=16),
        _c("o_shippriority", ColKind.INT),
        _c("o_comment", ColKind.TEXT, max_words=8),
    ],
    primary_key=("o_orderkey",),
    foreign_keys=(ForeignKey("o_custkey", "customer", "c_custkey"),),
))

LINEITEM = _register(TableSchema(
    "lineitem",
    [
        _c("l_orderkey", ColKind.INT),
        _c("l_partkey", ColKind.INT),
        _c("l_suppkey", ColKind.INT),
        _c("l_linenumber", ColKind.INT),
        _c("l_quantity", ColKind.FLOAT),
        _c("l_extendedprice", ColKind.FLOAT),
        _c("l_discount", ColKind.FLOAT),
        _c("l_tax", ColKind.FLOAT),
        _c("l_returnflag", ColKind.CAT, char_width=4),
        _c("l_linestatus", ColKind.CAT, char_width=4),
        _c("l_shipdate", ColKind.DATE),
        _c("l_commitdate", ColKind.DATE),
        _c("l_receiptdate", ColKind.DATE),
        _c("l_shipinstruct", ColKind.CAT, char_width=20),
        _c("l_shipmode", ColKind.CAT, char_width=12),
    ],
    # Composite primary key — per the paper (§3.2.1) no dense PK array is
    # built for lineitem; it is instead partitioned on its foreign keys.
    primary_key=("l_orderkey", "l_linenumber"),
    foreign_keys=(
        ForeignKey("l_orderkey", "orders", "o_orderkey"),
        ForeignKey("l_partkey", "part", "p_partkey"),
        ForeignKey("l_suppkey", "supplier", "s_suppkey"),
    ),
))
