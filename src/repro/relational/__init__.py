from repro.relational.loader import Database
from repro.relational.schema import TPCH_SCHEMAS, days
from repro.relational.table import Table

__all__ = ["Database", "Table", "TPCH_SCHEMAS", "days"]
