"""TPC-H query plans (the physical plans LegoBase receives, Fig 4/Fig 8).

Each builder returns a *fresh* logical plan (passes mutate plans in place).
Join orientation follows the paper's partitioned execution: the fact side
streams and dimension/parent sides build.  Group-bys on keys functionally
determining other attributes use carry columns (Q3, Q10, Q18), matching the
paper's single-key aggregation maps.

15 TPC-H query plans are implemented (incl. two Q9 variants) — chosen to cover every
optimization in §3 (the remaining queries exercise no additional engine
feature: correlated sub-queries are rewritten the same way Q17/Q18 are).
"""
from __future__ import annotations

from repro.core.expr import (And, Arith, Cmp, Not, Or, Param,
                             StrContainsWord, StrEq, StrIn, StrStartsWith,
                             Where, Year, col, lit)
from repro.core.ir import Agg, AggSpec, Join, Limit, Plan, Project, Scan, Select, Sort
from repro.relational.schema import days


def _between(c: str, lo, hi) -> And:
    return And(Cmp(">=", col(c), lit(lo)), Cmp("<=", col(c), lit(hi)))


def _date_in(c: str, lo: str, hi: str) -> And:
    """lo <= c < hi over date strings."""
    return And(Cmp(">=", col(c), lit(days(lo))), Cmp("<", col(c), lit(days(hi))))


def _revenue() -> Arith:
    return Arith("*", col("l_extendedprice"),
                 Arith("-", lit(1.0), col("l_discount")))


# ---------------------------------------------------------------------------

def q1() -> Plan:
    disc_price = _revenue()
    charge = Arith("*", disc_price, Arith("+", lit(1.0), col("l_tax")))
    sel = Select(Scan("lineitem"),
                 Cmp("<=", col("l_shipdate"), lit(days("1998-09-02"))))
    agg = Agg(sel, ["l_returnflag", "l_linestatus"], [
        AggSpec("sum_qty", "sum", col("l_quantity")),
        AggSpec("sum_base_price", "sum", col("l_extendedprice")),
        AggSpec("sum_disc_price", "sum", disc_price),
        AggSpec("sum_charge", "sum", charge),
        AggSpec("avg_qty", "avg", col("l_quantity")),
        AggSpec("avg_price", "avg", col("l_extendedprice")),
        AggSpec("avg_disc", "avg", col("l_discount")),
        AggSpec("count_order", "count"),
    ])
    return Sort(agg, [("l_returnflag", True), ("l_linestatus", True)])


def q3() -> Plan:
    li = Select(Scan("lineitem"),
                Cmp(">", col("l_shipdate"), lit(days("1995-03-15"))))
    orders = Select(Scan("orders"),
                    Cmp("<", col("o_orderdate"), lit(days("1995-03-15"))))
    cust = Select(Scan("customer"), StrEq("c_mktsegment", "BUILDING"))
    j1 = Join(li, orders, "l_orderkey", "o_orderkey")
    j2 = Join(j1, cust, "o_custkey", "c_custkey")
    agg = Agg(j2, ["l_orderkey"],
              [AggSpec("revenue", "sum", _revenue())],
              carry=["o_orderdate", "o_shippriority"])
    srt = Sort(agg, [("revenue", False), ("o_orderdate", True)])
    return Limit(srt, 10)


def q4() -> Plan:
    orders = Select(Scan("orders"),
                    _date_in("o_orderdate", "1993-07-01", "1993-10-01"))
    li = Select(Scan("lineitem"),
                Cmp("<", col("l_commitdate"), col("l_receiptdate")))
    semi = Join(orders, li, "o_orderkey", "l_orderkey", kind="semi")
    agg = Agg(semi, ["o_orderpriority"], [AggSpec("order_count", "count")])
    return Sort(agg, [("o_orderpriority", True)])


def q5() -> Plan:
    orders = Select(Scan("orders"),
                    _date_in("o_orderdate", "1994-01-01", "1995-01-01"))
    region = Select(Scan("region"), StrEq("r_name", "ASIA"))
    j1 = Join(Scan("lineitem"), orders, "l_orderkey", "o_orderkey")
    j2 = Join(j1, Scan("customer"), "o_custkey", "c_custkey")
    j3 = Join(j2, Scan("supplier"), "l_suppkey", "s_suppkey")
    j4 = Join(j3, Scan("nation"), "s_nationkey", "n_nationkey")
    j5 = Join(j4, region, "n_regionkey", "r_regionkey")
    sel = Select(j5, Cmp("==", col("c_nationkey"), col("s_nationkey")))
    agg = Agg(sel, ["n_name"], [AggSpec("revenue", "sum", _revenue())])
    return Sort(agg, [("revenue", False)])


def q6() -> Plan:
    pred = And(And(_date_in("l_shipdate", "1994-01-01", "1995-01-01"),
                   _between("l_discount", 0.05, 0.07)),
               Cmp("<", col("l_quantity"), lit(24.0)))
    sel = Select(Scan("lineitem"), pred)
    return Agg(sel, [], [AggSpec("revenue", "sum",
                                 Arith("*", col("l_extendedprice"),
                                       col("l_discount")))])


def q7() -> Plan:
    n1 = Project(Scan("nation"),
                 {"supp_nation": col("n_name"), "n1_key": col("n_nationkey")},
                 keep_input=False)
    n2 = Project(Scan("nation"),
                 {"cust_nation": col("n_name"), "n2_key": col("n_nationkey")},
                 keep_input=False)
    li = Select(Scan("lineitem"),
                _date_in("l_shipdate", "1995-01-01", "1997-01-01"))
    j1 = Join(li, Scan("orders"), "l_orderkey", "o_orderkey")
    j2 = Join(j1, Scan("customer"), "o_custkey", "c_custkey")
    j3 = Join(j2, Scan("supplier"), "l_suppkey", "s_suppkey")
    j4 = Join(j3, n1, "s_nationkey", "n1_key")
    j5 = Join(j4, n2, "c_nationkey", "n2_key")
    pair = Or(And(StrEq("supp_nation", "FRANCE"), StrEq("cust_nation", "GERMANY")),
              And(StrEq("supp_nation", "GERMANY"), StrEq("cust_nation", "FRANCE")))
    sel = Select(j5, pair)
    # group key offset to the data's year range (1992..1998): the dense
    # aggregation array is sized by the key domain (paper §3.2.2 worst-case
    # preallocation) — domain 8 instead of 2000.
    proj = Project(sel, {"y_off": Arith("-", Year(col("l_shipdate")),
                                        lit(1992))})
    agg = Agg(proj, ["supp_nation", "cust_nation", "y_off"],
              [AggSpec("revenue", "sum", _revenue())],
              domain_hints={"y_off": 8})
    post = Project(agg, {"l_year": Arith("+", col("y_off"), lit(1992))})
    return Sort(post, [("supp_nation", True), ("cust_nation", True),
                       ("l_year", True)])


def q9() -> Plan:
    """Q9 (product-type profit), simplified: the ps_supplycost term (a
    composite-key partsupp join) is dropped — profit = revenue.  Exercises
    the word-tokenizing dictionary on p_name ('green'), Year() grouping,
    and a 4-way gather chain."""
    part = Select(Scan("part"), StrContainsWord("p_name", "green"))
    j1 = Join(Scan("lineitem"), part, "l_partkey", "p_partkey")
    j2 = Join(j1, Scan("supplier"), "l_suppkey", "s_suppkey")
    j3 = Join(j2, Scan("nation"), "s_nationkey", "n_nationkey")
    j4 = Join(j3, Scan("orders"), "l_orderkey", "o_orderkey")
    proj = Project(j4, {"y_off": Arith("-", Year(col("o_orderdate")),
                                       lit(1992))})
    agg = Agg(proj, ["n_name", "y_off"],
              [AggSpec("sum_profit", "sum", _revenue())],
              domain_hints={"y_off": 8})
    post = Project(agg, {"o_year": Arith("+", col("y_off"), lit(1992))})
    return Sort(post, [("n_name", True), ("o_year", False)])


def q9_full() -> Plan:
    """Q9 with the ps_supplycost term: the lineitem→partsupp join is on the
    composite primary key (l_partkey, l_suppkey) = (ps_partkey, ps_suppkey),
    exercising the §3.2.1 composite-PK 2-D partitioned array
    (Join.strategy='bucket_gather')."""
    part = Select(Scan("part"), StrContainsWord("p_name", "green"))
    j1 = Join(Scan("lineitem"), part, "l_partkey", "p_partkey")
    j2 = Join(j1, Scan("supplier"), "l_suppkey", "s_suppkey")
    j3 = Join(j2, Scan("nation"), "s_nationkey", "n_nationkey")
    j4 = Join(j3, Scan("orders"), "l_orderkey", "o_orderkey")
    j5 = Join(j4, Scan("partsupp"), "l_partkey", "ps_partkey",
              stream_key2="l_suppkey", build_key2="ps_suppkey")
    profit = Arith("-", _revenue(),
                   Arith("*", col("ps_supplycost"), col("l_quantity")))
    proj = Project(j5, {"y_off": Arith("-", Year(col("o_orderdate")),
                                       lit(1992))})
    agg = Agg(proj, ["n_name", "y_off"],
              [AggSpec("sum_profit", "sum", profit)],
              domain_hints={"y_off": 8})
    post = Project(agg, {"o_year": Arith("+", col("y_off"), lit(1992))})
    return Sort(post, [("n_name", True), ("o_year", False)])


def q10() -> Plan:
    li = Select(Scan("lineitem"), StrEq("l_returnflag", "R"))
    orders = Select(Scan("orders"),
                    _date_in("o_orderdate", "1993-10-01", "1994-01-01"))
    j1 = Join(li, orders, "l_orderkey", "o_orderkey")
    j2 = Join(j1, Scan("customer"), "o_custkey", "c_custkey")
    j3 = Join(j2, Scan("nation"), "c_nationkey", "n_nationkey")
    agg = Agg(j3, ["c_custkey"], [AggSpec("revenue", "sum", _revenue())],
              carry=["c_acctbal", "n_name"])
    srt = Sort(agg, [("revenue", False)])
    return Limit(srt, 20)


def q12() -> Plan:
    pred = And(And(StrIn("l_shipmode", ("MAIL", "SHIP")),
                   Cmp("<", col("l_commitdate"), col("l_receiptdate"))),
               And(Cmp("<", col("l_shipdate"), col("l_commitdate")),
                   _date_in("l_receiptdate", "1994-01-01", "1995-01-01")))
    li = Select(Scan("lineitem"), pred)
    j = Join(li, Scan("orders"), "l_orderkey", "o_orderkey")
    urgent = StrIn("o_orderpriority", ("1-URGENT", "2-HIGH"))
    agg = Agg(j, ["l_shipmode"], [
        AggSpec("high_line_count", "sum", Where(urgent, lit(1.0), lit(0.0))),
        AggSpec("low_line_count", "sum", Where(urgent, lit(0.0), lit(1.0))),
    ])
    return Sort(agg, [("l_shipmode", True)])


def q13() -> Plan:
    orders = Select(Scan("orders"),
                    Not(And(StrContainsWord("o_comment", "special"),
                            StrContainsWord("o_comment", "requests"))))
    per_cust = Agg(orders, ["o_custkey"], [AggSpec("c_count", "count")])
    j = Join(Scan("customer"), per_cust, "c_custkey", "o_custkey", kind="left")
    agg = Agg(j, ["c_count"], [AggSpec("custdist", "count")],
              domain_hints={"c_count": 64})
    return Sort(agg, [("custdist", False), ("c_count", False)])


def q14() -> Plan:
    li = Select(Scan("lineitem"),
                _date_in("l_shipdate", "1995-09-01", "1995-10-01"))
    j = Join(li, Scan("part"), "l_partkey", "p_partkey")
    rev = _revenue()
    agg = Agg(j, [], [
        AggSpec("promo", "sum",
                Where(StrStartsWith("p_type", "PROMO"), rev, lit(0.0))),
        AggSpec("total", "sum", rev),
    ])
    return Project(agg, {"promo_revenue":
                         Arith("/", Arith("*", lit(100.0), col("promo")),
                               col("total"))}, keep_input=False)


def q17() -> Plan:
    per_part = Agg(Scan("lineitem"), ["l_partkey"],
                   [AggSpec("avg_qty", "avg", col("l_quantity"))])
    part = Select(Scan("part"), And(StrEq("p_brand", "Brand#23"),
                                    StrEq("p_container", "MED BOX")))
    j1 = Join(Scan("lineitem"), part, "l_partkey", "p_partkey")
    j2 = Join(j1, per_part, "l_partkey", "l_partkey")
    sel = Select(j2, Cmp("<", col("l_quantity"),
                         Arith("*", lit(0.2), col("avg_qty"))))
    agg = Agg(sel, [], [AggSpec("total", "sum", col("l_extendedprice"))])
    return Project(agg, {"avg_yearly": Arith("/", col("total"), lit(7.0))},
                   keep_input=False)


def q18() -> Plan:
    # HAVING sum(l_quantity) > 212: threshold adapted to the synthetic
    # generator's 1–7 lines/order so the result is non-trivial (TPC-H's 300
    # is near the max possible 350 here).
    big = Select(Agg(Scan("lineitem"), ["l_orderkey"],
                     [AggSpec("sum_qty", "sum", col("l_quantity"))]),
                 Cmp(">", col("sum_qty"), lit(212.0)))
    j1 = Join(Scan("orders"), big, "o_orderkey", "l_orderkey")
    j2 = Join(j1, Scan("customer"), "o_custkey", "c_custkey")
    proj = Project(j2, {"c_name": col("c_name"), "c_custkey": col("c_custkey"),
                        "o_orderkey": col("o_orderkey"),
                        "o_orderdate": col("o_orderdate"),
                        "o_totalprice": col("o_totalprice"),
                        "sum_qty": col("sum_qty")}, keep_input=False)
    srt = Sort(proj, [("o_totalprice", False), ("o_orderdate", True)])
    return Limit(srt, 100)


def q19() -> Plan:
    li = Select(Scan("lineitem"),
                And(StrIn("l_shipmode", ("AIR", "REG AIR")),
                    StrEq("l_shipinstruct", "DELIVER IN PERSON")))
    j = Join(li, Scan("part"), "l_partkey", "p_partkey")
    c1 = And(And(StrEq("p_brand", "Brand#12"),
                 StrIn("p_container", ("SM CASE", "SM BOX", "SM PACK", "SM PKG"))),
             And(_between("l_quantity", 1.0, 11.0), _between("p_size", 1, 5)))
    c2 = And(And(StrEq("p_brand", "Brand#23"),
                 StrIn("p_container", ("MED BAG", "MED BOX", "MED PKG", "MED PACK"))),
             And(_between("l_quantity", 10.0, 20.0), _between("p_size", 1, 10)))
    c3 = And(And(StrEq("p_brand", "Brand#34"),
                 StrIn("p_container", ("LG CASE", "LG BOX", "LG PACK", "LG PKG"))),
             And(_between("l_quantity", 20.0, 30.0), _between("p_size", 1, 15)))
    sel = Select(j, Or(Or(c1, c2), c3))
    return Agg(sel, [], [AggSpec("revenue", "sum", _revenue())])


QUERIES: dict[str, object] = {
    "q1": q1, "q3": q3, "q4": q4, "q5": q5, "q6": q6, "q7": q7, "q9": q9,
    "q9full": q9_full, "q10": q10, "q12": q12, "q13": q13, "q14": q14,
    "q17": q17, "q18": q18, "q19": q19,
}


# ---------------------------------------------------------------------------
# Parameterized variants (compile-once / bind-many, the runtime layer's
# workload).  Numeric Params are runtime-bound scalar inputs of the staged
# program; the string segment and the Limit count are compile-time params
# (part of the plan-cache key).  Each default binding reproduces the literal
# query above exactly.
# ---------------------------------------------------------------------------

def q1_param() -> Plan:
    plan = q1()
    sel = plan.child.child          # Sort -> Agg -> Select
    sel.pred = Cmp("<=", col("l_shipdate"), Param("shipdate_hi", "int32"))
    return plan


Q1_DEFAULTS = {"shipdate_hi": days("1998-09-02")}


def q3_param() -> Plan:
    cutoff = Param("cutoff", "int32")
    li = Select(Scan("lineitem"), Cmp(">", col("l_shipdate"), cutoff))
    orders = Select(Scan("orders"), Cmp("<", col("o_orderdate"), cutoff))
    cust = Select(Scan("customer"),
                  StrEq("c_mktsegment", Param("segment", "str")))
    j1 = Join(li, orders, "l_orderkey", "o_orderkey")
    j2 = Join(j1, cust, "o_custkey", "c_custkey")
    agg = Agg(j2, ["l_orderkey"],
              [AggSpec("revenue", "sum", _revenue())],
              carry=["o_orderdate", "o_shippriority"])
    srt = Sort(agg, [("revenue", False), ("o_orderdate", True)])
    return Limit(srt, Param("topn", "int32"))


Q3_DEFAULTS = {"cutoff": days("1995-03-15"), "segment": "BUILDING",
               "topn": 10}


def q6_param() -> Plan:
    pred = And(And(And(Cmp(">=", col("l_shipdate"), Param("date_lo", "int32")),
                       Cmp("<", col("l_shipdate"), Param("date_hi", "int32"))),
               And(Cmp(">=", col("l_discount"), Param("disc_lo", "float32")),
                   Cmp("<=", col("l_discount"), Param("disc_hi", "float32")))),
               Cmp("<", col("l_quantity"), Param("qty_max", "float32")))
    sel = Select(Scan("lineitem"), pred)
    return Agg(sel, [], [AggSpec("revenue", "sum",
                                 Arith("*", col("l_extendedprice"),
                                       col("l_discount")))])


Q6_DEFAULTS = {"date_lo": days("1994-01-01"), "date_hi": days("1995-01-01"),
               "disc_lo": 0.05, "disc_hi": 0.07, "qty_max": 24.0}


def q12_param() -> Plan:
    """Shipmode strings are compile-time params (the StrIn rewrite needs
    dictionary codes); the receipt-date window is runtime-bound."""
    pred = And(And(StrIn("l_shipmode", (Param("mode1", "str"),
                                        Param("mode2", "str"))),
                   Cmp("<", col("l_commitdate"), col("l_receiptdate"))),
               And(Cmp("<", col("l_shipdate"), col("l_commitdate")),
                   And(Cmp(">=", col("l_receiptdate"),
                           Param("receipt_lo", "int32")),
                       Cmp("<", col("l_receiptdate"),
                           Param("receipt_hi", "int32")))))
    li = Select(Scan("lineitem"), pred)
    j = Join(li, Scan("orders"), "l_orderkey", "o_orderkey")
    urgent = StrIn("o_orderpriority", ("1-URGENT", "2-HIGH"))
    agg = Agg(j, ["l_shipmode"], [
        AggSpec("high_line_count", "sum", Where(urgent, lit(1.0), lit(0.0))),
        AggSpec("low_line_count", "sum", Where(urgent, lit(0.0), lit(1.0))),
    ])
    return Sort(agg, [("l_shipmode", True)])


Q12_DEFAULTS = {"mode1": "MAIL", "mode2": "SHIP",
                "receipt_lo": days("1994-01-01"),
                "receipt_hi": days("1995-01-01")}


def q14_param() -> Plan:
    """Date range over the lineitem/part join as runtime params; the
    promo prefix is compile-time (StrStartsWith needs the concrete
    prefix for the dictionary-range rewrite)."""
    li = Select(Scan("lineitem"),
                And(Cmp(">=", col("l_shipdate"), Param("ship_lo", "int32")),
                    Cmp("<", col("l_shipdate"), Param("ship_hi", "int32"))))
    j = Join(li, Scan("part"), "l_partkey", "p_partkey")
    rev = _revenue()
    agg = Agg(j, [], [
        AggSpec("promo", "sum",
                Where(StrStartsWith("p_type", Param("promo_prefix", "str")),
                      rev, lit(0.0))),
        AggSpec("total", "sum", rev),
    ])
    return Project(agg, {"promo_revenue":
                         Arith("/", Arith("*", lit(100.0), col("promo")),
                               col("total"))}, keep_input=False)


Q14_DEFAULTS = {"ship_lo": days("1995-09-01"), "ship_hi": days("1995-10-01"),
                "promo_prefix": "PROMO"}


def q19_param() -> Plan:
    """Disjunctive predicate: per-branch quantity windows are runtime
    params, the three brands compile-time string params."""
    li = Select(Scan("lineitem"),
                And(StrIn("l_shipmode", ("AIR", "REG AIR")),
                    StrEq("l_shipinstruct", "DELIVER IN PERSON")))
    j = Join(li, Scan("part"), "l_partkey", "p_partkey")

    def qty(lo, hi):
        return And(Cmp(">=", col("l_quantity"), Param(lo, "float32")),
                   Cmp("<=", col("l_quantity"), Param(hi, "float32")))

    c1 = And(And(StrEq("p_brand", Param("brand1", "str")),
                 StrIn("p_container", ("SM CASE", "SM BOX", "SM PACK", "SM PKG"))),
             And(qty("qty1_lo", "qty1_hi"), _between("p_size", 1, 5)))
    c2 = And(And(StrEq("p_brand", Param("brand2", "str")),
                 StrIn("p_container", ("MED BAG", "MED BOX", "MED PKG", "MED PACK"))),
             And(qty("qty2_lo", "qty2_hi"), _between("p_size", 1, 10)))
    c3 = And(And(StrEq("p_brand", Param("brand3", "str")),
                 StrIn("p_container", ("LG CASE", "LG BOX", "LG PACK", "LG PKG"))),
             And(qty("qty3_lo", "qty3_hi"), _between("p_size", 1, 15)))
    sel = Select(j, Or(Or(c1, c2), c3))
    return Agg(sel, [], [AggSpec("revenue", "sum", _revenue())])


Q19_DEFAULTS = {"brand1": "Brand#12", "qty1_lo": 1.0, "qty1_hi": 11.0,
                "brand2": "Brand#23", "qty2_lo": 10.0, "qty2_hi": 20.0,
                "brand3": "Brand#34", "qty3_lo": 20.0, "qty3_hi": 30.0}


# name -> (plan builder, default bindings matching the literal query)
PARAM_QUERIES: dict[str, tuple] = {
    "q1": (q1_param, Q1_DEFAULTS),
    "q3": (q3_param, Q3_DEFAULTS),
    "q6": (q6_param, Q6_DEFAULTS),
    "q12": (q12_param, Q12_DEFAULTS),
    "q14": (q14_param, Q14_DEFAULTS),
    "q19": (q19_param, Q19_DEFAULTS),
}

# alternative runtime bindings (overlay on the defaults) used by the cache
# tests and bench_plan_cache to exercise the re-bind path with a different,
# non-empty result.  Only *runtime* params are overridden: the same plan
# key (and therefore the same staged program / batch group) must serve
# both the default and the alternative bindings.
PARAM_ALT_BINDINGS: dict[str, dict] = {
    "q1": {"shipdate_hi": days("1997-06-30")},
    "q3": {"cutoff": days("1995-06-15")},
    "q6": {"date_lo": days("1995-01-01"), "date_hi": days("1996-01-01"),
           "qty_max": 30.0},
    "q12": {"receipt_lo": days("1995-01-01"),
            "receipt_hi": days("1996-01-01")},
    "q14": {"ship_lo": days("1994-03-01"), "ship_hi": days("1994-06-01")},
    "q19": {"qty1_lo": 2.0, "qty1_hi": 14.0, "qty2_lo": 8.0,
            "qty2_hi": 24.0, "qty3_lo": 16.0, "qty3_hi": 34.0},
}
