"""Synthetic TPC-H data generator (a numpy `dbgen`).

Faithful in structure to the TPC-H spec (table cardinalities scale with SF,
uniform dates over 1992-01-01..1998-12-31, the standard categorical
domains, PK/FK relationships) but synthetic in content.  Primary keys are
generated as dense 0-based ranges — the paper (§3.2.1) relies on TPC-H keys
being "typically integer values in the range [1..#num_tuples]" and
otherwise trades memory for a sparse array; we take the dense case.
"""
from __future__ import annotations

import numpy as np

from repro.relational import schema as S
from repro.relational.table import Table

EPOCH = np.datetime64("1970-01-01", "D")
DATE_LO = int(np.datetime64("1992-01-01", "D").astype(np.int64))
DATE_HI = int(np.datetime64("1998-08-02", "D").astype(np.int64))

REGIONS = ["AFRICA", "AMERICA", "ASIA", "EUROPE", "MIDDLE EAST"]
NATIONS = [
    "ALGERIA", "ARGENTINA", "BRAZIL", "CANADA", "EGYPT", "ETHIOPIA",
    "FRANCE", "GERMANY", "INDIA", "INDONESIA", "IRAN", "IRAQ", "JAPAN",
    "JORDAN", "KENYA", "MOROCCO", "MOZAMBIQUE", "PERU", "CHINA",
    "ROMANIA", "SAUDI ARABIA", "VIETNAM", "RUSSIA", "UNITED KINGDOM",
    "UNITED STATES",
]
NATION_REGION = [0, 1, 1, 1, 4, 0, 3, 3, 2, 2, 4, 4, 2, 4, 0, 0, 0, 1, 2,
                 3, 4, 2, 3, 3, 1]
SEGMENTS = ["AUTOMOBILE", "BUILDING", "FURNITURE", "HOUSEHOLD", "MACHINERY"]
PRIORITIES = ["1-URGENT", "2-HIGH", "3-MEDIUM", "4-NOT SPECI", "5-LOW"]
ORDERSTATUS = ["F", "O", "P"]
RETURNFLAGS = ["A", "N", "R"]
LINESTATUS = ["F", "O"]
SHIPINSTRUCT = ["COLLECT COD", "DELIVER IN PERSON", "NONE", "TAKE BACK RETURN"]
SHIPMODES = ["AIR", "FOB", "MAIL", "RAIL", "REG AIR", "SHIP", "TRUCK"]
TYPE_SYL1 = ["STANDARD", "SMALL", "MEDIUM", "LARGE", "ECONOMY", "PROMO"]
TYPE_SYL2 = ["ANODIZED", "BURNISHED", "PLATED", "POLISHED", "BRUSHED"]
TYPE_SYL3 = ["TIN", "NICKEL", "BRASS", "STEEL", "COPPER"]
CONTAINER_1 = ["SM", "LG", "MED", "JUMBO", "WRAP"]
CONTAINER_2 = ["CASE", "BOX", "BAG", "JAR", "PKG", "PACK", "CAN", "DRUM"]
P_WORDS = [
    "almond", "antique", "aquamarine", "azure", "beige", "bisque", "black",
    "blanched", "blue", "blush", "brown", "burlywood", "burnished",
    "chartreuse", "chiffon", "chocolate", "coral", "cornflower", "cornsilk",
    "cream", "cyan", "dark", "deep", "dim", "dodger", "drab", "firebrick",
    "floral", "forest", "frosted", "gainsboro", "ghost", "goldenrod",
    "green", "grey", "honeydew", "hot", "hotpink", "indian", "ivory",
    "khaki", "lace", "lavender", "lawn", "lemon", "light", "lime", "linen",
    "magenta", "maroon", "medium", "metallic", "midnight", "mint", "misty",
    "moccasin", "navajo", "navy", "olive", "orange", "orchid", "pale",
    "papaya", "peach", "peru", "pink", "plum", "powder", "puff", "purple",
    "red", "rose", "rosy", "royal", "saddle", "salmon", "sandy", "seashell",
    "sienna", "sky", "slate", "smoke", "snow", "spring", "steel", "tan",
    "thistle", "tomato", "turquoise", "violet", "wheat", "white", "yellow",
]
COMMENT_WORDS = [
    "about", "above", "accounts", "across", "after", "again", "against",
    "along", "among", "asymptotes", "attainments", "blithely", "bold",
    "braids", "carefully", "courts", "daringly", "decoys", "deposits",
    "dolphins", "dugouts", "engage", "epitaphs", "escapades", "even",
    "excuses", "express", "final", "fluffily", "foxes", "frays", "furious",
    "furiously", "gifts", "grouches", "hockey", "ideas", "instructions",
    "ironic", "packages", "pending", "pinto", "platelets", "players",
    "quickly", "quietly", "realms", "regular", "requests", "ruthlessly",
    "sauternes", "sentiments", "silent", "sleepy", "slyly", "special",
    "theodolites", "thinly", "unusual", "waters",
]


def _cat(domain: list[str], raw_idx: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Ordered-dictionary encode: vocab is sorted, codes order-preserving."""
    vocab = np.array(sorted(domain))
    rank = np.empty(len(domain), dtype=np.int32)
    for i, s in enumerate(domain):
        rank[i] = np.searchsorted(vocab, s)
    return rank[raw_idx].astype(np.int32), vocab


def _text(rng, n: int, words: list[str], n_words: int, max_words: int,
          inject: list[str] | None = None, inject_p: float = 0.0,
          ) -> tuple[np.ndarray, np.ndarray]:
    vocab = np.array(sorted(set(words) | set(inject or [])))
    codes = rng.integers(0, len(vocab), size=(n, max_words)).astype(np.int32)
    lens = rng.integers(max(1, n_words - 2), n_words + 1, size=n)
    mask = np.arange(max_words)[None, :] >= lens[:, None]
    codes[mask] = -1
    if inject:
        # Inject a fixed phrase (e.g. "special requests") into a fraction of
        # rows so Q13-style predicates are selective but non-trivial.
        picks = rng.random(n) < inject_p
        idx = np.searchsorted(vocab, inject)
        for j, code in enumerate(idx):
            codes[picks, j] = code
    return codes, vocab


def generate(sf: float = 0.01, seed: int = 0) -> dict[str, Table]:
    rng = np.random.default_rng(seed)
    n_supp = max(20, int(10_000 * sf))
    n_cust = max(30, int(150_000 * sf))
    n_part = max(40, int(200_000 * sf))
    n_ord = max(60, int(1_500_000 * sf))

    tables: dict[str, Table] = {}

    # -- region / nation ----------------------------------------------------
    r_codes, r_vocab = _cat(REGIONS, np.arange(5))
    tables["region"] = Table(S.REGION, 5, {
        "r_regionkey": np.arange(5, dtype=np.int32),
        "r_name": r_codes,
    }, vocabs={"r_name": r_vocab})

    n_codes, n_vocab = _cat(NATIONS, np.arange(25))
    tables["nation"] = Table(S.NATION, 25, {
        "n_nationkey": np.arange(25, dtype=np.int32),
        "n_name": n_codes,
        "n_regionkey": np.array(NATION_REGION, dtype=np.int32),
    }, vocabs={"n_name": n_vocab})

    # -- supplier -----------------------------------------------------------
    s_names = [f"Supplier#{i:09d}" for i in range(n_supp)]
    s_name_codes, s_name_vocab = _cat(s_names, np.arange(n_supp))
    s_comment, s_cvocab = _text(rng, n_supp, COMMENT_WORDS, 6, 8,
                                inject=["customer", "complaints"], inject_p=0.01)
    tables["supplier"] = Table(S.SUPPLIER, n_supp, {
        "s_suppkey": np.arange(n_supp, dtype=np.int32),
        "s_name": s_name_codes,
        "s_nationkey": rng.integers(0, 25, n_supp).astype(np.int32),
        "s_acctbal": (rng.uniform(-999.99, 9999.99, n_supp)).astype(np.float32),
        "s_comment": s_comment,
    }, vocabs={"s_name": s_name_vocab}, word_vocabs={"s_comment": s_cvocab})

    # -- customer -----------------------------------------------------------
    c_names = [f"Customer#{i:09d}" for i in range(n_cust)]
    c_name_codes, c_name_vocab = _cat(c_names, np.arange(n_cust))
    seg_codes, seg_vocab = _cat(SEGMENTS, rng.integers(0, 5, n_cust))
    phones = [f"{cc:02d}-{rng.integers(100,999)}-{rng.integers(100,999)}"
              for cc in rng.integers(10, 35, n_cust)]
    ph_codes, ph_vocab = _cat(phones, np.arange(n_cust))
    c_comment, c_cvocab = _text(rng, n_cust, COMMENT_WORDS, 6, 8)
    tables["customer"] = Table(S.CUSTOMER, n_cust, {
        "c_custkey": np.arange(n_cust, dtype=np.int32),
        "c_name": c_name_codes,
        "c_nationkey": rng.integers(0, 25, n_cust).astype(np.int32),
        "c_acctbal": rng.uniform(-999.99, 9999.99, n_cust).astype(np.float32),
        "c_mktsegment": seg_codes,
        "c_phone": ph_codes,
        "c_comment": c_comment,
    }, vocabs={"c_name": c_name_vocab, "c_mktsegment": seg_vocab,
               "c_phone": ph_vocab},
       word_vocabs={"c_comment": c_cvocab})

    # -- part ---------------------------------------------------------------
    types = [f"{a} {b} {c}" for a in TYPE_SYL1 for b in TYPE_SYL2 for c in TYPE_SYL3]
    containers = [f"{a} {b}" for a in CONTAINER_1 for b in CONTAINER_2]
    brands = [f"Brand#{m}{n}" for m in range(1, 6) for n in range(1, 6)]
    mfgrs = [f"Manufacturer#{m}" for m in range(1, 6)]
    p_name, p_nvocab = _text(rng, n_part, P_WORDS, 5, 5)
    ty_codes, ty_vocab = _cat(types, rng.integers(0, len(types), n_part))
    ct_codes, ct_vocab = _cat(containers, rng.integers(0, len(containers), n_part))
    br_codes, br_vocab = _cat(brands, rng.integers(0, len(brands), n_part))
    mf_codes, mf_vocab = _cat(mfgrs, rng.integers(0, len(mfgrs), n_part))
    tables["part"] = Table(S.PART, n_part, {
        "p_partkey": np.arange(n_part, dtype=np.int32),
        "p_name": p_name,
        "p_mfgr": mf_codes,
        "p_brand": br_codes,
        "p_type": ty_codes,
        "p_size": rng.integers(1, 51, n_part).astype(np.int32),
        "p_container": ct_codes,
        "p_retailprice": (900 + (np.arange(n_part) % 200) * 1.0
                          + rng.uniform(0, 100, n_part)).astype(np.float32),
    }, vocabs={"p_mfgr": mf_vocab, "p_brand": br_vocab, "p_type": ty_vocab,
               "p_container": ct_vocab},
       word_vocabs={"p_name": p_nvocab})

    # -- partsupp -----------------------------------------------------------
    n_ps = 4 * n_part
    ps_part = np.repeat(np.arange(n_part, dtype=np.int32), 4)
    ps_supp = ((ps_part + (np.tile(np.arange(4), n_part) * (n_supp // 4 + 1)))
               % n_supp).astype(np.int32)
    tables["partsupp"] = Table(S.PARTSUPP, n_ps, {
        "ps_partkey": ps_part,
        "ps_suppkey": ps_supp,
        "ps_availqty": rng.integers(1, 10_000, n_ps).astype(np.int32),
        "ps_supplycost": rng.uniform(1.0, 1000.0, n_ps).astype(np.float32),
    })

    # -- orders -------------------------------------------------------------
    o_date = rng.integers(DATE_LO, DATE_HI + 1, n_ord).astype(np.int32)
    op_codes, op_vocab = _cat(PRIORITIES, rng.integers(0, 5, n_ord))
    os_codes, os_vocab = _cat(ORDERSTATUS, rng.integers(0, 3, n_ord))
    o_comment, o_cvocab = _text(rng, n_ord, COMMENT_WORDS, 6, 8,
                                inject=["special", "requests"], inject_p=0.25)
    tables["orders"] = Table(S.ORDERS, n_ord, {
        "o_orderkey": np.arange(n_ord, dtype=np.int32),
        "o_custkey": rng.integers(0, n_cust, n_ord).astype(np.int32),
        "o_orderstatus": os_codes,
        "o_totalprice": rng.uniform(850.0, 560_000.0, n_ord).astype(np.float32),
        "o_orderdate": o_date,
        "o_orderpriority": op_codes,
        "o_shippriority": np.zeros(n_ord, dtype=np.int32),
        "o_comment": o_comment,
    }, vocabs={"o_orderstatus": os_vocab, "o_orderpriority": op_vocab},
       word_vocabs={"o_comment": o_cvocab})

    # -- lineitem -----------------------------------------------------------
    lines_per_order = rng.integers(1, 8, n_ord)
    l_ord = np.repeat(np.arange(n_ord, dtype=np.int32), lines_per_order)
    n_li = int(l_ord.shape[0])
    l_lineno = (np.arange(n_li, dtype=np.int32)
                - np.repeat(np.cumsum(lines_per_order) - lines_per_order,
                            lines_per_order).astype(np.int32)) + 1
    l_part = rng.integers(0, n_part, n_li).astype(np.int32)
    l_supp = ((l_part + rng.integers(0, 4, n_li) * (n_supp // 4 + 1))
              % n_supp).astype(np.int32)
    qty = rng.integers(1, 51, n_li).astype(np.float32)
    retail = tables["part"].data["p_retailprice"][l_part]
    eprice = (qty * retail * rng.uniform(0.9, 1.1, n_li)).astype(np.float32)
    odate = o_date[l_ord]
    shipd = (odate + rng.integers(1, 122, n_li)).astype(np.int32)
    commd = (odate + rng.integers(30, 91, n_li)).astype(np.int32)
    recd = (shipd + rng.integers(1, 31, n_li)).astype(np.int32)
    rf_codes, rf_vocab = _cat(RETURNFLAGS, rng.integers(0, 3, n_li))
    ls_codes, ls_vocab = _cat(LINESTATUS, (shipd > S.days("1995-06-17")).astype(np.int64))
    si_codes, si_vocab = _cat(SHIPINSTRUCT, rng.integers(0, 4, n_li))
    sm_codes, sm_vocab = _cat(SHIPMODES, rng.integers(0, 7, n_li))
    tables["lineitem"] = Table(S.LINEITEM, n_li, {
        "l_orderkey": l_ord,
        "l_partkey": l_part,
        "l_suppkey": l_supp,
        "l_linenumber": l_lineno,
        "l_quantity": qty,
        "l_extendedprice": eprice,
        "l_discount": (rng.integers(0, 11, n_li) / 100.0).astype(np.float32),
        "l_tax": (rng.integers(0, 9, n_li) / 100.0).astype(np.float32),
        "l_returnflag": rf_codes,
        "l_linestatus": ls_codes,
        "l_shipdate": shipd,
        "l_commitdate": commd,
        "l_receiptdate": recd,
        "l_shipinstruct": si_codes,
        "l_shipmode": sm_codes,
    }, vocabs={"l_returnflag": rf_vocab, "l_linestatus": ls_vocab,
               "l_shipinstruct": si_vocab, "l_shipmode": sm_vocab})

    for t in tables.values():
        t.compute_stats()
    return tables
