"""Columnar table storage (host-side numpy) + per-column statistics.

Physical representation (this is the *record-of-arrays* / column layout of
paper §3.3 — the row-layout AoS variant used by the layout experiment is
built at staging time by `repro.core.operators.scan` under
`Settings(layout="row")`: per-dtype-group record matrices behind an
optimization barrier):

  INT/DATE  -> int32[n]
  FLOAT     -> float32[n]
  CAT       -> int32[n] dictionary codes + `vocab` (np.ndarray of str).
               The dictionary is *ordered* (codes sorted lexicographically)
               so range operations lower to code-range compares (§3.4).
  TEXT      -> int32[n, max_words] word codes (-1 padding) + word `vocab`.

`char_matrix()` materializes the un-dictionary-encoded representation
(fixed width uint8 bytes) used by engine configurations where the
StringDictionary optimization is disabled.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.relational.schema import ColKind, TableSchema


@dataclasses.dataclass
class ColumnStats:
    min: float = 0.0
    max: float = 0.0
    n_distinct: int = 0
    # For DATE columns: sorted unique years present.
    years: Optional[np.ndarray] = None


@dataclasses.dataclass
class Table:
    schema: TableSchema
    nrows: int
    # Column name -> physical array (codes for CAT, word matrix for TEXT).
    data: dict[str, np.ndarray]
    # CAT column name -> vocabulary (sorted, so codes are order-preserving).
    vocabs: dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    # TEXT column name -> word vocabulary.
    word_vocabs: dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    stats: dict[str, ColumnStats] = dataclasses.field(default_factory=dict)
    _char_cache: dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    # lazy selectivity sketches (built on first use by the Compaction pass):
    # per-column equi-depth quantiles and measured 2-column range fractions.
    _quantile_cache: dict[str, np.ndarray] = dataclasses.field(
        default_factory=dict)
    _pair_cache: dict[tuple, float] = dataclasses.field(default_factory=dict)
    _sample_cache: Optional[np.ndarray] = None
    # analysis-layer base ColInfo per column, validated against the stats
    # values on every hit (tests mutate `stats` in place): name ->
    # (stats signature, ColInfo).  Populated by analysis/schema.py.
    _colinfo_cache: dict[str, tuple] = dataclasses.field(default_factory=dict)

    @property
    def name(self) -> str:
        return self.schema.name

    def col(self, name: str) -> np.ndarray:
        return self.data[name]

    def compute_stats(self) -> None:
        for cdef in self.schema.columns:
            arr = self.data[cdef.name]
            st = ColumnStats()
            if cdef.kind in (ColKind.INT, ColKind.FLOAT, ColKind.DATE):
                if arr.size:
                    st.min = float(arr.min())
                    st.max = float(arr.max())
                if cdef.kind in (ColKind.INT, ColKind.DATE) and arr.size:
                    # exact distinct count (one np.unique at load time):
                    # feeds the compaction planner's group-count estimate
                    # for dense aggregations over key columns, where the
                    # static domain bound (parent row count) can be far
                    # above the live key population
                    st.n_distinct = int(np.unique(arr).size)
                if cdef.kind == ColKind.DATE and arr.size:
                    yrs = arr.astype("datetime64[D]").astype("datetime64[Y]")
                    st.years = np.unique(yrs).astype(np.int64) + 1970
            if cdef.kind == ColKind.CAT:
                st.n_distinct = len(self.vocabs[cdef.name])
                if arr.size:
                    st.min, st.max = float(arr.min()), float(arr.max())
            if cdef.kind == ColKind.TEXT:
                st.n_distinct = len(self.word_vocabs[cdef.name])
            self.stats[cdef.name] = st

    # -- selectivity sketches (§3.5.2 statistics knowledge, extended) -------
    QUANTILES = 129   # equi-depth knots: CDF error bounded by 1/(k-1)

    def quantile_sketch(self, name: str) -> np.ndarray:
        """Equi-depth quantile knots of a numeric column (sorted, length
        QUANTILES).  One pass at first use, cached; `cdf` interpolates on
        it, so any value bound — including one only known at bind time —
        gets a distribution-aware range estimate instead of the min/max
        linear interpolation."""
        q = self._quantile_cache.get(name)
        if q is None:
            arr = self.data[name]
            if arr.size == 0:
                q = np.zeros(2, dtype=np.float64)
            else:
                knots = np.linspace(0.0, 1.0, self.QUANTILES)
                q = np.quantile(arr.astype(np.float64), knots)
            self._quantile_cache[name] = q
        return q

    def cdf(self, name: str, v: float) -> float:
        """Estimated fraction of rows with column value <= v."""
        q = self.quantile_sketch(name)
        k = len(q) - 1
        if v < q[0]:
            return 0.0
        if v >= q[-1]:
            return 1.0
        i = int(np.searchsorted(q, v, side="right")) - 1
        i = min(max(i, 0), k - 1)
        span = q[i + 1] - q[i]
        frac = (v - q[i]) / span if span > 0 else 1.0
        return (i + min(max(frac, 0.0), 1.0)) / k

    def pair_frac(self, a: str, op: str, b: str) -> float:
        """Measured fraction of rows satisfying `a op b` for two columns
        of THIS table (row-aligned compare, one vectorized pass, cached).
        The 2-column range sketch behind col-vs-col selectivity — replaces
        the textbook 0.5 with the observed fraction."""
        key = (a, op, b)
        got = self._pair_cache.get(key)
        if got is None:
            x, y = self.data[a], self.data[b]
            if x.size == 0:
                got = 0.5
            else:
                cmp = {"<": np.less, "<=": np.less_equal,
                       ">": np.greater, ">=": np.greater_equal,
                       "==": np.equal, "!=": np.not_equal}[op]
                got = float(np.count_nonzero(cmp(x, y))) / x.size
            self._pair_cache[key] = got
        return got

    SAMPLE_ROWS = 2048

    def sample_index(self) -> np.ndarray:
        """Sorted row sample (≤ SAMPLE_ROWS rows) for joint-predicate
        selectivity measurement (compaction's conjunction clamp).  Fixed
        seed: capacity planning must be deterministic across processes and
        across the plan cache's capacity-signature runs."""
        if self._sample_cache is None:
            if self.nrows <= self.SAMPLE_ROWS:
                idx = np.arange(self.nrows)
            else:
                rng = np.random.default_rng(0x5EED)
                idx = rng.choice(self.nrows, self.SAMPLE_ROWS, replace=False)
                idx.sort()
            self._sample_cache = idx
        return self._sample_cache

    # -- un-optimized (no string dictionary) physical representation -------
    def char_matrix(self, name: str) -> np.ndarray:
        """uint8[n, width] fixed-width byte matrix for a CAT column."""
        if name in self._char_cache:
            return self._char_cache[name]
        cdef = self.schema.col(name)
        if cdef.kind == ColKind.CAT:
            vocab = self.vocabs[name]
            width = cdef.char_width
            lut = np.zeros((len(vocab), width), dtype=np.uint8)
            for i, s in enumerate(vocab):
                b = str(s).encode()[:width]
                lut[i, : len(b)] = np.frombuffer(b, dtype=np.uint8)
            mat = lut[self.data[name]]
        elif cdef.kind == ColKind.TEXT:
            # Join words with single spaces into a char matrix.
            vocab = self.word_vocabs[name]
            wlens = np.array([len(str(s)) for s in vocab] + [0])
            codes = self.data[name]
            safe = np.where(codes < 0, len(vocab), codes)
            width = int((wlens[safe].sum(axis=1) + codes.shape[1]).max()) if codes.size else 1
            mat = np.zeros((self.nrows, width), dtype=np.uint8)
            strs = [" ".join(str(vocab[c]) for c in row if c >= 0) for row in codes]
            for i, s in enumerate(strs):
                b = s.encode()[:width]
                mat[i, : len(b)] = np.frombuffer(b, dtype=np.uint8)
        else:
            raise TypeError(f"char_matrix on non-string column {name}")
        self._char_cache[name] = mat
        return mat

    def encode_const(self, name: str, value: str) -> int:
        """Dictionary code for a constant string (−1 if absent)."""
        vocab = self.vocabs[name]
        idx = np.searchsorted(vocab, value)
        if idx < len(vocab) and vocab[idx] == value:
            return int(idx)
        return -1

    def encode_word(self, name: str, word: str) -> int:
        vocab = self.word_vocabs[name]
        idx = np.searchsorted(vocab, word)
        if idx < len(vocab) and vocab[idx] == word:
            return int(idx)
        return -1

    def code_range(self, name: str, prefix: str) -> tuple[int, int]:
        """[lo, hi) code range of vocab entries starting with `prefix`.

        This is the ordered-dictionary lowering of startsWith (§3.4): the
        vocabulary is sorted, so a prefix corresponds to a code interval.
        """
        vocab = self.vocabs[name]
        lo = int(np.searchsorted(vocab, prefix, side="left"))
        hi = int(np.searchsorted(vocab, prefix + "\x7f", side="left"))
        return lo, hi

    def nbytes(self) -> int:
        return int(sum(a.nbytes for a in self.data.values()))


def pad_words(rows: list[list[int]], max_words: int) -> np.ndarray:
    out = np.full((len(rows), max_words), -1, dtype=np.int32)
    for i, r in enumerate(rows):
        r = r[:max_words]
        out[i, : len(r)] = r
    return out
