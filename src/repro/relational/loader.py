"""Data loading: builds the auxiliary, load-time data structures the paper
creates off the critical path (§3.2.1 partitioning, §3.2.3 date indices,
§3.4 string dictionaries, §3.5 hoisted pools).

In the JAX adaptation the structures are:

  * PK-dense access     — primary keys are dense 0-based ranges, so the
                          "1-D partitioned array" of §3.2.1 is the table
                          itself: a FK value *is* the row index (gather).
  * FK CSR partition    — rows clustered by FK value: permutation +
                          offsets over the parent key domain (the 2-D
                          bucket array of §3.2.1, in CSR form).
  * Date clustering     — per (table, date column): row permutation sorted
                          by date + the sorted date vector kept host-side.
                          A date-range predicate is lowered *at staging
                          time* to an exact static row-slice (the TPU-
                          native generalization of the paper's year-bucket
                          skipping — the bucket is exactly the predicate
                          range, so the residual `if` disappears).
  * String dictionaries — CAT columns are ordered-dictionary coded, TEXT
                          columns word-tokenized (built by the generator;
                          the *cost* of building them is measured by
                          `loading_cost()` for the Fig-21 experiment).

All structures are built lazily and cached; `aux_nbytes()` reports their
memory for the Fig-20 experiment.
"""
from __future__ import annotations

import itertools
import time
from typing import Optional

import numpy as np

from repro.relational.schema import ColKind
from repro.relational.table import Table
from repro.relational.tpch import generate

# Monotonic database identity.  `PlanCache` keys entries by this instead of
# `id(db)`: CPython reuses object addresses after garbage collection, so an
# id-based key could silently serve a stale compiled program to a *new*
# database that happened to land on a dead one's address.  The counter never
# repeats within a process (itertools.count.__next__ is atomic under the GIL).
_FINGERPRINTS = itertools.count()


class Database:
    def __init__(self, tables: dict[str, Table]):
        self.fingerprint: int = next(_FINGERPRINTS)
        self._content_fp: Optional[str] = None
        self.tables = tables
        self._fk_csr: dict[tuple[str, str], tuple[np.ndarray, np.ndarray]] = {}
        self._date_cluster: dict[tuple[str, str], tuple[np.ndarray, np.ndarray]] = {}
        self._slice_bounds: dict[tuple, tuple[int, int]] = {}
        self._device_cols: dict[tuple, object] = {}
        self._shard_plans: dict[int, "ShardPlan"] = {}

    # -- construction -------------------------------------------------------
    @classmethod
    def tpch(cls, sf: float = 0.01, seed: int = 0) -> "Database":
        return cls(generate(sf=sf, seed=seed))

    def table(self, name: str) -> Table:
        return self.tables[name]

    def reload(self, tables: dict[str, Table]) -> None:
        """Swap in freshly loaded tables *in place*.

        Data, `Table.stats`, and the selectivity sketches may all differ
        after a reload, so every derived structure is dropped and the
        fingerprint is bumped: a `PlanCache` keyed on the old fingerprint
        treats this object as a brand-new database — stale compiled
        entries AND stale memoized capacity vectors can never be served
        against the new data."""
        self.fingerprint = next(_FINGERPRINTS)
        self._content_fp = None
        self.tables = tables
        self._device_cols.clear()
        self.reset_aux()

    def content_fingerprint(self) -> str:
        """Stable digest of the loaded data, for state that outlives the
        process.  `fingerprint` is a process-local monotonic counter —
        perfect for in-memory cache keys, useless on disk — so persisted
        warm state (`core/persist.py`) is keyed by THIS: a sha256 over
        every table's name, schema, shape, and a strided content sample
        of each column.  A restarted process that loads the same data
        (same generator, same sf/seed) computes the same digest and
        adopts the saved state; different data silently cold-starts.
        Sampling keeps it cheap (~64 probes per column) while still
        catching scale, seed, or schema changes — it is a warm-state
        admission check, not a cryptographic data integrity guarantee."""
        if self._content_fp is None:
            import hashlib

            h = hashlib.sha256()
            for name in sorted(self.tables):
                t = self.tables[name]
                h.update(f"{name}:{t.nrows}".encode())
                for cdef in t.schema.columns:
                    arr = t.data[cdef.name]
                    h.update(f"{cdef.name}:{cdef.kind.value}"
                             f":{arr.dtype}:{arr.shape}".encode())
                    if arr.size:
                        step = max(1, arr.shape[0] // 64)
                        h.update(np.ascontiguousarray(arr[::step]).tobytes())
            self._content_fp = h.hexdigest()[:16]
        return self._content_fp

    # -- physical co-partitioning (§3.2.1 over a device mesh) ----------------
    def shard_plan(self, n: int) -> "ShardPlan":
        """The co-partitioning layout for an `n`-shard data mesh (cached:
        partitioned column copies are shared by every compile at this
        shard count)."""
        got = self._shard_plans.get(n)
        if got is None:
            got = self._shard_plans[n] = ShardPlan(self, n)
        return got

    # -- partitioning (§3.2.1) ----------------------------------------------
    def fk_csr(self, table: str, col: str) -> tuple[np.ndarray, np.ndarray]:
        """(perm, offsets): rows of `table` clustered by FK `col`.

        offsets has len = parent_domain+1; bucket k is perm[offsets[k]:offsets[k+1]].
        """
        key = (table, col)
        if key not in self._fk_csr:
            t = self.tables[table]
            fk = t.schema.fk_for(col)
            if fk is None:
                raise ValueError(f"{table}.{col} is not a declared foreign key")
            domain = self.tables[fk.ref_table].nrows
            vals = t.data[col]
            perm = np.argsort(vals, kind="stable").astype(np.int32)
            counts = np.bincount(vals, minlength=domain)
            offsets = np.zeros(domain + 1, dtype=np.int32)
            np.cumsum(counts, out=offsets[1:])
            self._fk_csr[key] = (perm, offsets)
        return self._fk_csr[key]

    def fk_bucket(self, table: str, col: str) -> tuple[np.ndarray, int]:
        """The paper's 2-D partitioned array for composite primary keys:
        (domain, W) row-id matrix (−1 padding) bucketed by FK `col`, W =
        max bucket population.  A composite-key join probes the bucket of
        the first key and discriminates on the second (§3.2.1)."""
        perm, offsets = self.fk_csr(table, col)
        counts = np.diff(offsets)
        w = int(counts.max()) if len(counts) else 1
        domain = len(offsets) - 1
        mat = np.full((domain, w), -1, dtype=np.int32)
        for slot in range(w):
            has = counts > slot
            mat[has, slot] = perm[offsets[:-1][has] + slot]
        return mat, w

    # -- date clustering (§3.2.3) --------------------------------------------
    def date_cluster(self, table: str, col: str) -> tuple[np.ndarray, np.ndarray]:
        """(perm, sorted_dates): rows clustered (sorted) by the date column."""
        key = (table, col)
        if key not in self._date_cluster:
            t = self.tables[table]
            vals = t.data[col]
            perm = np.argsort(vals, kind="stable").astype(np.int32)
            self._date_cluster[key] = (perm, vals[perm])
        return self._date_cluster[key]

    def date_slice(self, table: str, col: str, lo: Optional[int],
                   hi: Optional[int]) -> tuple[np.ndarray, int, int]:
        """Static [start, end) over the date-clustered permutation covering
        lo <= date < hi.  Resolved at staging time (host-side binary search),
        so the compiled query carries no date comparison at all."""
        perm, sdates = self.date_cluster(table, col)
        key = (table, col, lo, hi)
        bounds = self._slice_bounds.get(key)
        if bounds is None:
            # cached: the analysis layer re-derives slice cardinalities on
            # every optimize, and the binary search dominates its profile
            start = 0 if lo is None else int(np.searchsorted(sdates, lo, side="left"))
            end = len(sdates) if hi is None else int(np.searchsorted(sdates, hi, side="left"))
            bounds = self._slice_bounds[key] = (start, end)
        return perm, bounds[0], bounds[1]

    # -- memory accounting (Fig 20) -------------------------------------------
    def base_nbytes(self) -> int:
        return sum(t.nbytes() for t in self.tables.values())

    def aux_nbytes(self) -> int:
        n = 0
        for perm, offsets in self._fk_csr.values():
            n += perm.nbytes + offsets.nbytes
        for perm, sdates in self._date_cluster.values():
            n += perm.nbytes + sdates.nbytes
        for t in self.tables.values():
            n += sum(m.nbytes for m in t._char_cache.values())
        return n

    def reset_aux(self) -> None:
        self._fk_csr.clear()
        self._date_cluster.clear()
        self._slice_bounds.clear()
        self._shard_plans.clear()
        for t in self.tables.values():
            t._char_cache.clear()


class ShardPlan:
    """Physical co-partitioning layout for one shard count (§3.2.1 made
    physical over a 1-D device mesh).

    Policy (schema-driven, no per-query decisions): the largest table's
    largest FK parent becomes the partition **root** — it is row-range
    partitioned by its dense PK, shard s owning rows [s*P, (s+1)*P) with
    P = ceil(nrows/n).  Every table holding a declared FK to the root is
    **routed**: its rows are sent to the shard that owns their parent row
    (`owner = fk // P`), so a PK/FK join between a routed child and the
    root never crosses shards.  Everything else is replicated.  On TPC-H
    this partitions orders (root) + lineitem (routed) — the two tables
    that dominate memory — and replicates the dimension tables.

    Physical layout contract (what shard_map and the Exchange operator
    rely on):

      * root — columns are padded to n*P rows by repeating row 0 at the
        tail; padded position == global row id for every real row, so a
        tiled all-gather reconstitutes global positional order and
        parent-table *alignment* survives an Exchange.
      * routed — rows are stably grouped by owner, each shard's block
        padded to the max per-shard population L; a validity mask marks
        pad rows.  Row order is permuted (alignment is lost), which is
        sound because no routed table ever serves as a positional build
        side — only parents do, and parents are either the root or
        replicated.

    Pad rows repeat a real row, so every operator treats them like any
    other masked-out row — no NaN/sentinel hazards."""

    def __init__(self, db: Database, n: int):
        if n < 2:
            raise ValueError("ShardPlan needs n >= 2")
        self.db = db
        self.n = int(n)
        tables = db.tables
        child = max(tables, key=lambda name: tables[name].nrows)
        parents = [fk.ref_table for fk in tables[child].schema.foreign_keys]
        self.root = (max(parents, key=lambda name: tables[name].nrows)
                     if parents else child)
        # P: root rows per shard (ceil)
        self.block = -(-tables[self.root].nrows // self.n)
        self.route_fk: dict[str, str] = {}
        for tname, t in tables.items():
            if tname == self.root:
                continue
            for fk in t.schema.foreign_keys:
                if fk.ref_table == self.root:
                    self.route_fk[tname] = fk.column
                    break
        self._index: dict[str, tuple[np.ndarray, np.ndarray]] = {}
        self._cache: dict[tuple[str, str], np.ndarray] = {}

    def part_of(self, table: str) -> Optional[str]:
        """Partition root when `table` is partitioned, else None."""
        if table == self.root or table in self.route_fk:
            return self.root
        return None

    def rows_per_shard(self, table: str) -> Optional[int]:
        """Static padded per-shard row count (None when replicated)."""
        if table == self.root:
            return self.block
        if table in self.route_fk:
            return self._routed_index(table)[1].shape[0] // self.n
        return None

    def _routed_index(self, table: str) -> tuple[np.ndarray, np.ndarray]:
        """(idx, valid) of length n*L: position s*L+j of a partitioned
        column is row idx[s*L+j] of the base table, pad where ~valid."""
        got = self._index.get(table)
        if got is None:
            t = self.db.tables[table]
            owner = t.data[self.route_fk[table]] // self.block
            perm = np.argsort(owner, kind="stable").astype(np.int64)
            counts = np.bincount(owner, minlength=self.n)
            width = max(int(counts.max()) if len(counts) else 0, 1)
            starts = np.zeros(self.n, dtype=np.int64)
            np.cumsum(counts[:-1], out=starts[1:])
            j = np.arange(self.n * width)
            s, off = j // width, j % width
            valid = off < counts[s]
            src = np.minimum(starts[s] + off, max(t.nrows - 1, 0))
            idx = np.where(valid, perm[src], 0)
            got = self._index[table] = (idx, valid)
        return got

    def partition(self, table: str, arr: np.ndarray) -> np.ndarray:
        """Padded partitioned copy of a per-row array (axis 0 = rows)."""
        arr = np.asarray(arr)
        if table == self.root:
            pad = self.n * self.block - arr.shape[0]
            if pad <= 0:
                return arr
            return np.concatenate(
                [arr, np.repeat(arr[:1], pad, axis=0)], axis=0)
        idx, _ = self._routed_index(table)
        return arr[idx]

    def col(self, table: str, key: str, thunk) -> np.ndarray:
        """Memoized `partition(table, thunk())` — one partitioned copy per
        (table, column key) shared across compiles."""
        ck = (table, key)
        got = self._cache.get(ck)
        if got is None:
            got = self._cache[ck] = self.partition(table, thunk())
        return got

    def valid_mask(self, table: str) -> np.ndarray:
        if table == self.root:
            n = self.db.tables[self.root].nrows
            return np.arange(self.n * self.block) < n
        return self._routed_index(table)[1]

    def nbytes(self) -> int:
        n = sum(a.nbytes for a in self._cache.values())
        for idx, valid in self._index.values():
            n += idx.nbytes + valid.nbytes
        return n


def loading_cost(db: Database, *, string_dict: bool, partition: bool,
                 date_index: bool) -> float:
    """Measure the load-time overhead of each optimization (Fig 21).

    The generator hands us dictionary codes natively, so "building the
    dictionary" is free and "NOT using it" costs a char-matrix
    materialization; to charge costs the way the paper does we measure the
    *decode + re-encode* round trip for dictionaries and the actual
    clustering builds for partitions/date indices.
    """
    t0 = time.perf_counter()
    if string_dict:
        for t in db.tables.values():
            for cdef in t.schema.columns:
                if cdef.kind == ColKind.CAT:
                    # two-phase ordered dictionary build (§3.4): distinct,
                    # sort, then second pass assigning codes.
                    chars = t.char_matrix(cdef.name)
                    view = chars.view([("", chars.dtype)] * chars.shape[1]).ravel()
                    uniq, codes = np.unique(view, return_inverse=True)
                    del uniq, codes
                elif cdef.kind == ColKind.TEXT:
                    # word-tokenizing dictionary: tokenize every row.
                    chars = t.char_matrix(cdef.name)
                    is_space = chars == ord(" ")
                    np.count_nonzero(is_space, axis=1)
    if partition:
        for tname, t in db.tables.items():
            for fk in t.schema.foreign_keys:
                db.fk_csr(tname, fk.column)
    if date_index:
        for tname, t in db.tables.items():
            for cdef in t.schema.columns:
                if cdef.kind == ColKind.DATE:
                    db.date_cluster(tname, cdef.name)
    return time.perf_counter() - t0
