"""Assigned architecture configs (one module per arch, exact published
numbers) + reduced smoke variants.  `get_config(name)` / `smoke_config(name)`."""
from __future__ import annotations

import importlib

ARCHS = [
    "qwen1_5_0_5b", "chatglm3_6b", "phi3_medium_14b", "h2o_danube3_4b",
    "seamless_m4t_large_v2", "deepseek_v2_236b", "granite_moe_1b_a400m",
    "internvl2_76b", "xlstm_125m", "jamba_v0_1_52b",
]

# arch id -> shapes it skips, with reason (DESIGN.md §Arch-applicability)
SKIPS: dict[str, dict[str, str]] = {
    "qwen1_5_0_5b": {"long_500k": "pure full attention (O(S^2) prefill; 500k KV infeasible)"},
    "chatglm3_6b": {"long_500k": "pure full attention"},
    "phi3_medium_14b": {"long_500k": "pure full attention"},
    "seamless_m4t_large_v2": {"long_500k": "full-attention enc-dec"},
    "deepseek_v2_236b": {"long_500k": "MLA is still full attention"},
    "granite_moe_1b_a400m": {"long_500k": "pure full attention"},
    "internvl2_76b": {"long_500k": "pure full attention"},
    # h2o_danube3 (SWA), xlstm (SSM), jamba (hybrid) run long_500k.
}


def get_config(name: str):
    return importlib.import_module(f"repro.configs.{name}").CONFIG


def smoke_config(name: str):
    return importlib.import_module(f"repro.configs.{name}").SMOKE


def shapes_for(name: str) -> list[str]:
    from repro.models.config import SHAPES

    return [s for s in SHAPES if s not in SKIPS.get(name, {})]
