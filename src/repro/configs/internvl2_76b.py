"""InternVL2-76B [arXiv:2404.16821]: InternLM2-76B language backbone; the
InternViT frontend is a STUB (input_specs supplies 256 precomputed patch
embeddings prepended to the text sequence)."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm", n_layers=80, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=28_672, vocab=128_256, n_patches=256,
)
SMOKE = dataclasses.replace(
    CONFIG, name="internvl-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=256, n_patches=8, dtype="float32")
