"""ChatGLM3-6B [arXiv:2406.12793]: dense, GQA kv=2, 2D (half) RoPE."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense", n_layers=28, d_model=4096,
    n_heads=32, n_kv_heads=2, d_ff=13_696, vocab=65_024,
    rope="half", qkv_bias=True,
)
SMOKE = dataclasses.replace(
    CONFIG, name="chatglm-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=256, dtype="float32")
