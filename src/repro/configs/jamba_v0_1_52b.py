"""Jamba-v0.1 52B [arXiv:2403.19887]: Mamba+attention 7:1 interleave
(attention at position 4 of every 8-layer block), MoE every 2 layers
(16 experts top-2, expert d_ff = 14336)."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14_336, vocab=65_536,
    pattern=("mamba", "mamba", "mamba", "mamba", "attn",
             "mamba", "mamba", "mamba"),
    moe=True, n_experts=16, topk=2, moe_d_ff=14_336, moe_every=2,
)
SMOKE = dataclasses.replace(
    CONFIG, name="jamba-smoke", n_layers=8, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, n_experts=4, topk=2, moe_d_ff=64, vocab=256,
    dtype="float32")
