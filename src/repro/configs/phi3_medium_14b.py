"""Phi-3-medium-14B [arXiv:2404.14219]: dense, GQA kv=10, RoPE, SwiGLU."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b", family="dense", n_layers=40, d_model=5120,
    n_heads=40, n_kv_heads=10, d_ff=17_920, vocab=100_352,
)
SMOKE = dataclasses.replace(
    CONFIG, name="phi3-smoke", n_layers=2, d_model=80, n_heads=4,
    n_kv_heads=2, d_ff=160, vocab=256, dtype="float32")
