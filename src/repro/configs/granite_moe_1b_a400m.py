"""Granite-3.0-1B-A400M [hf:ibm-granite]: 32 experts top-8, expert d_ff=512."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=8, d_ff=0, vocab=49_155,
    moe=True, n_experts=32, topk=8, moe_d_ff=512,
)
SMOKE = dataclasses.replace(
    CONFIG, name="granite-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, n_experts=4, topk=2, moe_d_ff=32, vocab=256,
    dtype="float32")
