"""DeepSeek-V2 236B [arXiv:2405.04434]: MLA (kv_lora=512, q_lora=1536,
decoupled rope 64, v=128) + MoE (2 shared + 160 routed, top-6, expert
d_ff=1536).  All layers MoE (the real model's first dense layer is folded
into the uniform scan — noted in DESIGN.md)."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe", n_layers=60, d_model=5120,
    n_heads=128, n_kv_heads=128, d_ff=0, vocab=102_400,
    head_dim=128, pattern=("mla",), mla=True, kv_lora=512, q_lora=1536,
    rope_dim=64, v_head_dim=128,
    moe=True, n_experts=160, topk=6, n_shared_experts=2, moe_d_ff=1536,
)
SMOKE = dataclasses.replace(
    CONFIG, name="deepseek-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, head_dim=16, kv_lora=32, q_lora=48, rope_dim=8,
    v_head_dim=16, n_experts=4, topk=2, n_shared_experts=1, moe_d_ff=32,
    vocab=256, dtype="float32")
