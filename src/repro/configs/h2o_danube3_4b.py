"""H2O-Danube3-4B [arXiv:2401.16818]: llama+mistral mix, sliding-window
attention — the SWA bound makes the long_500k decode cell feasible."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube3-4b", family="dense", n_layers=24, d_model=3840,
    n_heads=32, n_kv_heads=8, d_ff=10_240, vocab=32_000,
    attn="swa", window=4096,
)
SMOKE = dataclasses.replace(
    CONFIG, name="danube-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=256, window=8, dtype="float32")
