"""SeamlessM4T-large-v2 [arXiv:2308.11596]: enc-dec multimodal backbone.
The 24 layers split 12 encoder + 12 decoder; the speech frontend is a STUB
(input_specs supplies precomputed frame embeddings at seq_len/4 frames)."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec", n_layers=12,
    encoder_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=256_206, mlp="gelu",
)
SMOKE = dataclasses.replace(
    CONFIG, name="seamless-smoke", n_layers=2, encoder_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab=256, dtype="float32")
