"""xLSTM-125M [arXiv:2405.04517]: mLSTM + sLSTM blocks (3:1), d_ff=0 (the
cells carry their own projections).  Recurrent state is O(1) in sequence
length, so all long-context cells run."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm", n_layers=12, d_model=768,
    n_heads=4, n_kv_heads=4, d_ff=0, vocab=50_304,
    pattern=("mlstm", "mlstm", "mlstm", "slstm"), rope="none",
)
SMOKE = dataclasses.replace(
    CONFIG, name="xlstm-smoke", n_layers=4, d_model=64, n_heads=4,
    n_kv_heads=4, vocab=256, dtype="float32")
