"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory with
recurrent gate connections), with exponential gating + stabilizer state,
following arXiv:2405.04517.

Training uses `lax.scan` over time (the recurrences are inherently
sequential for sLSTM; mLSTM's chunkwise-parallel form is a recorded
optimization item).  Decode is the O(1) per-step recurrence, which is why
the xlstm/jamba architectures run the long_500k cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


# ---------------------------------------------------------------------- mLSTM

def mlstm_init(key, cfg, dtype):
    d, h = cfg.d_model, cfg.n_heads
    hd = d // h
    ks = jax.random.split(key, 8)
    return {
        "w_q": dense_init(ks[0], (d, d), dtype),
        "w_k": dense_init(ks[1], (d, d), dtype),
        "w_v": dense_init(ks[2], (d, d), dtype),
        "w_i": dense_init(ks[3], (d, h), jnp.float32),
        "w_f": dense_init(ks[4], (d, h), jnp.float32),
        "w_o": dense_init(ks[5], (d, d), dtype),
        "w_out": dense_init(ks[6], (d, d), dtype),
        "f_bias": jnp.ones((h,), jnp.float32) * 3.0,
    }


def _mlstm_step(p, state, qkvif):
    c, n, m = state                       # (B,H,hd,hd), (B,H,hd), (B,H)
    q, k, v, ig, fg = qkvif               # q/k/v: (B,H,hd); ig/fg: (B,H)
    m_new = jnp.maximum(fg + m, ig)
    i_p = jnp.exp(ig - m_new)[..., None]
    f_p = jnp.exp(fg + m - m_new)[..., None]
    c = f_p[..., None] * c + i_p[..., None] * (v[..., :, None] * k[..., None, :])
    n = f_p * n + i_p * k
    num = jnp.einsum("bhvk,bhk->bhv", c, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)), 1.0)
    return (c, n, m_new), num / den[..., None]


def _mlstm_proj(x, p, cfg):
    b = x.shape[0]
    h = cfg.n_heads
    hd = cfg.d_model // h
    shape = x.shape[:-1] + (h, hd)
    q = (x @ p["w_q"]).reshape(shape).astype(jnp.float32)
    k = (x @ p["w_k"]).reshape(shape).astype(jnp.float32) * (hd ** -0.5)
    v = (x @ p["w_v"]).reshape(shape).astype(jnp.float32)
    ig = x.astype(jnp.float32) @ p["w_i"]
    fg = jax.nn.log_sigmoid(x.astype(jnp.float32) @ p["w_f"] + p["f_bias"])
    return q, k, v, ig, fg


def mlstm_parallel(x, p, cfg):
    """Quadratic (chunk-free) parallel form of the mLSTM recurrence — the
    xLSTM paper's training formulation.  Used for the dry-run cost probes
    (every FLOP visible to HloCostAnalysis) and as the fast training path
    for short sequences."""
    b, s, d = x.shape
    q, k, v, ig, fg = _mlstm_proj(x, p, cfg)          # (B,S,H,hd)/(B,S,H)
    q, k, v = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
    ig, fg = ig.transpose(0, 2, 1), fg.transpose(0, 2, 1)   # (B,H,S)
    lcum = jnp.cumsum(fg, axis=-1)                    # log forget prefix
    a = ig - lcum
    m = lcum + jax.lax.cummax(a, axis=a.ndim - 1)     # stabilizer per step
    logd = (lcum[..., :, None] - lcum[..., None, :]
            + ig[..., None, :] - m[..., :, None])     # (B,H,S,S)
    causal = jnp.tril(jnp.ones((s, s), bool))
    dmat = jnp.where(causal, jnp.exp(logd), 0.0)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * dmat
    den = jnp.maximum(jnp.abs(scores.sum(-1)), jnp.exp(-m))
    y = jnp.einsum("bhqk,bhkd->bhqd", scores / den[..., None], v)
    y = y.transpose(0, 2, 1, 3).reshape(b, s, d).astype(x.dtype)
    o = jax.nn.sigmoid(x @ p["w_o"])
    return (y * o) @ p["w_out"]


def mlstm_forward(x, p, cfg):
    """x: (B,S,D) -> (B,S,D)."""
    if cfg.unroll:
        return mlstm_parallel(x, p, cfg)
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.d_model // cfg.n_heads
    q, k, v, ig, fg = _mlstm_proj(x, p, cfg)

    def step(state, inp):
        return _mlstm_step(p, state, inp)

    c0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    n0 = jnp.zeros((b, h, hd), jnp.float32)
    m0 = jnp.full((b, h), -1e30, jnp.float32)
    xs = tuple(t.transpose(1, 0, 2, 3) if t.ndim == 4 else t.transpose(1, 0, 2)
               for t in (q, k, v, ig, fg))
    _, ys = jax.lax.scan(step, (c0, n0, m0), xs)
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    o = jax.nn.sigmoid(x @ p["w_o"])
    return (y * o) @ p["w_out"]


def mlstm_decode_init(cfg, batch, p):
    h, hd = cfg.n_heads, cfg.d_model // cfg.n_heads
    return {
        "c": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, h, hd), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
    }


def mlstm_decode(x, state, p, cfg):
    q, k, v, ig, fg = _mlstm_proj(x[:, None], p, cfg)
    sel = lambda t: t[:, 0]
    (c, n, m), y = _mlstm_step(
        p, (state["c"], state["n"], state["m"]),
        (sel(q), sel(k), sel(v), sel(ig), sel(fg)))
    y = y.reshape(x.shape).astype(x.dtype)
    o = jax.nn.sigmoid(x @ p["w_o"])
    return (y * o) @ p["w_out"], {"c": c, "n": n, "m": m}


# ---------------------------------------------------------------------- sLSTM

def slstm_init(key, cfg, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "w": dense_init(ks[0], (d, 4 * d), dtype),
        "r": dense_init(ks[1], (d, 4 * d), dtype),
        "b": jnp.zeros((4 * d,), jnp.float32),
        "w_out": dense_init(ks[2], (d, d), dtype),
    }


def _slstm_step(p, state, wx):
    c, n, m, h = state                      # all (B, D) f32
    pre = (wx + h.astype(wx.dtype) @ p["r"]).astype(jnp.float32) + p["b"]
    zi, ii, fi, oi = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(zi)
    o = jax.nn.sigmoid(oi)
    lf = jax.nn.log_sigmoid(fi)
    m_new = jnp.maximum(lf + m, ii)
    i_p = jnp.exp(ii - m_new)
    f_p = jnp.exp(lf + m - m_new)
    c = f_p * c + i_p * z
    n = f_p * n + i_p
    h_new = o * c / jnp.maximum(n, 1.0)
    return (c, n, m_new, h_new), h_new


def slstm_forward(x, p, cfg):
    b, s, d = x.shape
    wx = x @ p["w"]

    def step(state, inp):
        return _slstm_step(p, state, inp)

    z = jnp.zeros((b, d), jnp.float32)
    _, ys = jax.lax.scan(step, (z, z, z - 1e30, z), wx.transpose(1, 0, 2))
    y = ys.transpose(1, 0, 2).astype(x.dtype)
    return y @ p["w_out"]


def slstm_decode_init(cfg, batch, p):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z, "m": z - 1e30, "h": z}


def slstm_decode(x, state, p, cfg):
    wx = x @ p["w"]
    (c, n, m, h), y = _slstm_step(
        p, (state["c"], state["n"], state["m"], state["h"]), wx)
    return y.astype(x.dtype) @ p["w_out"], {"c": c, "n": n, "m": m, "h": h}
