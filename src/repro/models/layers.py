"""Shared layer primitives: norms, RoPE, MLPs, embeddings, init helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * scale


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 10_000.0, fraction: float = 1.0):
    """Rotary embedding over the leading `fraction` of the head dims.

    x: (..., S, H, hd); positions: broadcastable (..., S).
    fraction=0.5 gives the ChatGLM-style 2D/partial rotary.
    """
    hd = x.shape[-1]
    rot = int(hd * fraction)
    if rot % 2:
        rot -= 1
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    freqs = jnp.asarray(rope_freqs(rot, theta))           # (rot/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, rot/2)
    cos = jnp.cos(ang)[..., None, :]                      # (..., S, 1, rot/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


def mlp_apply(x, p, kind: str):
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
        return h @ p["w_down"]
    h = jax.nn.gelu(x @ p["w_up"])
    return h @ p["w_down"]


def mlp_init(key, d_model: int, d_ff: int, kind: str, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    std = d_model ** -0.5
    p = {
        "w_up": jax.random.normal(k1, (d_model, d_ff), dtype) * std,
        "w_down": jax.random.normal(k2, (d_ff, d_model), dtype) * (d_ff ** -0.5),
    }
    if kind == "swiglu":
        p["w_gate"] = jax.random.normal(k3, (d_model, d_ff), dtype) * std
    return p


def dense_init(key, shape, dtype, scale_axis: int = 0):
    std = shape[scale_axis] ** -0.5
    return jax.random.normal(key, shape, dtype) * std
