"""Composable block stacks for all assigned architecture families.

A model is `embed -> scan over pattern-repeats -> final norm -> lm head`.
Each repeat applies the config's block `pattern` (e.g. Jamba's
mamba/attn/MoE interleave) with per-position parameters stacked over
repeats, so the HLO contains ONE copy of each block kind regardless of
depth — essential for 512-device dry-run compile times.

Modes: train/encode (full sequence), prefill (full sequence + emits KV /
state caches), decode (single token + cache update).  Remat
(`jax.checkpoint`) wraps the repeat body in training.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models import xlstm as XL
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, mlp_apply, mlp_init, rms_norm
from repro.models.sharding import Ctx, batch_spec
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _attn_init(key, cfg: ModelConfig, dtype):
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "w_q": dense_init(ks[0], (d, h * hd), dtype),
        "w_k": dense_init(ks[1], (d, hkv * hd), dtype),
        "w_v": dense_init(ks[2], (d, hkv * hd), dtype),
        "w_o": dense_init(ks[3], (h * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["b_q"] = jnp.zeros((h * hd,), dtype)
        p["b_k"] = jnp.zeros((hkv * hd,), dtype)
        p["b_v"] = jnp.zeros((hkv * hd,), dtype)
    return p


def _mla_init(key, cfg: ModelConfig, dtype):
    d, h = cfg.d_model, cfg.n_heads
    nope, rd, dv = cfg.hd, cfg.rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    return {
        "w_dq": dense_init(ks[0], (d, cfg.q_lora), dtype),
        "q_ln": jnp.ones((cfg.q_lora,), dtype),
        "w_uq": dense_init(ks[1], (cfg.q_lora, h * (nope + rd)), dtype),
        "w_dkv": dense_init(ks[2], (d, cfg.kv_lora + rd), dtype),
        "kv_ln": jnp.ones((cfg.kv_lora,), dtype),
        "w_uk": dense_init(ks[3], (cfg.kv_lora, h, nope), dtype),
        "w_uv": dense_init(ks[4], (cfg.kv_lora, h, dv), dtype),
        "w_o": dense_init(ks[5], (h * dv, d), dtype),
    }


def _block_init(key, cfg: ModelConfig, kind: str, is_moe: bool, dtype,
                cross: bool = False):
    ks = jax.random.split(key, 5)
    p: dict[str, Any] = {"ln1": jnp.ones((cfg.d_model,), dtype)}
    if kind == "attn":
        p["mixer"] = _attn_init(ks[0], cfg, dtype)
    elif kind == "mla":
        p["mixer"] = _mla_init(ks[0], cfg, dtype)
    elif kind == "mamba":
        p["mixer"] = SSM.mamba_init(ks[0], cfg, dtype)
    elif kind == "mlstm":
        p["mixer"] = XL.mlstm_init(ks[0], cfg, dtype)
    elif kind == "slstm":
        p["mixer"] = XL.slstm_init(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    if cross:
        p["cross"] = _attn_init(ks[2], cfg, dtype)
        p["ln_cross"] = jnp.ones((cfg.d_model,), dtype)
    if kind in ("attn", "mla", "mamba") and (cfg.d_ff > 0 or is_moe):
        p["ln2"] = jnp.ones((cfg.d_model,), dtype)
        p["ffn"] = (MOE.moe_init(ks[1], cfg, dtype) if is_moe
                    else mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp, dtype))
    return p


def _pattern_info(cfg: ModelConfig):
    plen = len(cfg.pattern)
    assert cfg.n_layers % plen == 0, (cfg.name, cfg.n_layers, plen)
    if cfg.moe:
        assert plen % cfg.moe_every == 0 or cfg.moe_every % plen == 0 or plen == 1
    reps = cfg.n_layers // plen
    moe_flags = [cfg.is_moe_layer(j) for j in range(plen)]
    return plen, reps, moe_flags


def init_params(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    plen, reps, moe_flags = _pattern_info(cfg)
    keys = jax.random.split(key, 8)
    cross = cfg.encoder_layers > 0
    blocks = []
    for j in range(plen):
        bkeys = jax.random.split(jax.random.fold_in(keys[0], j), reps)
        blocks.append(jax.vmap(
            lambda k: _block_init(k, cfg, cfg.pattern[j], moe_flags[j],
                                  dtype, cross=cross))(bkeys))
    params: dict[str, Any] = {
        "embed": dense_init(keys[1], (cfg.vocab, cfg.d_model), dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "blocks": tuple(blocks),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[2], (cfg.d_model, cfg.vocab), dtype)
    if cfg.encoder_layers > 0:
        ekeys = jax.random.split(keys[3], cfg.encoder_layers)
        params["encoder"] = {
            "blocks": (jax.vmap(
                lambda k: _block_init(k, cfg, "attn", False, dtype))(ekeys),),
            "final_norm": jnp.ones((cfg.d_model,), dtype),
        }
    return params


# ---------------------------------------------------------------------------
# cache structure
# ---------------------------------------------------------------------------

def _block_cache_struct(cfg: ModelConfig, kind: str, batch: int, smax: int,
                        s_enc: int, cross: bool, dtype):
    hkv, hd = cfg.n_kv_heads, cfg.hd
    di = cfg.ssm_expand * cfg.d_model
    h = cfg.n_heads
    hd_m = cfg.d_model // cfg.n_heads
    if kind == "attn":
        c = {"k": ((batch, smax, hkv, hd), dtype),
             "v": ((batch, smax, hkv, hd), dtype)}
    elif kind == "mla":
        c = {"ckv": ((batch, smax, cfg.kv_lora), dtype),
             "kpe": ((batch, smax, cfg.rope_dim), dtype)}
    elif kind == "mamba":
        c = {"h": ((batch, di, cfg.ssm_state), jnp.float32),
             "conv": ((batch, cfg.ssm_conv - 1, di), dtype)}
    elif kind == "mlstm":
        c = {"c": ((batch, h, hd_m, hd_m), jnp.float32),
             "n": ((batch, h, hd_m), jnp.float32),
             "m": ((batch, h), jnp.float32)}
    elif kind == "slstm":
        d = cfg.d_model
        c = {"c": ((batch, d), jnp.float32), "n": ((batch, d), jnp.float32),
             "m": ((batch, d), jnp.float32), "h": ((batch, d), jnp.float32)}
    else:
        raise ValueError(kind)
    if cross:
        c["ck"] = ((batch, s_enc, hkv, hd), dtype)
        c["cv"] = ((batch, s_enc, hkv, hd), dtype)
    return c


def cache_struct(cfg: ModelConfig, batch: int, smax: int,
                 s_enc: int = 0) -> Any:
    """Pytree of ShapeDtypeStructs for the decode cache."""
    plen, reps, _ = _pattern_info(cfg)
    dtype = jnp.dtype(cfg.dtype)
    cross = cfg.encoder_layers > 0
    out = []
    for j in range(plen):
        c = _block_cache_struct(cfg, cfg.pattern[j], batch, smax, s_enc,
                                cross, dtype)
        out.append(jax.tree.map(
            lambda sd: jax.ShapeDtypeStruct((reps,) + sd[0], sd[1]),
            c, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
            and isinstance(x[0], tuple)))
    return tuple(out)


def init_cache(cfg: ModelConfig, batch: int, smax: int, s_enc: int = 0):
    return jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype),
                        cache_struct(cfg, batch, smax, s_enc),
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

def _rope_frac(cfg):
    return {"default": 1.0, "half": 0.5, "none": 0.0}[cfg.rope]


def _qkv(x, p, cfg, positions):
    b = x.shape[0]
    s = x.shape[1]
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["w_q"] + (p["b_q"] if "b_q" in p else 0)
    k = x @ p["w_k"] + (p["b_k"] if "b_k" in p else 0)
    v = x @ p["w_v"] + (p["b_v"] if "b_v" in p else 0)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    fr = _rope_frac(cfg)
    if fr > 0:
        q = _ap(q, positions, cfg, fr)
        k = _ap(k, positions, cfg, fr)
    return q, k, v


def _ap(t, positions, cfg, fr):
    from repro.models.layers import apply_rope

    return apply_rope(t, positions, theta=cfg.rope_theta, fraction=fr)


def _attn_full(x, p, cfg, ctx, positions, causal):
    window = cfg.window if cfg.attn == "swa" else None
    q, k, v = _qkv(x, p, cfg, positions)
    out = A.blockwise_attention(
        q, k, v, causal=causal, window=window,
        unroll=cfg.unroll and cfg.attn_impl == "naive")
    out = out.reshape(x.shape[0], x.shape[1], -1) @ p["w_o"]
    return out, (k, v)


def _attn_decode(x, p, cfg, cache, pos):
    b = x.shape[0]
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    positions = jnp.asarray(pos)[None]
    q, k, v = _qkv(x[:, None], p, cfg, positions)
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k, (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v, (0, pos, 0, 0))
    window = cfg.window if cfg.attn == "swa" else None
    out = A.decode_attention(q[:, 0], k_cache, v_cache, pos + 1, window=window)
    out = out.reshape(b, -1) @ p["w_o"]
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = k_cache, v_cache
    return out, new_cache


def _mla_proj_q(x, p, cfg):
    b, s = x.shape[0], x.shape[1]
    h, nope, rd = cfg.n_heads, cfg.hd, cfg.rope_dim
    cq = rms_norm(x @ p["w_dq"], p["q_ln"])
    q = (cq @ p["w_uq"]).reshape(b, s, h, nope + rd)
    return q[..., :nope], q[..., nope:]


def _mla_full(x, p, cfg, ctx, positions, causal):
    b, s = x.shape[0], x.shape[1]
    h, nope, rd, dv = cfg.n_heads, cfg.hd, cfg.rope_dim, cfg.v_head_dim
    q_nope, q_pe = _mla_proj_q(x, p, cfg)
    q_pe = _ap(q_pe, positions, cfg, 1.0)
    ckv_full = x @ p["w_dkv"]
    ckv, kpe = ckv_full[..., :cfg.kv_lora], ckv_full[..., cfg.kv_lora:]
    ckv_n = rms_norm(ckv, p["kv_ln"])
    kpe = _ap(kpe[:, :, None, :], positions, cfg, 1.0)[:, :, 0]
    k_nope = jnp.einsum("bsl,lhn->bshn", ckv_n, p["w_uk"])
    v = jnp.einsum("bsl,lhn->bshn", ckv_n, p["w_uv"])
    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(kpe[:, :, None], (b, s, h, rd))],
                        axis=-1)
    out = A.blockwise_attention(
        q, k, v, causal=causal,
        unroll=cfg.unroll and cfg.attn_impl == "naive")
    out = out.reshape(b, s, -1) @ p["w_o"]
    return out, (ckv_n, kpe)


def _mla_decode(x, p, cfg, cache, pos):
    b = x.shape[0]
    h, nope, rd, dv = cfg.n_heads, cfg.hd, cfg.rope_dim, cfg.v_head_dim
    positions = jnp.asarray(pos)[None]
    q_nope, q_pe = _mla_proj_q(x[:, None], p, cfg)
    q_pe = _ap(q_pe, positions, cfg, 1.0)[:, 0]
    q_nope = q_nope[:, 0]
    ckv_full = x @ p["w_dkv"]
    ckv, kpe = ckv_full[..., :cfg.kv_lora], ckv_full[..., cfg.kv_lora:]
    ckv_n = rms_norm(ckv, p["kv_ln"])
    kpe = _ap(kpe[:, None, None, :], positions, cfg, 1.0)[:, 0, 0]
    ckv_cache = jax.lax.dynamic_update_slice(cache["ckv"], ckv_n[:, None],
                                             (0, pos, 0))
    kpe_cache = jax.lax.dynamic_update_slice(cache["kpe"], kpe[:, None],
                                             (0, pos, 0))
    # absorbed attention against the compressed cache
    q_abs = jnp.einsum("bhn,lhn->bhl", q_nope.astype(jnp.float32),
                       p["w_uk"].astype(jnp.float32))
    w = A.mla_decode_scores(q_abs, q_pe.astype(jnp.float32),
                            ckv_cache.astype(jnp.float32),
                            kpe_cache.astype(jnp.float32), pos + 1,
                            (nope + rd) ** -0.5)
    out_c = jnp.einsum("bhk,bkl->bhl", w, ckv_cache.astype(jnp.float32))
    out = jnp.einsum("bhl,lhn->bhn", out_c, p["w_uv"].astype(jnp.float32))
    out = out.reshape(b, -1).astype(x.dtype) @ p["w_o"]
    new_cache = dict(cache)
    new_cache["ckv"], new_cache["kpe"] = ckv_cache, kpe_cache
    return out, new_cache


def _cross_attn(x, p, ln, enc_kv, cfg):
    """Cross attention over precomputed encoder K/V."""
    b, s = x.shape[0], x.shape[1]
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    hx = rms_norm(x, ln)
    q = (hx @ p["w_q"]).reshape(b, s, h, hd)
    k, v = enc_kv
    out = A.blockwise_attention(q, k, v, causal=False)
    return out.reshape(b, s, -1) @ p["w_o"]


def block_apply(x, p, kind, cfg, ctx, *, positions, mode, is_moe,
                causal=True, cache=None, pos=None, enc_out=None):
    """Returns (x, new_cache_dict)."""
    h = rms_norm(x, p["ln1"])
    new_cache: dict[str, Any] = {}
    if mode == "decode":
        new_cache = dict(cache)
        if kind == "attn":
            out, new_cache = _attn_decode(h, p["mixer"], cfg, cache, pos)
        elif kind == "mla":
            out, new_cache = _mla_decode(h, p["mixer"], cfg, cache, pos)
        elif kind == "mamba":
            st = {"h": cache["h"], "conv": cache["conv"]}
            out, st = SSM.mamba_decode(h, st, p["mixer"], cfg)
            new_cache.update(st)
        elif kind == "mlstm":
            st = {k_: cache[k_] for k_ in ("c", "n", "m")}
            out, st = XL.mlstm_decode(h, st, p["mixer"], cfg)
            new_cache.update(st)
        elif kind == "slstm":
            st = {k_: cache[k_] for k_ in ("c", "n", "m", "h")}
            out, st = XL.slstm_decode(h, st, p["mixer"], cfg)
            new_cache.update(st)
        x = x + out
        if "cross" in p:
            ck, cv = cache["ck"], cache["cv"]
            out = _cross_attn(x[:, None], p["cross"], p["ln_cross"],
                              (ck, cv), cfg)[:, 0]
            x = x + out
        if "ffn" in p:
            h2 = rms_norm(x, p["ln2"])
            if is_moe:
                f = MOE.moe_ffn(h2[:, None], p["ffn"], cfg, ctx)[:, 0]
            else:
                f = mlp_apply(h2, p["ffn"], cfg.mlp)
            x = x + f
        return x, new_cache

    # ---- full-sequence modes (train / encode / prefill) ---------------------
    if kind == "attn":
        out, (k, v) = _attn_full(h, p["mixer"], cfg, ctx, positions, causal)
        if mode == "prefill":
            new_cache = {"k": k, "v": v}
    elif kind == "mla":
        out, (ckv, kpe) = _mla_full(h, p["mixer"], cfg, ctx, positions, causal)
        if mode == "prefill":
            new_cache = {"ckv": ckv, "kpe": kpe}
    elif kind == "mamba":
        out = SSM.mamba_forward(h, p["mixer"], cfg)
        if mode == "prefill":
            # recompute the decode-entry state cheaply from the tail
            st0 = SSM.mamba_decode_init(cfg, x.shape[0], x.dtype)
            new_cache = st0  # placeholder state; exact state handoff is a
            # serving-layer concern (decode cells start from a given cache)
    elif kind == "mlstm":
        out = XL.mlstm_forward(h, p["mixer"], cfg)
        if mode == "prefill":
            new_cache = XL.mlstm_decode_init(cfg, x.shape[0], p["mixer"])
    elif kind == "slstm":
        out = XL.slstm_forward(h, p["mixer"], cfg)
        if mode == "prefill":
            new_cache = XL.slstm_decode_init(cfg, x.shape[0], p["mixer"])
    x = x + out
    if "cross" in p and enc_out is not None:
        k_enc = (enc_out @ p["cross"]["w_k"]).reshape(
            x.shape[0], enc_out.shape[1], cfg.n_kv_heads, cfg.hd)
        v_enc = (enc_out @ p["cross"]["w_v"]).reshape(
            x.shape[0], enc_out.shape[1], cfg.n_kv_heads, cfg.hd)
        x = x + _cross_attn(x, p["cross"], p["ln_cross"], (k_enc, v_enc), cfg)
        if mode == "prefill":
            new_cache["ck"], new_cache["cv"] = k_enc, v_enc
    if "ffn" in p:
        h2 = rms_norm(x, p["ln2"])
        f = (MOE.moe_ffn(h2, p["ffn"], cfg, ctx) if is_moe
             else mlp_apply(h2, p["ffn"], cfg.mlp))
        x = x + f
    return x, new_cache


# ---------------------------------------------------------------------------
# stacks
# ---------------------------------------------------------------------------

def run_stack(x, blocks, cfg: ModelConfig, ctx: Ctx, *, positions, mode,
              causal=True, caches=None, pos=None, enc_out=None,
              pattern=None, moe_flags=None, remat=False):
    pattern = pattern if pattern is not None else cfg.pattern
    if moe_flags is None:
        _, _, moe_flags = _pattern_info(cfg)

    import os
    block_constraint = os.environ.get("REPRO_BLOCK_CONSTRAINT") == "1"

    def rep_body(carry, inp):
        xx = carry
        rep_params, rep_cache = inp
        new_caches = []
        for j, kind in enumerate(pattern):
            cj = rep_cache[j] if rep_cache is not None else None
            xx, nc = block_apply(xx, rep_params[j], kind, cfg, ctx,
                                 positions=positions, mode=mode,
                                 is_moe=moe_flags[j], causal=causal,
                                 cache=cj, pos=pos, enc_out=enc_out)
            if block_constraint and xx.ndim == 3:
                # §Perf D4: pin the residual stream to (batch over dp,
                # replicated over model) after every block — stops GSPMD
                # resharding churn (f32 activation all-gathers) between
                # differently-sharded weight contractions.
                xx = ctx.constraint(xx, P(batch_spec(ctx), None, None))
            new_caches.append(nc)
        return xx, tuple(new_caches)

    body = jax.checkpoint(rep_body) if remat else rep_body
    xs = (blocks, caches)
    if cfg.unroll:
        reps = jax.tree.leaves(blocks)[0].shape[0]
        outs = []
        for r in range(reps):
            rep_xs = jax.tree.map(lambda t: t[r], xs)
            x, ys = body(x, rep_xs)
            outs.append(ys)
        new_caches = (jax.tree.map(lambda *ts: jnp.stack(ts), *outs)
                      if outs and jax.tree.leaves(outs[0]) else outs[0]
                      if outs else None)
        return x, (new_caches if caches is not None or mode == "prefill"
                   else None)
    x, new_caches = jax.lax.scan(body, x, xs)
    return x, (new_caches if caches is not None or mode == "prefill" else None)


def _embed(params, tokens, cfg, ctx: Ctx, batch_extra=None):
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    if batch_extra is not None:       # vlm patches / prepended embeddings
        x = jnp.concatenate([batch_extra.astype(x.dtype), x], axis=1)
    x = ctx.constraint(x, P(batch_spec(ctx), None, None))
    return x


def _logits(params, x, cfg, ctx: Ctx):
    import os

    x = rms_norm(x, params["final_norm"])
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    if os.environ.get("REPRO_HEAD_RESHARD") == "1" and ctx.mesh is not None:
        # §Perf D-series: the head's contraction (D) dim is FSDP-sharded;
        # left alone, GSPMD psums the full f32 (B,S,V) logits over `data`
        # (~40 GB/dev for qwen) — reshard the *weight* instead (one ~20 MB
        # all-gather) so the contraction dim is local and logits come out
        # model-sharded with no activation collective.
        head = ctx.constraint(head, P(None, ctx.tp_axis))
    logits = x @ head
    return ctx.constraint(logits, P(batch_spec(ctx), None, ctx.tp_axis))


def _encode(params, frames, cfg, ctx):
    positions = jnp.arange(frames.shape[1])
    x = frames.astype(jnp.dtype(cfg.dtype))
    x, _ = run_stack(x, params["encoder"]["blocks"], cfg, ctx,
                     positions=positions, mode="encode", causal=False,
                     caches=None, pattern=("attn",),
                     moe_flags=[False])
    return rms_norm(x, params["encoder"]["final_norm"])


def cast_params(params, cfg: ModelConfig):
    """Cast float params to the compute dtype (differentiable: grads flow
    back to the f32 masters held by the optimizer)."""
    dt = jnp.dtype(cfg.dtype)

    def leaf(p):
        if hasattr(p, "dtype") and jnp.issubdtype(p.dtype, jnp.floating) \
                and p.dtype != dt:
            return p.astype(dt)
        return p

    return jax.tree.map(leaf, params)


def forward_train(params, batch, cfg: ModelConfig, ctx: Ctx):
    """batch: {'tokens': (B,S) int32, optional 'patch_embeds', 'frames'}."""
    params = cast_params(params, cfg)
    tokens = batch["tokens"]
    enc_out = None
    if cfg.encoder_layers > 0:
        enc_out = _encode(params, batch["frames"], cfg, ctx)
    extra = batch.get("patch_embeds")
    x = _embed(params, tokens, cfg, ctx, extra)
    positions = jnp.arange(x.shape[1])
    x, _ = run_stack(x, params["blocks"], cfg, ctx, positions=positions,
                     mode="train", causal=True, caches=None,
                     enc_out=enc_out, remat=True)
    return _logits(params, x, cfg, ctx)


def prefill(params, batch, cfg: ModelConfig, ctx: Ctx):
    params = cast_params(params, cfg)
    tokens = batch["tokens"]
    enc_out = None
    if cfg.encoder_layers > 0:
        enc_out = _encode(params, batch["frames"], cfg, ctx)
    extra = batch.get("patch_embeds")
    x = _embed(params, tokens, cfg, ctx, extra)
    positions = jnp.arange(x.shape[1])
    x, caches = run_stack(x, params["blocks"], cfg, ctx, positions=positions,
                          mode="prefill", causal=True, caches=None,
                          enc_out=enc_out)
    logits = _logits(params, x[:, -1:], cfg, ctx)
    return logits[:, 0], caches


def decode_step(params, token, cache, pos, cfg: ModelConfig, ctx: Ctx):
    """token: (B,) int32; pos: int32 scalar; cache: pytree from
    cache_struct().  Returns (logits (B,V), new cache)."""
    params = cast_params(params, cfg)
    x = jnp.take(params["embed"], token, axis=0).astype(jnp.dtype(cfg.dtype))
    x, new_cache = run_stack(x, params["blocks"], cfg, ctx,
                             positions=None, mode="decode", causal=True,
                             caches=cache, pos=pos)
    logits = _logits(params, x[:, None], cfg, ctx)[:, 0]
    return logits, new_cache
