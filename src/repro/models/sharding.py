"""Sharding context + GSPMD partition specs.

Parallelism layout (DESIGN.md §5):
  * TP   — last dim of every weight matrix over `model` (heads / FFN hidden
           / expert FFN / vocab);
  * FSDP — second-to-last dim over the batch axes (`pod`+`data`): params and
           optimizer state live sharded, XLA all-gathers per layer (ZeRO-3);
  * DP   — batch over (`pod`,`data`).

Specs are rule-based on leaf shapes with divisibility guards, so the same
code shards a 236B MoE and a 125M SSM; KV caches get explicit specs
(batch→data, kv-heads→model, falling back to sequence→data for the
global_batch=1 long-context cell).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class Ctx:
    mesh: Optional[Any] = None
    dp_axes: tuple[str, ...] = ("data",)
    tp_axis: str = "model"

    @property
    def dp_size(self) -> int:
        if self.mesh is None:
            return 1
        return int(__import__("numpy").prod(
            [self.mesh.shape[a] for a in self.dp_axes]))

    @property
    def tp_size(self) -> int:
        return 1 if self.mesh is None else self.mesh.shape[self.tp_axis]

    def constraint(self, x, spec: P):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


def leaf_spec(shape: tuple[int, ...], ctx: Ctx, *, stacked: bool) -> P:
    """Generic FSDP+TP spec for a parameter leaf.

    REPRO_NO_FSDP=1 disables the data-axis (ZeRO) sharding — the right
    call for small models where the per-layer param all-gather costs more
    than the replicated-param memory (a §Perf hillclimb lever)."""
    import os

    if ctx.mesh is None:
        return P()
    nd = len(shape)
    spec: list = [None] * nd
    lo = 1 if stacked else 0       # leading layer-stack dim never sharded
    if nd - lo >= 1 and shape[-1] % ctx.tp_size == 0 and shape[-1] >= ctx.tp_size * 8:
        spec[-1] = ctx.tp_axis
    if (os.environ.get("REPRO_NO_FSDP") != "1" and nd - lo >= 2
            and shape[-2] % ctx.dp_size == 0
            and shape[-2] >= ctx.dp_size * 8):
        spec[-2] = ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]
    return P(*spec)


def param_specs(params, ctx: Ctx):
    """Pytree of PartitionSpecs matching `params`.  Leaves under 'blocks'
    are layer-stacked (leading reps axis)."""

    def rec(tree, stacked: bool):
        if isinstance(tree, dict):
            return {k: rec(v, stacked or k == "blocks") for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            t = [rec(v, stacked) for v in tree]
            return type(tree)(t)
        if hasattr(tree, "shape"):
            return leaf_spec(tuple(tree.shape), ctx, stacked=stacked)
        return P()

    return rec(params, False)


def shardings_for(params, ctx: Ctx):
    if ctx.mesh is None:
        return None
    return jax.tree.map(lambda s: NamedSharding(ctx.mesh, s), param_specs(params, ctx),
                        is_leaf=lambda x: isinstance(x, P))


def batch_spec(ctx: Ctx):
    """The PartitionSpec *entry* for the batch dimension (str or tuple)."""
    return ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]


def cache_spec(shape: tuple[int, ...], batch: int, ctx: Ctx) -> P:
    """KV/state cache leaf spec: (R, B, S, heads, hd)-style layouts.

    Batch shards over dp when divisible; otherwise (global_batch=1 long
    context) the longest remaining dim shards over dp.  Head-like dims
    shard over model when divisible."""
    if ctx.mesh is None:
        return P()
    nd = len(shape)
    spec: list = [None] * nd
    dp = ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]
    dp_used = False
    if nd >= 2 and shape[1] == batch and batch % ctx.dp_size == 0:
        spec[1] = dp
        dp_used = True
    # model axis on the largest remaining divisible dim (prefer later dims:
    # heads / feature); fall back dp onto sequence for batch=1 cells.
    for i in range(nd - 1, 1, -1):
        if spec[i] is None and shape[i] % ctx.tp_size == 0 and shape[i] >= ctx.tp_size:
            spec[i] = ctx.tp_axis
            break
    if not dp_used:
        # shard the longest unsharded dim (the sequence) over dp
        cand = max((i for i in range(1, nd) if spec[i] is None),
                   key=lambda i: shape[i], default=None)
        if cand is not None and shape[cand] % ctx.dp_size == 0:
            spec[cand] = dp
    return P(*spec)
