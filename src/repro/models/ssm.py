"""Mamba-1 selective SSM block (Jamba's sequence mixer).

Training/prefill uses a chunked scan: sequential `lax.scan` over chunks of
the sequence with a parallel `associative_scan` inside each chunk, so the
(B, S, d_inner, d_state) discretized tensors are only ever materialized one
chunk at a time (the whole-sequence version is ~TBs at train_4k scale).
Decode is the O(1) single-step recurrence — this is what makes the
long_500k cell feasible for SSM/hybrid architectures.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def mamba_init(key, cfg, dtype):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    rank = max(1, d // 16)
    ks = jax.random.split(key, 8)
    return {
        "w_in": dense_init(ks[0], (d, 2 * di), dtype),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, di), dtype) * 0.1,
        "conv_b": jnp.zeros((di,), dtype),
        "w_xproj": dense_init(ks[2], (di, rank + 2 * cfg.ssm_state), dtype),
        "w_dt": dense_init(ks[3], (rank, di), dtype),
        "dt_bias": jnp.zeros((di,), dtype),
        "a_log": jnp.log(jnp.tile(jnp.arange(1, cfg.ssm_state + 1,
                                             dtype=jnp.float32), (di, 1))),
        "d_skip": jnp.ones((di,), jnp.float32),
        "w_out": dense_init(ks[4], (di, d), dtype, scale_axis=0),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B,S,di), w: (K,di)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return out + b


def _ssm_params(x1, p, cfg):
    rank = p["w_dt"].shape[0]
    proj = x1 @ p["w_xproj"]
    dt, bmat, cmat = jnp.split(proj, [rank, rank + cfg.ssm_state], axis=-1)
    dt = jax.nn.softplus(dt @ p["w_dt"] + p["dt_bias"]).astype(jnp.float32)
    a = -jnp.exp(p["a_log"])                                  # (di, state)
    return dt, bmat.astype(jnp.float32), cmat.astype(jnp.float32), a


def mamba_forward(x, p, cfg, chunk: int = 16):
    """x: (B, S, D) -> (B, S, D).  Chunked selective scan."""
    b, s, d = x.shape
    if cfg.unroll:
        chunk = max(s // 4, 1)   # few unrolled chunks for the cost probes
    di = cfg.ssm_expand * d
    xz = x @ p["w_in"]
    x1, z = jnp.split(xz, 2, axis=-1)
    x1 = jax.nn.silu(_causal_conv(x1, p["conv_w"], p["conv_b"]))
    dt, bmat, cmat, a = _ssm_params(x1, p, cfg)

    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk

    def to_chunks(t):
        return t.reshape(b, nc, chunk, *t.shape[2:]).transpose(
            1, 0, 2, *range(3, t.ndim + 1))

    x1c, dtc, bc, cc = map(to_chunks, (x1, dt, bmat, cmat))

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    def chunk_step(h, inp):
        x1_, dt_, b_, c_ = inp                        # (B, c, ...)
        da = jnp.exp(dt_[..., None] * a)              # (B,c,di,state)
        dbx = (dt_ * x1_.astype(jnp.float32))[..., None] * b_[:, :, None, :]
        acum, bcum = jax.lax.associative_scan(combine, (da, dbx), axis=1)
        hs = acum * h[:, None] + bcum                 # states at each step
        y = jnp.einsum("bcds,bcs->bcd", hs, c_)
        return hs[:, -1], y

    h0 = jnp.zeros((b, di, cfg.ssm_state), jnp.float32)
    if cfg.unroll:
        # cost probes: straight-line chunk loop (exact HLO accounting)
        hh, ylist = h0, []
        for i in range(nc):
            hh, yc = chunk_step(hh, (x1c[i], dtc[i], bc[i], cc[i]))
            ylist.append(yc)
        ys = jnp.stack(ylist)
    else:
        _, ys = jax.lax.scan(chunk_step, h0, (x1c, dtc, bc, cc))
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, di)
    y = (y + p["d_skip"] * x1.astype(jnp.float32)).astype(x.dtype)
    return (y * jax.nn.silu(z)) @ p["w_out"]


def mamba_decode_init(cfg, batch, dtype):
    di = cfg.ssm_expand * cfg.d_model
    return {
        "h": jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype),
    }


def mamba_decode(x, state, p, cfg):
    """x: (B, D) one token; state: {'h','conv'} -> (y (B,D), new state)."""
    xz = x @ p["w_in"]
    x1, z = jnp.split(xz, 2, axis=-1)
    conv_in = jnp.concatenate([state["conv"], x1[:, None]], axis=1)
    x1 = jax.nn.silu((conv_in * p["conv_w"]).sum(axis=1) + p["conv_b"])
    dt, bmat, cmat, a = _ssm_params(x1[:, None], p, cfg)
    dt, bmat, cmat = dt[:, 0], bmat[:, 0], cmat[:, 0]
    da = jnp.exp(dt[..., None] * a)
    dbx = (dt * x1.astype(jnp.float32))[..., None] * bmat[:, None, :]
    h = da * state["h"] + dbx
    y = jnp.einsum("bds,bs->bd", h, cmat)
    y = (y + p["d_skip"] * x1.astype(jnp.float32)).astype(x.dtype)
    out = (y * jax.nn.silu(z)) @ p["w_out"]
    return out, {"h": h, "conv": conv_in[:, 1:]}
