"""Mixture-of-Experts FFN.

Implementation: capacity-bounded grouped compute with *local* routing.
Tokens are routed per data-shard (inside `shard_map` over the batch axes),
sorted by expert id, and each expert processes a fixed-capacity slice of
the sorted token stream — all static shapes, no host round trips.  Expert
FFN width is sharded over the `model` axis (tensor-parallel experts), so
the only collective is the same per-layer psum a dense FFN needs; the
compiled FLOPs are capacity_factor × active-expert FLOPs (the roofline
table reports MODEL_FLOPS as 6·N_active·D and the ratio exposes the
capacity slack).

An expert-parallel all-to-all variant is the recorded §Perf hillclimb for
the MoE-bound cells (see EXPERIMENTS.md).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense_init


def moe_init(key, cfg, dtype):
    e, d, f = cfg.n_experts, cfg.d_model, cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(key, 8)
    p = {
        "w_router": dense_init(ks[0], (d, e), jnp.float32),
        "w_gate": dense_init(ks[1], (e, d, f), dtype, scale_axis=1),
        "w_up": dense_init(ks[2], (e, d, f), dtype, scale_axis=1),
        "w_down": dense_init(ks[3], (e, f, d), dtype, scale_axis=1),
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * (cfg.moe_d_ff or cfg.d_ff)
        p["shared"] = {
            "w_gate": dense_init(ks[4], (d, fs), dtype),
            "w_up": dense_init(ks[5], (d, fs), dtype),
            "w_down": dense_init(ks[6], (fs, d), dtype, scale_axis=0),
        }
    return p


def _moe_local(x, p, *, topk: int, capacity: int, tp_axis: str | None,
               unroll: bool = False):
    """x: (N, D) local tokens. Expert weights locally (E, D, F_local)."""
    n, d = x.shape
    e = p["w_router"].shape[1]
    logits = x.astype(jnp.float32) @ p["w_router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, ids = jax.lax.top_k(probs, topk)                 # (N, k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    flat_ids = ids.reshape(-1).astype(jnp.int32)             # (N*k,)
    flat_tok = jnp.repeat(jnp.arange(n, dtype=jnp.int32), topk)
    flat_w = gate_w.reshape(-1).astype(jnp.float32)
    order = jnp.argsort(flat_ids)
    s_ids = jnp.pad(flat_ids[order], (0, capacity), constant_values=-1)
    s_tok = jnp.pad(flat_tok[order], (0, capacity))
    s_w = jnp.pad(flat_w[order], (0, capacity))
    counts = jnp.bincount(flat_ids, length=e)
    offsets = jnp.concatenate([jnp.zeros(1, jnp.int32),
                               jnp.cumsum(counts).astype(jnp.int32)])

    import os

    # §Perf knob: experts per scan step.  group=1 scatters the (N, D)
    # accumulator once per expert (E full traversals); larger groups batch
    # G experts' contributions into one scatter (E/G traversals).
    group = int(os.environ.get("REPRO_MOE_GROUP", "1"))
    group = max(1, min(group, e))
    while e % group:
        group -= 1

    def body(acc, einp):
        eids, wgs, wus, wds = einp
        # one gather for the whole group: the backward of this gather is a
        # single scatter into dx (instead of one per expert) — with group=1
        # this degenerates to the per-expert baseline.
        idx_l, eid_l, w_l = [], [], []
        for j in range(group):
            start = offsets[eids[j]]
            idx_l.append(jax.lax.dynamic_slice(s_tok, (start,), (capacity,)))
            eid_l.append(jax.lax.dynamic_slice(s_ids, (start,), (capacity,)))
            w_l.append(jax.lax.dynamic_slice(s_w, (start,), (capacity,)))
        cat_idx = jnp.concatenate(idx_l)
        xg = x[cat_idx]                                   # (G·C, D)
        ys = []
        for j in range(group):
            valid = (eid_l[j] == eids[j])
            xe = xg[j * capacity:(j + 1) * capacity] \
                * valid[:, None].astype(x.dtype)
            h = jax.nn.silu(xe @ wgs[j]) * (xe @ wus[j])
            ys.append((h @ wds[j]).astype(jnp.float32)
                      * (w_l[j] * valid)[:, None])
        return acc.at[cat_idx].add(jnp.concatenate(ys)), None

    acc0 = jnp.zeros((n, d), jnp.float32)
    eidx = jnp.arange(e, dtype=jnp.int32).reshape(e // group, group)
    stack = lambda w: w.reshape(e // group, group, *w.shape[1:])
    xs = (eidx, stack(p["w_gate"]), stack(p["w_up"]), stack(p["w_down"]))
    if unroll:
        # straight-line expert loop: exact cost accounting for the dry-run
        # probes (XLA counts while-loop bodies once)
        acc = acc0
        for gstep in range(e // group):
            acc, _ = body(acc, jax.tree.map(lambda t: t[gstep], xs))
    else:
        acc, _ = jax.lax.scan(body, acc0, xs)

    if "shared" in p:
        sp = p["shared"]
        h = jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])
        acc = acc + (h @ sp["w_down"]).astype(jnp.float32)

    if tp_axis is not None:
        acc = jax.lax.psum(acc, tp_axis)   # partial sums over F shards
    return acc.astype(x.dtype)


def moe_ffn(x, p, cfg, ctx):
    """x: (B, S, D). ctx: repro.models.sharding.Ctx (mesh optional)."""
    b, s, d = x.shape

    def run(xl, pl_):
        n = xl.shape[0] * xl.shape[1]
        cap = int(np.ceil(cfg.capacity_factor * n * cfg.topk
                          / max(cfg.n_experts, 1)))
        cap = max(8, -(-cap // 8) * 8)
        y = _moe_local(xl.reshape(n, d), pl_, topk=cfg.topk, capacity=cap,
                       tp_axis=ctx.tp_axis if ctx.mesh is not None else None,
                       unroll=cfg.unroll)
        return y.reshape(xl.shape)

    if ctx.mesh is None:
        return run(x, p)

    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    dp = ctx.dp_axes
    if b % ctx.dp_size != 0:
        # global_batch=1 decode (long_500k): tokens replicate across the
        # batch axes; expert FFN stays TP-sharded over `model`.
        dp = None
    specs_p = {
        "w_router": P(None, None),
        "w_gate": P(None, None, ctx.tp_axis),
        "w_up": P(None, None, ctx.tp_axis),
        "w_down": P(None, ctx.tp_axis, None),
    }
    if "shared" in p:
        specs_p["shared"] = {
            "w_gate": P(None, ctx.tp_axis),
            "w_up": P(None, ctx.tp_axis),
            "w_down": P(ctx.tp_axis, None),
        }
    return shard_map(
        run, mesh=ctx.mesh,
        in_specs=(P(dp, None, None), specs_p),
        out_specs=P(dp, None, None),
        check_rep=False,
    )(x, p)
