"""Attention: blockwise (flash-style) training/prefill attention, GQA,
sliding-window, decode-with-KV-cache, and MLA (DeepSeek-V2).

The blockwise implementation is a pure-JAX double `lax.scan` (outer over
query blocks, inner over KV blocks) with online softmax, so the S×S score
matrix is never materialized — prefill_32k and train_4k fit on chip.
Causality/windowing are handled by masking (the causal half-waste is
visible in the roofline's MODEL_FLOPS/HLO_FLOPs ratio and is a recorded
hillclimb item).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

NEG = np.float32(-1e30)


def _block_attn(q, k, v, qpos, kpos, scale, causal, window):
    """One (q-block, kv-block) tile.  q: (B,bq,Hkv,G,dk) k: (B,bk,Hkv,dk)
    v: (B,bk,Hkv,dv).  Returns scores-softmax partials (m, l, acc)."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    mask = jnp.ones(s.shape[-2:], dtype=bool)
    dpos = qpos[:, None] - kpos[None, :]
    if causal:
        mask &= dpos >= 0
    if window is not None:
        mask &= dpos < window
    s = jnp.where(mask[None, None, None], s, NEG)
    return s


def naive_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None,
                    scale: float | None = None) -> jax.Array:
    """Reference S×S attention (used by the dry-run cost probes: the
    blockwise double-scan is a while loop whose body HloCostAnalysis counts
    once — this form exposes every FLOP to the analyzer)."""
    b, sq, h, dk = q.shape
    hkv = k.shape[2]
    g = h // hkv
    scale = scale if scale is not None else dk ** -0.5
    qg = q.reshape(b, sq, hkv, g, dk)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    qpos = jnp.arange(sq)
    kpos = jnp.arange(k.shape[1])
    mask = jnp.ones((sq, k.shape[1]), dtype=bool)
    dpos = qpos[:, None] - kpos[None, :]
    if causal:
        mask &= dpos >= 0
    if window is not None:
        mask &= dpos < window
    s = jnp.where(mask[None, None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, v.shape[-1]).astype(q.dtype)


def blockwise_attention(q, k, v, *, causal: bool = True,
                        window: int | None = None,
                        q_block: int = 256, kv_block: int = 512,
                        scale: float | None = None,
                        unroll: bool = False) -> jax.Array:
    """q: (B,S,H,dk), k: (B,Sk,Hkv,dk), v: (B,Sk,Hkv,dv) -> (B,S,H,dv)."""
    if unroll:
        return naive_attention(q, k, v, causal=causal, window=window,
                               scale=scale)
    b, sq, h, dk = q.shape
    sk = k.shape[1]
    hkv = k.shape[2]
    dv = v.shape[-1]
    g = h // hkv
    scale = scale if scale is not None else dk ** -0.5

    def _fit(block, s):
        # largest divisor of s not exceeding the requested block size
        # (VLM cells prepend patches: S = 4096 + 256 = 4352 = 256·17)
        block = min(block, s)
        while s % block:
            block -= 1
        return block

    q_block = _fit(q_block, sq)
    kv_block = _fit(kv_block, sk)
    nq, nk = sq // q_block, sk // kv_block

    qb = q.reshape(b, nq, q_block, hkv, g, dk)
    kb = k.reshape(b, nk, kv_block, hkv, dk)
    vb = v.reshape(b, nk, kv_block, hkv, dv)

    def q_step(_, qi):
        qt, qoff = qi                                     # (B,bq,Hkv,G,dk)
        qpos = qoff + jnp.arange(q_block)

        def kv_step(carry, ki):
            m, l, acc = carry
            kt, vt, koff = ki
            kpos = koff + jnp.arange(kv_block)
            s = _block_attn(qt, kt, vt, qpos, kpos, scale, causal, window)
            m_new = jnp.maximum(m, s.max(axis=-1))        # (B,Hkv,G,bq)
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vt.dtype), vt,
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (m_new, l, acc), None

        m0 = jnp.full((b, hkv, g, q_block), NEG, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_block, dv), jnp.float32)
        koffs = jnp.arange(nk) * kv_block
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4), koffs))
        out = acc / jnp.maximum(l[..., None], 1e-20)      # (B,Hkv,G,bq,dv)
        return None, out

    qoffs = jnp.arange(nq) * q_block
    _, outs = jax.lax.scan(q_step, None,
                           (qb.transpose(1, 0, 2, 3, 4, 5), qoffs))
    # outs: (nq, B, Hkv, G, bq, dv) -> (B, S, H, dv)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, h, dv)
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, window: int | None = None,
                     scale: float | None = None) -> jax.Array:
    """Single-token attention against a cache.

    q: (B,H,dk); k_cache: (B,Smax,Hkv,dk); v_cache: (B,Smax,Hkv,dv);
    cache_len: int32 scalar (valid prefix length, the new token included).
    """
    b, h, dk = q.shape
    hkv = k_cache.shape[2]
    g = h // hkv
    scale = scale if scale is not None else dk ** -0.5
    qg = q.reshape(b, hkv, g, dk)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(k_cache.shape[1])
    valid = pos < cache_len
    if window is not None:
        valid &= pos >= cache_len - window
    s = jnp.where(valid[None, None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, h, -1).astype(q.dtype)


def mla_decode_scores(q_nope_abs, q_pe, ckv_cache, kpe_cache, cache_len,
                      scale: float):
    """Absorbed MLA decode: score against the *compressed* cache.

    q_nope_abs: (B,H,kv_lora)  — q_nope @ w_uk absorbed
    q_pe: (B,H,rope_dim); ckv_cache: (B,Smax,kv_lora); kpe_cache:(B,Smax,rd).
    Returns attention weights (B,H,Smax).
    """
    s = (jnp.einsum("bhl,bkl->bhk", q_nope_abs, ckv_cache,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bhr,bkr->bhk", q_pe, kpe_cache,
                      preferred_element_type=jnp.float32)) * scale
    pos = jnp.arange(ckv_cache.shape[1])
    s = jnp.where((pos < cache_len)[None, None], s, NEG)
    return jax.nn.softmax(s, axis=-1)
