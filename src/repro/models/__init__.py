from repro.models.config import SHAPES, ModelConfig, ShapeConfig
from repro.models.sharding import Ctx
from repro.models.transformer import (cache_struct, decode_step,
                                      forward_train, init_cache, init_params,
                                      prefill)

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "Ctx", "init_params",
           "forward_train", "prefill", "decode_step", "cache_struct",
           "init_cache"]
