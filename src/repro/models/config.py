"""Model configuration for the assigned architecture pool.

One frozen dataclass covers all 10 families; `src/repro/configs/<id>.py`
instantiates the exact published numbers and a reduced smoke variant.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    # ---- attention ----------------------------------------------------------
    attn: str = "full"           # full | swa
    window: int = 4096           # swa window
    rope: str = "default"        # default | half | none  (half = 2d/partial)
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    # ---- mlp ------------------------------------------------------------------
    mlp: str = "swiglu"          # swiglu | gelu
    # ---- MoE -------------------------------------------------------------------
    moe: bool = False
    n_experts: int = 0
    topk: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0            # per-expert FFN width
    moe_every: int = 1           # MoE layer period (jamba: 2)
    capacity_factor: float = 2.0
    # ---- MLA (deepseek-v2) -------------------------------------------------------
    mla: bool = False
    kv_lora: int = 512
    q_lora: int = 1536
    rope_dim: int = 64           # decoupled rope key dim
    v_head_dim: int = 128
    # ---- SSM / hybrid / xLSTM -------------------------------------------------
    # per-super-block layer pattern, tiled to n_layers.  entries:
    #   'attn' | 'mamba' | 'slstm' | 'mlstm'
    pattern: tuple[str, ...] = ("attn",)
    ssm_state: int = 16
    ssm_expand: int = 2
    ssm_conv: int = 4
    # ---- encoder-decoder --------------------------------------------------------
    encoder_layers: int = 0      # >0 => enc-dec; decoder = n_layers
    # ---- vlm ------------------------------------------------------------------
    n_patches: int = 0           # stub patch embeddings prepended
    # ---- misc -----------------------------------------------------------------
    tie_embeddings: bool = False
    dtype: str = "bfloat16"      # compute dtype
    param_dtype: str = "float32"
    # unroll the layer stack into straight-line HLO instead of lax.scan —
    # used by the dry-run cost probes (HloCostAnalysis counts while-loop
    # bodies once) and available as a compile-time/runtime trade-off knob.
    unroll: bool = False
    # attention implementation when unrolled: 'naive' exposes exact S×S
    # FLOPs to the cost analyzer; 'blockwise' keeps flash semantics so the
    # probe's byte counts reflect streamed (non-materialized) attention.
    attn_impl: str = "naive"

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    def layer_kinds(self) -> list[str]:
        """Per-layer block kind, tiling `pattern` to n_layers."""
        out = []
        i = 0
        while len(out) < self.n_layers:
            out.append(self.pattern[i % len(self.pattern)])
            i += 1
        return out

    def is_moe_layer(self, i: int) -> bool:
        return self.moe and (i % self.moe_every == self.moe_every - 1)

    def active_params_note(self) -> str:
        return "MoE: roofline uses 6*N_active*D" if self.moe else "dense"


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str        # train_4k | prefill_32k | decode_32k | long_500k
    kind: str        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}
