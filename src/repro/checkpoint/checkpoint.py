"""Sharded, step-atomic checkpointing with async write and elastic restore.

Layout:  <dir>/step_<n>/manifest.json + arrays.npz
  * manifest records the flattened key paths, shapes, dtypes and step, so a
    restore can validate against (or adapt to) a different topology;
  * writes go to a temp dir + atomic rename — a crash mid-write never
    corrupts the latest checkpoint (step-atomicity);
  * `save_async` snapshots to host memory synchronously (cheap) and writes
    in a background thread off the training critical path;
  * `restore(..., shardings=...)` `device_put`s each leaf with the *target*
    sharding — restoring onto a different mesh shape (elastic rescale)
    is the same code path.

Multi-host note: on a real cluster each process saves only
`addressable_shards` of each array under a per-process suffix; this
single-process implementation writes the full arrays but keeps the same
manifest schema.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Optional

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out[key] = np.asarray(leaf)
    return out


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> str:
    arrays = _flatten(tree)
    return _write(ckpt_dir, step, arrays, keep)


def _write(ckpt_dir: str, step: int, arrays: dict[str, np.ndarray],
           keep: int) -> str:
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "time": time.time(),
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in arrays.items()},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d))


class AsyncCheckpointer:
    """Snapshot synchronously, write in a background thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree) -> None:
        self.wait()
        arrays = _flatten(tree)        # host snapshot (blocks briefly)
        self._thread = threading.Thread(
            target=_write, args=(self.ckpt_dir, step, arrays, self.keep),
            daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like, *, shardings=None):
    """Rebuild the pytree `like` from a checkpoint; `shardings` (a matching
    pytree of Shardings or None) places leaves on the target mesh —
    restoring onto a different mesh is elastic rescale."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as data:
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        shard_flat = (treedef.flatten_up_to(shardings)
                      if shardings is not None else [None] * len(flat))
        leaves = []
        for (kpath, leaf), shard in zip(flat, shard_flat):
            key = jax.tree_util.keystr(kpath)
            arr = data[key]
            want = getattr(leaf, "dtype", None)
            if want is not None and str(arr.dtype) != str(want):
                arr = arr.astype(want)
            if shard is not None:
                leaves.append(jax.device_put(arr, shard))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, [l for l in leaves])
