"""Warm-state persistence: a restarted server answers request 1 warm.

Two kinds of state make a long-running engine fast, and both evaporate on
restart without this module:

  * the **compaction feedback store** (PlanCache._Feedback, docs §6) —
    per-plan-shape observed counts and capacity overrides that took
    `compact_replan_after` overflows to converge.  Losing it means the
    first post-restart requests re-pay the overflow → re-plan → retrace
    convergence (and its fallback executions).
  * the **plan-cache warm metadata** — which plan shapes had compiled
    entries (and at which capacities/tier) when the process exited.  The
    XLA executables themselves are not picklable from here; instead the
    JAX persistent compilation cache (`enable_compilation_cache`) keeps
    the expensive XLA compile on disk, and the warm hints let a tiered
    cache/server recognize known-hot shapes at request 1.

Format (JSON, one file, written atomically via tmp + os.replace):

    {"version": 1,
     "db": "<Database.content_fingerprint()>",
     "feedback": [{"plan": repr, "settings": [...], "mesh": n,
                   "est_params": {...}, "observed": {pid: max},
                   "overrides": {pid: count} | null,
                   "replans": n, "shrinks": n, "warm": bool}, ...]}

Keyed by the *content* fingerprint, not the process-local monotonic
`Database.fingerprint`: the monotonic counter exists to make in-memory
keys collision-free across reloads, which is exactly wrong on disk.  At
load time each record's base is re-rooted onto the live database's
process fingerprint, so the in-memory keying discipline is untouched.

Failure policy: a corrupt, truncated, version-skewed, or
wrong-database file is a COLD START, never a crash — `load_warm_state`
returns 0 and the engine behaves like a fresh process.
"""
from __future__ import annotations

import json
import os
import tempfile

FORMAT_VERSION = 1


def _py(v):
    """JSON-safe scalar: numpy ints/floats carry .item(); tuples of
    binding values (rare) become lists."""
    if hasattr(v, "item"):
        return v.item()
    if isinstance(v, (list, tuple)):
        return [_py(x) for x in v]
    return v


def _settings_key(raw) -> tuple:
    """Round-trip a persisted settings astuple back into the exact tuple
    `dataclasses.astuple(Settings)` produces (JSON turns tuples into
    lists; nothing else in Settings needs conversion)."""
    return tuple(tuple(v) if isinstance(v, list) else v for v in raw)


def save_warm_state(cache, path: str) -> int:
    """Serialize `cache`'s feedback store + warm metadata to `path`
    (atomic).  Returns the number of feedback records written."""
    records = []
    with cache._lock:
        warm_bases = {k[:-1] for k in cache._entries}
        for base, fb in cache._feedback.items():
            plan_repr, settings_t, _fp, mesh = base
            records.append({
                "plan": plan_repr,
                "settings": list(settings_t),
                "mesh": mesh,
                "est_params": {k: _py(v) for k, v in fb.est_params.items()},
                "observed": {k: int(v) for k, v in fb.observed.items()},
                "overrides": None if fb.overrides is None
                else {k: int(v) for k, v in fb.overrides.items()},
                "replans": fb.replans,
                "shrinks": fb.shrinks,
                "warm": base in warm_bases,
            })
    payload = {"version": FORMAT_VERSION,
               "db": cache.db.content_fingerprint(),
               "feedback": records}
    d = os.path.dirname(os.path.abspath(path)) or "."
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, prefix=".warm-state-")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return len(records)


def load_warm_state(cache, path: str) -> int:
    """Restore feedback records (and warm hints) saved by
    `save_warm_state` into `cache`, re-rooting each base onto the live
    database's process fingerprint.  Returns the number of records
    restored; 0 — cold start — for a missing, corrupt, version-skewed,
    or different-database file.  Existing in-memory feedback for a base
    is never overwritten (live observations beat stale disk)."""
    from repro.core.plan_cache import _Feedback

    try:
        with open(path) as f:
            payload = json.load(f)
        if not isinstance(payload, dict) \
                or payload.get("version") != FORMAT_VERSION:
            return 0
        if payload.get("db") != cache.db.content_fingerprint():
            return 0
        records = payload["feedback"]
        restored = 0
        with cache._lock:
            for r in records:
                base = (r["plan"], _settings_key(r["settings"]),
                        cache.db.fingerprint, r["mesh"])
                if base in cache._feedback:
                    continue
                cache._feedback[base] = _Feedback(
                    est_params=dict(r["est_params"]),
                    observed={k: int(v) for k, v in r["observed"].items()},
                    overrides=None if r["overrides"] is None
                    else {k: int(v) for k, v in r["overrides"].items()},
                    replans=int(r.get("replans", 0)),
                    shrinks=int(r.get("shrinks", 0)))
                if r.get("warm"):
                    cache._warm_hints.add(base)
                restored += 1
            cache.stats.restored += restored
        return restored
    except (OSError, ValueError, KeyError, TypeError, AttributeError):
        # ValueError covers json.JSONDecodeError; any malformed record
        # shape lands in KeyError/TypeError.  Corrupt file = cold start.
        return 0


def enable_compilation_cache(cache_dir: str) -> bool:
    """Point JAX's persistent compilation cache at `cache_dir` so the XLA
    compile itself survives restarts: a re-staged program whose HLO
    matches a cached executable deserializes instead of recompiling.
    Thresholds are zeroed (every entry qualifies) and the XLA-level
    caches are enabled where the backend supports them (required for the
    CPU backend).  Returns False — changing nothing — on a JAX too old
    for the config knobs; never raises."""
    import jax

    try:
        jax.config.update("jax_compilation_cache_dir", str(cache_dir))
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    except AttributeError:
        return False
    try:
        jax.config.update("jax_persistent_cache_enable_xla_caches", "all")
    except (AttributeError, ValueError):
        pass   # older JAX: GPU/TPU caching still works without it
    return True
