"""Inter-operator fusion (paper §3.1).

Plan-level canonicalization: adjacent Select nodes are merged into one
conjunction (so downstream passes see a single predicate per pipeline
stage), and Project-over-Project chains are collapsed.

The paper's headline §3.1 rewrite — merging the aggregation's hash map into
the join's hash map so the two materialization points become one — is
realized *structurally* in this engine: the staged whole-query program has
no materialization boundaries at all (every operator is a pure dataflow
region of one XLA program), which is the fixpoint of that optimization.
The contrast configuration (`Settings.fusion = False`) re-introduces the
template-expansion world by placing `optimization_barrier` between operator
regions, preventing XLA from fusing across operator interfaces (paper Fig 2:
"operators are not aware of each other").
"""
from __future__ import annotations

from repro.core import ir
from repro.core.expr import And


class SelectFusion:
    name = "SelectFusion"

    def run(self, plan: ir.Plan, db, settings) -> ir.Plan:
        return _fuse(plan)


def _fuse(p: ir.Plan) -> ir.Plan:
    kids = [_fuse(c) for c in ir.children(p)]
    ir.replace_children(p, kids)
    if isinstance(p, ir.Select) and isinstance(p.child, ir.Select):
        return _fuse(ir.Select(p.child.child, And(p.child.pred, p.pred)))
    if (isinstance(p, ir.Project) and isinstance(p.child, ir.Project)
            and p.keep_input and p.child.keep_input):
        merged = dict(p.child.outputs)
        merged.update(p.outputs)
        return ir.Project(p.child.child, merged, keep_input=True)
    return p
