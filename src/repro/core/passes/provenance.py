"""Column provenance queries shared by the specialization passes.

The paper's data-structure specializations all rest on *schema +
statistics knowledge*: which table a column's values range over (PK/FK
declarations) and how large a group key's domain is (load-time stats).
Since PR 6 that knowledge is computed by the static-analysis layer
(`core/analysis/schema.py`) in one bottom-up pass; these wrappers keep
the historical per-column query API for the passes' call sites.
"""
from __future__ import annotations

from typing import Optional

from repro.core import ir
from repro.core.analysis.schema import schema_of
from repro.relational.loader import Database
from repro.relational.schema import ColKind


def key_parent_table(p: ir.Plan, name: str, db: Database) -> Optional[str]:
    """Table T such that values of column `name` lie in [0, |T|) and index
    T's dense primary key — i.e. `name` is T's PK or a FK referencing T."""
    ci = schema_of(p, db).get(name)
    return ci.parent if ci is not None else None


def col_kind(p: ir.Plan, name: str, db: Database) -> Optional[ColKind]:
    """Schema kind of a (possibly renamed) column, if it is a base column."""
    ci = schema_of(p, db).get(name)
    if ci is None or ci.table is None:
        return None
    return db.table(ci.table).schema.col(ci.col).kind


def col_domain(p: ir.Plan, name: str, db: Database,
               hints: Optional[dict[str, int]] = None) -> Optional[int]:
    """Static key-domain size for a column, if known (for dense lowering)."""
    if hints and name in hints:
        return hints[name]
    ci = schema_of(p, db).get(name)
    return ci.domain if ci is not None else None
