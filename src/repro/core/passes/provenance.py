"""Column provenance analysis shared by the specialization passes.

The paper's data-structure specializations all rest on *schema + statistics
knowledge*: which table a column's values range over (PK/FK declarations)
and how large a group key's domain is (load-time stats).  These two queries
are answered here by walking the plan.
"""
from __future__ import annotations

from typing import Optional

from repro.core import ir
from repro.core.expr import Col
from repro.relational.loader import Database
from repro.relational.schema import ColKind


def key_parent_table(p: ir.Plan, name: str, db: Database) -> Optional[str]:
    """Table T such that values of column `name` lie in [0, |T|) and index
    T's dense primary key — i.e. `name` is T's PK or a FK referencing T."""
    if isinstance(p, ir.Scan):
        sch = db.table(p.table).schema
        if not sch.has_col(name):
            return None
        if sch.primary_key == (name,):
            return p.table
        fk = sch.fk_for(name)
        return fk.ref_table if fk else None
    if isinstance(p, (ir.Select, ir.Sort, ir.Limit, ir.Compact)):
        return key_parent_table(p.child, name, db)
    if isinstance(p, ir.Project):
        if name in p.outputs:
            e = p.outputs[name]
            if isinstance(e, Col):
                return key_parent_table(p.child, e.name, db)
            return None
        return key_parent_table(p.child, name, db) if p.keep_input else None
    if isinstance(p, ir.Join):
        return (key_parent_table(p.stream, name, db)
                or (key_parent_table(p.build, name, db)
                    if p.kind in ("inner", "left") else None))
    if isinstance(p, ir.Agg):
        if name in p.group_by or name in p.carry:
            return key_parent_table(p.child, name, db)
        return None
    return None


def col_kind(p: ir.Plan, name: str, db: Database) -> Optional[ColKind]:
    """Schema kind of a (possibly renamed) column, if it is a base column."""
    if isinstance(p, ir.Scan):
        sch = db.table(p.table).schema
        return sch.col(name).kind if sch.has_col(name) else None
    if isinstance(p, (ir.Select, ir.Sort, ir.Limit, ir.Compact)):
        return col_kind(p.child, name, db)
    if isinstance(p, ir.Project):
        if name in p.outputs:
            e = p.outputs[name]
            return col_kind(p.child, e.name, db) if isinstance(e, Col) else None
        return col_kind(p.child, name, db) if p.keep_input else None
    if isinstance(p, ir.Join):
        k = col_kind(p.stream, name, db)
        if k is None and p.kind in ("inner", "left"):
            k = col_kind(p.build, name, db)
        return k
    if isinstance(p, ir.Agg):
        if name in p.group_by or name in p.carry:
            return col_kind(p.child, name, db)
        return None
    return None


def col_domain(p: ir.Plan, name: str, db: Database,
               hints: Optional[dict[str, int]] = None) -> Optional[int]:
    """Static key-domain size for a column, if known (for dense lowering)."""
    if hints and name in hints:
        return hints[name]
    if isinstance(p, ir.Scan):
        t = db.table(p.table)
        sch = t.schema
        if not sch.has_col(name):
            return None
        cdef = sch.col(name)
        if cdef.kind == ColKind.CAT:
            return len(t.vocabs[name])
        if cdef.kind == ColKind.INT:
            parent = key_parent_table(p, name, db)
            if parent is not None:
                return db.table(parent).nrows
            st = t.stats[name]
            if st.min >= 0 and st.max < (1 << 20):
                return int(st.max) + 1
        return None
    if isinstance(p, (ir.Select, ir.Sort, ir.Limit, ir.Compact)):
        return col_domain(p.child, name, db, hints)
    if isinstance(p, ir.Project):
        if name in p.outputs:
            e = p.outputs[name]
            if isinstance(e, Col):
                return col_domain(p.child, e.name, db, hints)
            return None
        return col_domain(p.child, name, db, hints) if p.keep_input else None
    if isinstance(p, ir.Join):
        d = col_domain(p.stream, name, db, hints)
        if d is None and p.kind in ("inner", "left"):
            d = col_domain(p.build, name, db, hints)
        return d
    if isinstance(p, ir.Agg):
        if name in p.group_by or name in p.carry:
            return col_domain(p.child, name, db, hints)
        return None
    return None
