"""String dictionaries (paper §3.4, Table II).

Rewrites char-matrix string predicates into integer predicates over the
load-time dictionary codes:

  StrEq(c, s)            -> CodeEq(c, dict[s])          (Normal dictionary)
  StrIn(c, ss)           -> CodeIn(c, codes)
  StrStartsWith(c, p)    -> CodeRange(c, lo, hi)        (Ordered dictionary:
                            the vocab is sorted, so a prefix is a code range)
  StrContainsWord(c, w)  -> WordCode(c, word_dict[w])   (Word-tokenizing
                            dictionary: per-row word-code matrix membership)

A constant absent from the dictionary lowers to the empty/full predicate
(code −1 matches nothing).  TPC-H column names are globally unique, so the
owning table is resolved by schema lookup.
"""
from __future__ import annotations

from repro.core import ir
from repro.core.expr import (CodeEq, CodeIn, CodeRange, StrContainsWord,
                             StrEq, StrIn, StrStartsWith, WordCode)
from repro.core.passes.cse_dce import transform_exprs
from repro.relational.loader import Database


def _owner(db: Database, col: str, renames: dict[str, str]):
    seen = set()
    while col in renames and col not in seen:
        seen.add(col)
        col = renames[col]
    for t in db.tables.values():
        if t.schema.has_col(col):
            return t, col
    raise KeyError(f"column {col} not found in any table")


class StringDictionary:
    name = "StringDictionary"

    def run(self, plan: ir.Plan, db: Database, settings) -> ir.Plan:
        from repro.core.expr import Col

        renames: dict[str, str] = {}
        for node in ir.walk(plan):
            if isinstance(node, ir.Project):
                for name, e in node.outputs.items():
                    if isinstance(e, Col) and e.name != name:
                        renames[name] = e.name

        def lower(e):
            return _lower(e, db, renames)

        transform_exprs(plan, lambda e: _map_tree(e, lower))
        return plan


def _map_tree(e, fn):
    from repro.core import expr as E

    if isinstance(e, (E.Arith, E.Cmp)):
        return type(e)(e.op, _map_tree(e.lhs, fn), _map_tree(e.rhs, fn))
    if isinstance(e, (E.And, E.Or)):
        return type(e)(_map_tree(e.lhs, fn), _map_tree(e.rhs, fn))
    if isinstance(e, E.Not):
        return E.Not(_map_tree(e.operand, fn))
    if isinstance(e, E.Where):
        return E.Where(_map_tree(e.cond, fn), _map_tree(e.then, fn),
                       _map_tree(e.other, fn))
    return fn(e)


def _lower(e, db: Database, renames: dict[str, str]):
    from repro.core.expr import Param

    if isinstance(e, (StrEq, StrIn, StrStartsWith, StrContainsWord)):
        # An unbound string Param has no dictionary code yet: leave the
        # predicate unlowered (param-residual).  Execution requires the
        # value, so the runtime layer substitutes string params before
        # optimization; this branch only matters for plan analysis.
        vals = {StrEq: lambda: [e.value], StrIn: lambda: list(e.values),
                StrStartsWith: lambda: [e.prefix],
                StrContainsWord: lambda: [e.word]}[type(e)]()
        if any(isinstance(v, Param) for v in vals):
            return e
    if isinstance(e, StrEq):
        t, c = _owner(db, e.col, renames)
        return CodeEq(e.col, t.encode_const(c, e.value), e.negate)
    if isinstance(e, StrIn):
        t, c = _owner(db, e.col, renames)
        return CodeIn(e.col, tuple(t.encode_const(c, v) for v in e.values))
    if isinstance(e, StrStartsWith):
        t, c = _owner(db, e.col, renames)
        lo, hi = t.code_range(c, e.prefix)
        return CodeRange(e.col, lo, hi)
    if isinstance(e, StrContainsWord):
        t, c = _owner(db, e.col, renames)
        return WordCode(e.col, t.encode_word(c, e.word), e.negate)
    return e
