"""Selection-vector compaction (paper §3.2, Fig 7: data-structure
specialization is where the constant factors live).

The mask-carrying dataflow of the staged engine is shape-stable — exactly
what XLA wants — but it makes every operator pay full-table cost no matter
how selective the upstream predicates were.  This pass plants
`ir.Compact(child, capacity)` points where that cost is worth cutting:

  * **where**: after selective Selects (and the masks PK-gather joins
    introduce), immediately below expensive consumers — join probes and
    gathers, aggregations, sorts — so the consumer runs over `capacity`
    rows instead of the full cardinality.  Build sides of `pk_gather` /
    `bucket_gather` joins are never compacted: those strategies index the
    build frame *positionally* (a key value is a row id), and compaction
    destroys alignment.
  * **capacity**: JAX shapes are static, so the capacity must be chosen at
    plan time.  We estimate the surviving-row count from `Table.stats` and
    predicate structure (range fractions over min/max, equality over known
    dictionary/key domains — §3.5.2 "statistics knowledge"), multiply by a
    safety margin, and round up to a power-of-two bucket so near-miss
    estimates across plans land on few distinct shapes (mirroring the
    batch buckets of `compile.bucket_size`).
  * **overflow**: estimates are estimates.  Every Compact point raises a
    runtime flag when `count > capacity`; the compile driver surfaces the
    OR of all flags as a program output and `CompiledQuery` re-executes an
    uncompacted twin plan, so compaction can never change results.

`PlanCache` folds the planted capacity vector (read off the lowered plan)
into the plan key: entries are distinct whenever their static shapes are,
so each capacity bucket is traced at most once.

Adaptive capacity feedback (PR 5).  Estimates come from three sources, in
priority order:

  1. **observed counts** — `observed[point_id]` is the true valid count a
     previous compile of the same plan shape measured at runtime (staged
     as a per-point program output).  An observed count replaces both the
     estimate AND the static 2x margin: the capacity is the pow2 bucket
     just above the measured count (measured headroom).
  2. **initial-binding estimates** — `est_params` holds the first-seen
     runtime parameter values; a Param-bounded range predicate is
     estimated against the per-column quantile sketch as if that value
     were a literal (previously: selectivity 1.0, so parameterized plans
     never compacted).
  3. **static sketches** — col-vs-col comparisons between columns of one
     base table use the measured 2-column range fraction
     (`Table.pair_frac`) instead of the textbook 0.5.

Static analysis integration (PR 6).  Column provenance and base
cardinalities come from the analysis layer (`core/analysis`): one
`analyze()` pass per plan replaces the per-column recursive walks, and
conjunctions of predicates over one base table are additionally measured
jointly on a small fixed row sample (`Table.sample_index`) — conjunct
independence overestimates the filtering power of correlated predicates
(Q12's receipt/commit/ship date chain), and the planted capacity
inherited that undershoot as overflow risk.  The measured joint fraction
only ever *raises* the estimate (`max(product, measured)`), so
capacities never shrink below what the independence model planned.

Candidate sites are numbered in walk order whether or not a point is
planted, so `point_id` survives re-planning even when decisions flip.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from repro.core import ir
from repro.core import expr as E
from repro.core.analysis import analyze
from repro.relational.loader import Database
from repro.relational.schema import ColKind

# minimum planned row-count win for a point to pay for the compaction
# itself (a cumsum pass + binary search plus a gather per carried column)
_RATIO_SORT = 2
_RATIO_ELEMENTWISE = 2
_MIN_CAPACITY = 64


@dataclasses.dataclass
class Card:
    """Cardinality estimate for a staged frame at one plan point."""
    phys: int        # physical row count (static, exact)
    valid: float     # estimated mask-valid rows
    masked: bool     # frame carries a (possibly selective) mask


@dataclasses.dataclass
class _Ctx:
    """Walk state: estimation inputs plus the candidate-site counter."""
    db: Database
    s: object                       # Settings
    est_params: dict                # runtime param name -> initial value
    observed: dict                  # point_id -> measured valid count
    analysis: object = None         # analysis.Analysis of the input plan
    next_site: int = 0
    next_hand: int = 0
    # live key population estimates: output column name -> estimated
    # distinct surviving values, recorded at joins (a join filters the
    # stream to the build's surviving keys) and consumed by the dense-agg
    # group-count estimate.  Join-scoped: build-subtree entries are
    # discarded (the build frame is internal to its join) and every Agg
    # clears the table (its output is re-keyed).
    key_groups: dict = dataclasses.field(default_factory=dict)

    def site_id(self) -> str:
        pid = f"c{self.next_site}"
        self.next_site += 1
        return pid

    def hand_id(self) -> str:
        pid = f"h{self.next_hand}"
        self.next_hand += 1
        return pid


def observed_bucket(count: int) -> int:
    """Capacity for a *measured* count: the pow2 bucket just above it.
    No static margin — the bucket roundup is the headroom (≥ +1 row,
    on average 50%); an estimate that still undershoots re-triggers the
    overflow feedback, costing one more retrace."""
    return _bucket(float(count), 1.0)


_TRANSLATE_MARGIN = 1.5


def translate_bucket(count: int) -> int:
    """Capacity for a measured count at a *translate* point.

    A translate overflow is worse than a gather overflow: the pk_gather
    probing `slot_of` silently drops rows past capacity, so the whole
    query re-executes on the uncompacted twin — not just one frame.  The
    floor therefore sits a margin *above* the all-time measured max (the
    feedback store never decays translate observations), instead of the
    just-above bucket that plain points get."""
    return _bucket(float(count), _TRANSLATE_MARGIN)


class Compaction:
    name = "Compaction"

    def __init__(self, est_params: Optional[dict] = None,
                 observed: Optional[dict] = None):
        self.est_params = dict(est_params or {})
        self.observed = dict(observed or {})

    def run(self, plan: ir.Plan, db: Database, settings) -> ir.Plan:
        ctx = _Ctx(db, settings, self.est_params, self.observed,
                   analysis=analyze(plan, db))
        plan, _ = _walk(plan, ctx, heavy=False)
        return plan


def strip_compaction(plan: ir.Plan) -> ir.Plan:
    """Remove every Compact node (planner-inserted or hand-planted) — the
    uncompacted twin the overflow fallback compiles against."""
    kids = [strip_compaction(c) for c in ir.children(plan)]
    ir.replace_children(plan, kids)
    if isinstance(plan, ir.Compact):
        return plan.child
    return plan


# ---------------------------------------------------------------------------
# the annotated walk: bottom-up cardinalities, top-down insertions
# ---------------------------------------------------------------------------

def _walk(p: ir.Plan, ctx: _Ctx, heavy: bool,
          protect: bool = False) -> tuple[ir.Plan, Card]:
    """`heavy` marks subtrees consumed (transitively) by an operator whose
    per-row cost does not fuse away — sorts, segment reductions, generic
    join probes.  A pure elementwise+gather pipeline ending in a scalar
    aggregate fuses into a handful of XLA loops already; compacting it
    trades fused passes for an unfused cumsum and loses.

    `protect` marks subtrees whose *physical frame* flows into a
    positional (`pk_gather`/`bucket_gather`) build side: a gathering
    compact there would re-pack rows and break the key-is-row-id
    addressing (the verifier's positional-build-alignment rule).  It
    follows the frame: through Select/Project/Compact/Limit children and
    join streams; a dense Agg re-keys its output by domain index and a
    Sort permutes anyway, so protection stops below both."""
    db, s = ctx.db, ctx.s
    if isinstance(p, ir.Scan):
        n = ctx.analysis.info(p).card if ctx.analysis is not None \
            else db.table(p.table).nrows
        return p, Card(n, float(n), False)

    if isinstance(p, ir.Select):
        child, c = _walk(p.child, ctx, heavy, protect)
        p.child = child
        sel = _selectivity(p.pred, p.child, ctx)
        return p, Card(c.phys, c.valid * sel, True)

    if isinstance(p, ir.Project):
        child, c = _walk(p.child, ctx, heavy, protect)
        p.child = child
        return p, c

    if isinstance(p, ir.Compact):   # pre-existing (hand-planted) point
        child, c = _walk(p.child, ctx, heavy, protect)
        p.child = child
        if p.point_id is None:
            # assign the stable h<i> id HERE, not at compile time: the
            # same pass walks the same plan shape on every re-plan, so the
            # numbering reproduces and the feedback store's observed
            # counts (keyed by these ids) can re-plan hand-planted
            # capacities exactly like pass-planted ones
            p.point_id = ctx.hand_id()
        cap = int(p.capacity)
        if cap <= 0:                # measure-only: cardinality untouched
            return p, c
        obs = ctx.observed.get(p.point_id)
        if obs is not None:
            # measured demand overrides the hand-chosen capacity (the
            # PR-5 bug: hand points were observed but never re-planned,
            # so an undershot hand capacity overflowed forever)
            p.capacity = cap = (translate_bucket(obs) if p.translate
                                else observed_bucket(obs))
        return p, Card(min(cap, c.phys), min(c.valid, float(cap)), True)

    if isinstance(p, ir.Join):
        # a generic join is itself a heavy consumer (build argsort, stream
        # binary-search probe); the positional strategies are gathers that
        # fuse, so their streams compact only under a heavy ancestor
        sub_heavy = heavy or p.strategy == "generic"
        positional = p.strategy in ("pk_gather", "bucket_gather")
        # the join's output IS the stream's physical frame, so stream-side
        # protection is inherited; the build frame feeds this join only,
        # and must stay intact throughout when the join is positional
        stream, sc = _walk(p.stream, ctx, sub_heavy, protect)
        stream_keys = dict(ctx.key_groups)
        # the build subtree is an independent pipeline: stream-side key
        # populations don't constrain it (a fresh scan sees every key),
        # and its own entries don't outlive the join (the build frame is
        # internal — the join's output is the stream frame)
        ctx.key_groups = {}
        build, bc = _walk(p.build, ctx, sub_heavy, positional)
        ctx.key_groups = stream_keys
        # the build's match fraction must reflect its *pre-compaction*
        # cardinality: compaction shrinks phys toward valid, which would
        # inflate the fraction to ~1/margin and poison downstream estimates
        bfrac = min(bc.valid / bc.phys, 1.0) if bc.phys else 1.0
        # key-population bookkeeping for the dense-agg group estimate: an
        # inner/semi join keeps a stream row (and hence its key values)
        # only when its build match survives, so every stream-side
        # population scales by the match fraction; the stream key itself
        # is now bounded by the build's surviving key mass.
        if p.kind in ("inner", "semi"):
            for k in list(ctx.key_groups):
                ctx.key_groups[k] *= bfrac
            ctx.key_groups[p.stream_key] = min(
                bc.valid, ctx.key_groups.get(p.stream_key, float("inf")))
        elif p.kind == "anti":
            anti = max(1.0 - bfrac, 0.1)
            for k in list(ctx.key_groups):
                ctx.key_groups[k] *= anti
        if sub_heavy:
            stream, sc = _maybe_compact(stream, sc, ctx,
                                        _RATIO_ELEMENTWISE, protect)
        # positional strategies index the build by key value: never compact.
        # The generic join argsorts the build; exists_flag scatters it —
        # either way the build frame is internal to the join (the output
        # is the stream frame), so outer protection does not apply.
        if p.strategy in ("generic", "exists_flag"):
            ratio = _RATIO_SORT if p.strategy == "generic" \
                else _RATIO_ELEMENTWISE
            build, bc = _maybe_compact(build, bc, ctx, ratio)
        elif p.strategy == "pk_gather":
            # a *translated* compact re-establishes key addressing over
            # the compacted build via the CSR slot_of vector (planted only
            # under Settings.use_pallas — gated inside _maybe_compact so
            # the candidate-site numbering is preset-independent).  A
            # partitioned build stays protected: slot_of would be built
            # over the shard-local block but probed with global keys.
            build, bc = _maybe_compact(build, bc, ctx, _RATIO_ELEMENTWISE,
                                       protect=_shard_count(build) > 1,
                                       translate=True)
        p.stream, p.build = stream, build
        if p.kind == "inner":
            valid, masked = sc.valid * bfrac, sc.masked or bc.masked
        elif p.kind == "left":
            valid, masked = sc.valid, sc.masked
        elif p.kind == "semi":
            valid, masked = sc.valid * bfrac, True
        else:  # anti
            valid, masked = sc.valid * max(1.0 - bfrac, 0.1), True
        return p, Card(sc.phys, valid, masked)

    if isinstance(p, ir.Agg):
        # dense/generic aggregation segment-reduces (or sorts) per row —
        # heavy for everything below; a scalar aggregation is a terminal
        # one-pass consumer that reduces masked rows as cheaply as the
        # compaction itself would run.  The output frame is re-keyed
        # (dense: by domain index) or re-packed (generic: sorted groups),
        # so upstream protection does not extend below the Agg.
        agg_heavy = p.strategy != "scalar" and bool(p.group_by)
        child, c = _walk(p.child, ctx, heavy or agg_heavy, False)
        if agg_heavy:
            ratio = _RATIO_SORT if p.strategy == "generic" \
                else _RATIO_ELEMENTWISE
            child, c = _maybe_compact(child, c, ctx, ratio)
        p.child = child
        if p.strategy == "dense":
            D = 1
            for d in p.domains or [1]:
                D *= d
            groups = _dense_groups(p, c, float(D), ctx)
            ctx.key_groups = {}    # output re-keyed by domain index
            return p, Card(D, groups, True)
        ctx.key_groups = {}        # generic: output re-packed by group
        if p.strategy == "scalar" or not p.group_by:
            return p, Card(1, 1.0, False)
        # generic grouping keeps the physical width, groups packed in front
        return p, Card(c.phys, min(c.valid, float(c.phys)), True)

    if isinstance(p, ir.Sort):
        child, c = _walk(p.child, ctx, True, False)
        child, c = _maybe_compact(child, c, ctx, _RATIO_SORT)
        p.child = child
        return p, c

    if isinstance(p, ir.Limit):
        child, c = _walk(p.child, ctx, heavy, protect)
        p.child = child
        n = p.n if isinstance(p.n, int) else c.phys
        return p, Card(min(n, c.phys), min(c.valid, float(n)), c.masked)

    if isinstance(p, ir.Exchange):
        # all-gather along the data axis: physical height and estimated
        # valid mass both multiply by the shard count (each shard held a
        # disjoint slice).  Anything planted *below* runs per shard —
        # the per-shard capacity contract — and the consumer above this
        # node sees the gathered cardinality when weighing its own point.
        child, c = _walk(p.child, ctx, heavy, protect)
        p.child = child
        ns = _shard_count(child)
        return p, Card(c.phys * ns, c.valid * ns, c.masked)

    raise TypeError(type(p))


def _shard_count(p: ir.Plan) -> int:
    """Mesh size of a partitioned subtree (1 when unsharded)."""
    return max((s.shard.n_shards for s in ir.walk(p)
                if isinstance(s, ir.Scan) and s.shard is not None),
               default=1)


def _bucket(est_rows: float, margin: float) -> int:
    want = max(int(est_rows * margin) + 1, _MIN_CAPACITY)
    return 1 << (want - 1).bit_length()


def _maybe_compact(node: ir.Plan, card: Card, ctx: _Ctx, ratio: int,
                   protect: bool = False,
                   translate: bool = False) -> tuple[ir.Plan, Card]:
    """Plant a Compact over `node` if the planner expects the consumer to
    win at least `ratio`x in row count.  Returns the (possibly wrapped)
    node and the post-compaction cardinality.

    The candidate id is drawn unconditionally — every call site consumes
    one — so ids depend only on plan structure, never on the estimates:
    an observed count recorded under capacity A still names the same site
    after a re-plan chose capacity B (or chose not to plant at all)."""
    pid = ctx.site_id()
    s = ctx.s
    if not s.compaction or not card.masked or isinstance(node, ir.Compact):
        return node, card
    if card.phys < s.compact_min_rows:
        return node, card
    if s.compact_measure_only:
        # the overflow twin: observe the true valid count at every
        # candidate site (capacity 0 = no gather, frame unchanged), so a
        # single fallback execution hands the feedback store the exact
        # demand at every site — including those an overflowed upstream
        # point would have truncated in the compacted program.  A
        # measure-only point never re-packs rows, so `protect` is moot.
        return _wrap(node, 0, pid), card
    if protect:
        # this frame flows into a positional build side: a gathering
        # compact here would break key-is-row-id addressing
        return node, card
    if translate and not s.use_pallas:
        # key→slot translation is the kernel path's contract; without it
        # pk_gather keeps positional addressing and the build stays intact
        return node, card
    obs = ctx.observed.get(pid)
    if obs is not None:
        # measured headroom: the bucket just above the observed count
        # replaces both the static estimate and the static margin
        # (translate points get a floored margin above their all-time max)
        cap = translate_bucket(obs) if translate else observed_bucket(obs)
        est_valid = float(min(obs, cap))
    else:
        cap = _bucket(card.valid, s.compact_margin)
        est_valid = card.valid
    if cap * ratio > card.phys:
        return node, card
    return _wrap(node, cap, pid, translate), Card(cap, est_valid, True)


def _wrap(node: ir.Plan, cap: int, pid: str,
          translate: bool = False) -> ir.Plan:
    # sink below Projects so the projection's expressions also run narrow
    # (a Project is elementwise: compact-then-project == project-then-compact)
    if isinstance(node, ir.Project):
        node.child = _wrap(node.child, cap, pid, translate)
        return node
    return ir.Compact(node, cap, point_id=pid, translate=translate)


# ---------------------------------------------------------------------------
# selectivity estimation from Table.stats + predicate structure
# ---------------------------------------------------------------------------

def _selectivity(e: E.Expr, plan: ir.Plan, ctx: _Ctx) -> float:
    parts = E.conjuncts(e)
    if len(parts) > 1:
        s = _conjunction_sel(parts, plan, ctx)
    else:
        s = _sel(e, plan, ctx)
    return min(max(s, 0.0), 1.0)


def _conjunction_sel(parts: list, plan: ir.Plan, ctx: _Ctx) -> float:
    """Surviving fraction of a conjunction.

    The independence product `∏ sel(cᵢ)` overestimates the filtering
    power of correlated predicates (Q12's receiptdate/commitdate/shipdate
    chain: each range is selective, but they fire together), and planted
    capacities inherit the undershoot as overflow risk.  For groups of
    conjuncts whose columns all resolve to ONE base table, the joint
    fraction is instead *measured* on the table's fixed row sample; the
    final estimate is `max(product, measured)` — the sample only ever
    raises the estimate, so capacities never drop below what the
    independence model planned (overflow-safe direction)."""
    per = [_sel(c, plan, ctx) for c in parts]
    indep = 1.0
    for s in per:
        indep *= s
    groups: dict[int, tuple] = {}
    for i, c in enumerate(parts):
        tc = _conjunct_table(c, plan, ctx)
        if tc is None:
            continue
        table, colmap = tc
        t, idxs, cols = groups.setdefault(id(table), (table, [], {}))
        idxs.append(i)
        cols.update(colmap)
    est = 1.0
    covered: set[int] = set()
    for table, idxs, colmap in groups.values():
        if len(idxs) < 2:
            continue   # a single conjunct gains nothing over its estimate
        frac = _sample_frac(table, [parts[i] for i in idxs], colmap, ctx)
        if frac is None:
            continue
        est *= frac
        covered.update(idxs)
    if not covered:
        return indep
    for i, s in enumerate(per):
        if i not in covered:
            est *= s
    return max(indep, est)


def _conjunct_table(e, plan, ctx: _Ctx):
    """(Table, {plan name: base column}) when every column of `e`
    resolves to the same base table — the condition for a row-aligned
    joint sample evaluation."""
    cols = E.expr_columns(e)
    if not cols:
        return None
    table = None
    colmap: dict[str, str] = {}
    for name in cols:
        tc = _base_column(plan, name, ctx)
        if tc is None:
            return None
        t, cname = tc
        if table is None:
            table = t
        elif t is not table:
            return None
        colmap[name] = cname
    return table, colmap


class _SampleEnv(E.EvalEnv):
    """Predicate evaluation over one base table's fixed row sample,
    resolving plan column names through the provenance map."""

    def __init__(self, t, colmap: dict[str, str], params: dict):
        super().__init__(np, cse=False, params=params)
        self._t = t
        self._colmap = colmap
        self._idx = t.sample_index()

    def _arr(self, name: str):
        return self._t.data[self._colmap[name]][self._idx]

    def get_num(self, name: str):
        return self._arr(name)

    def get_codes(self, name: str):
        return self._arr(name)

    def get_words(self, name: str):
        return self._arr(name)

    def get_chars(self, name: str):
        return self._t.char_matrix(self._colmap[name])[self._idx]

    def get_word_chars(self, name: str):
        return self._t.char_matrix(self._colmap[name])[self._idx]


def _sample_frac(t, exprs: list, colmap: dict[str, str],
                 ctx: _Ctx) -> Optional[float]:
    """Measured fraction of `t`'s row sample satisfying ALL of `exprs`
    (None when any conjunct is un-evaluable — unbound Params, string
    params — estimation falls back to the independence product)."""
    env = _SampleEnv(t, colmap, ctx.est_params)
    try:
        mask = None
        for e in exprs:
            v = np.asarray(E.eval_expr(e, env))
            if v.dtype != np.bool_ or v.ndim != 1:
                return None
            mask = v if mask is None else (mask & v)
    except Exception:
        return None
    if mask is None or mask.shape[0] == 0:
        return None
    return float(np.count_nonzero(mask)) / mask.shape[0]


def _sel(e, plan, ctx: _Ctx) -> float:
    if isinstance(e, E.And):
        return _sel(e.lhs, plan, ctx) * _sel(e.rhs, plan, ctx)
    if isinstance(e, E.Or):
        a, b = _sel(e.lhs, plan, ctx), _sel(e.rhs, plan, ctx)
        return a + b - a * b
    if isinstance(e, E.Not):
        return 1.0 - _sel(e.operand, plan, ctx)
    if isinstance(e, E.Const):
        return 1.0 if e.value else 0.0

    if isinstance(e, E.Cmp):
        lhs, rhs, op = e.lhs, e.rhs, e.op
        if isinstance(rhs, E.Col) and not isinstance(lhs, E.Col):
            lhs, rhs = rhs, lhs
            op = {"<": ">", "<=": ">=", ">": "<", ">=": "<="}.get(op, op)
        if isinstance(lhs, E.Col) and isinstance(rhs, E.Const):
            return _range_sel(op, lhs.name, float(rhs.value), plan, ctx,
                              quantile=False)
        if isinstance(lhs, E.Col) and isinstance(rhs, E.Param) \
                and rhs.name in ctx.est_params:
            # initial-binding estimate: the first-seen runtime value,
            # against the quantile sketch (the value is representative,
            # not exact — later bindings are covered by the overflow
            # feedback, so a distribution-aware guess beats 1.0)
            return _range_sel(op, lhs.name, float(ctx.est_params[rhs.name]),
                              plan, ctx, quantile=True)
        if isinstance(lhs, E.Col) and isinstance(rhs, E.Col):
            pair = _pair_sel(op, lhs.name, rhs.name, plan, ctx)
            if pair is not None:
                return pair    # measured 2-column range fraction
            if op in ("<", "<=", ">", ">="):
                return _cross_sel(op, lhs.name, rhs.name, plan, ctx)
        return 1.0         # unbound Param / computed lhs: no knowledge

    if isinstance(e, E.CodeEq):
        nd = _n_distinct(e.col, plan, ctx)
        s = 1.0 / nd if nd else 0.1
        return 1.0 - s if e.negate else s
    if isinstance(e, E.CodeIn):
        nd = _n_distinct(e.col, plan, ctx)
        return min(len(e.codes) / nd, 1.0) if nd else 0.3
    if isinstance(e, E.CodeRange):
        nd = _n_distinct(e.col, plan, ctx)
        return min(max((e.hi - e.lo) / nd, 0.0), 1.0) if nd else 0.3
    if isinstance(e, (E.WordCode, E.StrContainsWord)):
        # word membership: no positional statistics; stay conservative
        s = 0.5
        return 1.0 - s if e.negate else s

    # un-lowered string predicates (string_dict off): same dictionary
    # statistics, evaluated against the char matrices at runtime
    if isinstance(e, E.StrEq):
        nd = _n_distinct(e.col, plan, ctx)
        s = 1.0 / nd if nd and not isinstance(e.value, E.Param) else 1.0
        return 1.0 - s if e.negate else s
    if isinstance(e, E.StrIn):
        nd = _n_distinct(e.col, plan, ctx)
        if nd and not any(isinstance(v, E.Param) for v in e.values):
            return min(len(e.values) / nd, 1.0)
        return 1.0
    if isinstance(e, E.StrStartsWith):
        tc = _base_column(plan, e.col, ctx)
        if tc is not None and not isinstance(e.prefix, E.Param):
            t, name = tc
            if name in t.vocabs:
                lo, hi = t.code_range(name, e.prefix)
                return (hi - lo) / max(len(t.vocabs[name]), 1)
        return 1.0

    return 1.0             # Where / arithmetic / unknown: assume nothing


def _range_sel(op: str, name: str, v: float, plan: ir.Plan, ctx: _Ctx,
               quantile: bool = False) -> float:
    tc = _base_column(plan, name, ctx)
    if tc is None:
        return 1.0
    t, cname = tc
    st = t.stats.get(cname)
    if st is None:
        return 1.0
    lo, hi = float(st.min), float(st.max)
    span = hi - lo
    if op == "==":
        if st.n_distinct:
            return 1.0 / st.n_distinct
        return 1.0 / max(span, 1.0)
    if op == "!=":
        return 1.0
    if span <= 0:
        return 1.0
    if quantile and t.schema.col(cname).kind in (ColKind.INT, ColKind.FLOAT,
                                                 ColKind.DATE):
        # equi-depth quantile CDF: error bounded by one knot interval,
        # robust to skew (the min/max interpolation below is not)
        frac_le = t.cdf(cname, v)
        return frac_le if op in ("<", "<=") else 1.0 - frac_le
    # clamp per leaf: the And/Or/Not combiners assume [0, 1], and a bound
    # outside the stats range would otherwise go negative / above one
    if op in ("<", "<="):
        return min(max((v - lo) / span, 0.0), 1.0)
    return min(max((hi - v) / span, 0.0), 1.0)     # > / >=


def _pair_sel(op: str, a: str, b: str, plan: ir.Plan, ctx: _Ctx
              ) -> Optional[float]:
    """Measured fraction for `a op b` when both columns resolve to the
    SAME base table (row-aligned compare is only meaningful there)."""
    if op not in ("<", "<=", ">", ">=", "==", "!="):
        return None
    ta, tb = _base_column(plan, a, ctx), _base_column(plan, b, ctx)
    if ta is None or tb is None or ta[0] is not tb[0]:
        return None
    return ta[0].pair_frac(ta[1], op, tb[1])


def _cross_sel(op: str, a: str, b: str, plan: ir.Plan, ctx: _Ctx) -> float:
    """Cross-table column inequality `a op b`: independence estimate from
    the two marginal quantile sketches, replacing the textbook flat 0.5.

    P(a <= b) = E_b[cdf_a(b)]; the knots of b's sketch are equi-depth, so
    the plain mean of cdf_a over them IS that expectation up to one knot
    interval of error.  Both integration directions are evaluated and the
    result is clamped BY the textbook 0.5 (`min`): the measured fractions
    can only tighten a downstream capacity, never loosen it past the
    default — an undershoot re-plans through the overflow feedback, an
    overshoot would waste capacity silently forever."""
    ta, tb = _base_column(plan, a, ctx), _base_column(plan, b, ctx)
    if ta is None or tb is None:
        return 0.5
    numeric = (ColKind.INT, ColKind.FLOAT, ColKind.DATE)
    for t, cname in (ta, tb):
        if t.schema.col(cname).kind not in numeric:
            return 0.5
    (t_a, ca), (t_b, cb) = ta, tb
    le_ab = float(np.mean([t_a.cdf(ca, float(v))
                           for v in t_b.quantile_sketch(cb)]))  # P(a <= b)
    le_ba = float(np.mean([t_b.cdf(cb, float(v))
                           for v in t_a.quantile_sketch(ca)]))  # P(b <= a)
    if op in ("<", "<="):
        est = min(le_ab, 1.0 - le_ba)
    else:
        est = min(1.0 - le_ab, le_ba)
    return min(max(est, 0.01), 0.5)


def _dense_groups(p: ir.Agg, c: Card, D: float, ctx: _Ctx) -> float:
    """Expected occupied groups of a dense aggregation — tighter than
    `min(valid rows, domain)` (the ROADMAP residual behind q3's top-k:
    that bound left the dense agg's output too wide to compact before
    the Sort).

    Two refinements over the naive bound:

      * the *live key population* d: the static domain (parent row
        count for key columns) is capped per group column by the base
        table's measured distinct count and by the join-filtered key
        population recorded in `ctx.key_groups` — a group key only
        reaches the agg if its join survivors did;
      * *collision mass*: n valid rows thrown at d live keys occupy
        `d * (1 - (1 - 1/d)^n)` expected groups (balls in bins) — far
        below min(n, d) when rows per group vary, exact in expectation
        under the independence the rest of this planner already assumes.

    Both only ever tighten, and the planted capacity keeps the usual
    `compact_margin` + pow2-bucket headroom above the estimate; an
    undershoot degrades to the overflow-twin fallback plus re-plan, never
    to a wrong result."""
    n = c.valid
    naive = min(D, n)
    if n <= 0 or not p.group_by:
        return naive
    d = 1.0
    domains = p.domains or [0] * len(p.group_by)
    for name, dom in zip(p.group_by, domains):
        per = float(dom) if dom else D
        nd = _n_distinct(name, p, ctx)
        if nd:
            per = min(per, float(nd))
        kg = ctx.key_groups.get(name)
        if kg is not None:
            per = min(per, kg)
        d *= max(per, 1.0)
    if d <= 1.0:
        return min(naive, 1.0)
    # numerically stable (1 - 1/d)^n for large d, n
    groups = d * -math.expm1(n * math.log1p(-1.0 / d))
    return min(naive, groups)


def _n_distinct(name: str, plan: ir.Plan, ctx: _Ctx) -> Optional[int]:
    tc = _base_column(plan, name, ctx)
    if tc is None:
        return None
    t, cname = tc
    st = t.stats.get(cname)
    return st.n_distinct if st is not None and st.n_distinct else None


def _base_column(p: ir.Plan, name: str, ctx: _Ctx):
    """(Table, column) provenance of a (possibly renamed) base column,
    answered by the analysis layer's schema inference (one bottom-up pass
    shared by every estimate in this plan)."""
    ci = ctx.analysis.col(p, name) if ctx.analysis is not None else None
    if ci is None or ci.table is None:
        return None
    return ctx.db.table(ci.table), ci.col
