"""Hash-map lowering (paper §3.2.2).

Generic hash aggregation is specialized using schema + statistics knowledge
collected at load time:

  * no group key                     -> 'scalar' (the paper's "single,
    statically-known key" case, e.g. Q6's global aggregate): accumulators
    become scalar registers;
  * all group-key domains statically known and small -> 'dense': the hash
    map becomes a pre-allocated native array indexed by a mixed-radix
    composite of the key codes (the paper's "convert the hash map to a
    native array", with the pre-allocation sized by worst-case analysis and
    the initialization hoisted off the critical path — in XLA the
    accumulator is a statically-shaped zero buffer);
  * otherwise                        -> 'generic' sort-based grouping.

Domains come from the analysis layer's per-column `ColInfo.domain` (CAT
dictionary sizes, dense PK/FK ranges, integer stats) or explicit
statistics hints (`Agg.domain_hints`, §3.5.2); one `analyze()` pass serves
every Agg in the plan.
"""
from __future__ import annotations

from repro.core import ir
from repro.core.analysis import analyze
from repro.relational.loader import Database


class HashMapLowering:
    name = "HashMapLowering"

    def run(self, plan: ir.Plan, db: Database, settings) -> ir.Plan:
        a = analyze(plan, db)
        for node in ir.walk(plan):
            if not isinstance(node, ir.Agg) or node.strategy != "generic":
                continue
            if not node.group_by:
                node.strategy = "scalar"
                continue
            child = a.schema(node.child)
            # Without string dictionaries a CAT key has no integer code
            # domain — the dictionary IS the domain knowledge (§3.4/§3.2.2).
            if not settings.string_dict and any(
                    ci is not None and ci.dtype == "code"
                    for ci in (child.get(g) for g in node.group_by)):
                continue
            domains = []
            for g in node.group_by:
                d = node.domain_hints.get(g)
                if d is None:
                    ci = child.get(g)
                    d = ci.domain if ci is not None else None
                domains.append(d)
            if all(d is not None for d in domains):
                total = 1
                for d in domains:
                    total *= d
                if total <= settings.dense_agg_cap:
                    node.strategy = "dense"
                    node.domains = [int(d) for d in domains]
        return plan
