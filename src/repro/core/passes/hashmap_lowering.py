"""Hash-map lowering (paper §3.2.2).

Generic hash aggregation is specialized using schema + statistics knowledge
collected at load time:

  * no group key                     -> 'scalar' (the paper's "single,
    statically-known key" case, e.g. Q6's global aggregate): accumulators
    become scalar registers;
  * all group-key domains statically known and small -> 'dense': the hash
    map becomes a pre-allocated native array indexed by a mixed-radix
    composite of the key codes (the paper's "convert the hash map to a
    native array", with the pre-allocation sized by worst-case analysis and
    the initialization hoisted off the critical path — in XLA the
    accumulator is a statically-shaped zero buffer);
  * otherwise                        -> 'generic' sort-based grouping.

Domains come from: CAT dictionary sizes, dense PK/FK ranges, integer stats,
or explicit statistics hints (`Agg.domain_hints`, §3.5.2).
"""
from __future__ import annotations

from repro.core import ir
from repro.core.passes.provenance import col_domain, col_kind
from repro.relational.loader import Database
from repro.relational.schema import ColKind


class HashMapLowering:
    name = "HashMapLowering"

    def run(self, plan: ir.Plan, db: Database, settings) -> ir.Plan:
        for node in ir.walk(plan):
            if not isinstance(node, ir.Agg) or node.strategy != "generic":
                continue
            if not node.group_by:
                node.strategy = "scalar"
                continue
            # Without string dictionaries a CAT key has no integer code
            # domain — the dictionary IS the domain knowledge (§3.4/§3.2.2).
            if not settings.string_dict and any(
                    col_kind(node.child, g, db) == ColKind.CAT
                    for g in node.group_by):
                continue
            domains = [col_domain(node.child, g, db, node.domain_hints)
                       for g in node.group_by]
            if all(d is not None for d in domains):
                total = 1
                for d in domains:
                    total *= d
                if total <= settings.dense_agg_cap:
                    node.strategy = "dense"
                    node.domains = [int(d) for d in domains]
        return plan
