"""Parameter analysis + binding (compile-once / bind-many execution).

The paper bakes every literal into the staged program; with `Param` nodes a
plan can instead be compiled *once* and re-executed under many bindings
(Dashti et al., "Compiling Database Application Programs").  Two classes of
parameter exist:

  runtime      — numeric Params in expression positions.  They survive the
                 pass pipeline (the plan is *param-residual*: DateIndex skips
                 a bound it cannot resolve statically, FoldAndSimplify keeps
                 the node) and become scalar inputs of the staged program, so
                 re-binding is a pure re-execution of the jitted callable.
  compile-time — string-valued Params (the StringDictionary rewrite needs the
                 concrete value to look up dictionary codes) and Params used
                 as `Limit.n` (the top-k rewrite needs a static k).  These
                 must be substituted before optimization and therefore
                 participate in the plan-cache key.

`ParamBinding` is the pipeline pass realizing "resolve params from a binding
dict at optimize time"; `plan_params` is the analysis the runtime layer uses
to split a binding dict into the two classes.
"""
from __future__ import annotations

import dataclasses

from repro.core import ir
from repro.core.expr import Param, StrContainsWord, StrEq, StrIn, \
    StrStartsWith, substitute_params
from repro.core.passes.cse_dce import transform_exprs


@dataclasses.dataclass(frozen=True)
class ParamInfo:
    dtype: str
    structural: bool   # True -> must be bound at optimize (compile) time


def _plan_exprs(p: ir.Plan):
    for node in ir.walk(p):
        if isinstance(node, ir.Select):
            yield node.pred
        elif isinstance(node, ir.Project):
            yield from node.outputs.values()
        elif isinstance(node, ir.Agg):
            for spec in node.aggs:
                if spec.expr is not None:
                    yield spec.expr


def plan_params(plan: ir.Plan) -> dict[str, ParamInfo]:
    """Every Param in the plan, classified runtime vs compile-time."""
    from repro.core import expr as E

    out: dict[str, ParamInfo] = {}

    def record(p: Param, structural: bool):
        prev = out.get(p.name)
        if prev is not None and prev.dtype != p.dtype:
            raise TypeError(f"parameter {p.name!r} used with dtypes "
                            f"{prev.dtype} and {p.dtype}")
        structural = structural or p.dtype == "str" \
            or (prev.structural if prev else False)
        out[p.name] = ParamInfo(p.dtype, structural)

    def rec(e):
        if isinstance(e, Param):
            record(e, False)
        elif isinstance(e, (E.Arith, E.Cmp, E.And, E.Or)):
            rec(e.lhs), rec(e.rhs)
        elif isinstance(e, (E.Not, E.Year)):
            rec(e.operand)
        elif isinstance(e, E.Where):
            rec(e.cond), rec(e.then), rec(e.other)
        elif isinstance(e, StrEq):
            if isinstance(e.value, Param):
                record(e.value, True)
        elif isinstance(e, StrIn):
            for v in e.values:
                if isinstance(v, Param):
                    record(v, True)
        elif isinstance(e, StrStartsWith):
            if isinstance(e.prefix, Param):
                record(e.prefix, True)
        elif isinstance(e, StrContainsWord):
            if isinstance(e.word, Param):
                record(e.word, True)

    for e in _plan_exprs(plan):
        rec(e)
    for node in ir.walk(plan):
        if isinstance(node, ir.Limit) and isinstance(node.n, Param):
            record(node.n, True)
    return out


def bind_plan(plan: ir.Plan, bindings: dict) -> ir.Plan:
    """Substitute the named Params throughout the plan, in place where
    possible.  Params not named in `bindings` stay residual."""
    if not bindings:
        return plan
    transform_exprs(plan, lambda e: substitute_params(e, bindings))
    for node in ir.walk(plan):
        if isinstance(node, ir.Limit) and isinstance(node.n, Param) \
                and node.n.name in bindings:
            node.n = int(bindings[node.n.name])
    return plan


class ParamBinding:
    """Pipeline pass: resolve parameters from a binding dict at optimize
    time (full specialization — every named literal is baked in, exactly as
    the paper's generated code does)."""

    name = "ParamBinding"

    def __init__(self, bindings: dict):
        self.bindings = dict(bindings)

    def run(self, plan: ir.Plan, db, settings) -> ir.Plan:
        return bind_plan(plan, self.bindings)
