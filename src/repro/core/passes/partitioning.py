"""Data partitioning (paper §3.2.1), TPU-native form.

On TPU, the paper's "1-D partitioned array accessed through the primary
key" is the dense-PK columnar table itself: a foreign-key value *is* the
row index of the parent, so an equi-join on a PK/FK pair lowers to a
vectorized gather (`Join.strategy = 'pk_gather'`).  The generic hash join
(build + probe of a pointer-chased hash table) disappears exactly as in
Fig 7c→7e, but into gathers instead of linked lists.

Requirements checked here:
  * the build side is *parent-aligned*: its rows are (a masked view of) the
    parent table's rows in order — Scans (without date slicing), Selects,
    Projects, nested pk_gather joins, semi/anti masks, and dense
    aggregations whose single group key spans the parent PK domain (Q18's
    agg-then-join) all preserve alignment;
  * the build key is that table's single-column dense primary key;
  * the stream key provably ranges over the same domain (FK declaration).

Semi/anti joins lower to 'exists_flag': a dense boolean array over the key
domain scattered from the build side and gathered at the stream key — the
paper's partitioned-array membership probe.
"""
from __future__ import annotations

from typing import Optional

from repro.core import ir
from repro.core.passes.provenance import key_parent_table
from repro.relational.loader import Database


def aligned_table(p: ir.Plan, db: Database) -> Optional[str]:
    if isinstance(p, ir.Scan):
        return p.table if p.date_slice is None else None
    if isinstance(p, (ir.Select, ir.Project)):
        return aligned_table(p.child, db)
    if isinstance(p, ir.Join):
        if p.kind in ("semi", "anti") or p.strategy == "pk_gather":
            return aligned_table(p.stream, db)
        return None
    if isinstance(p, ir.Agg):
        if p.strategy == "dense" and len(p.group_by) == 1:
            parent = key_parent_table(p.child, p.group_by[0], db)
            if parent is not None and p.domains == [db.table(parent).nrows]:
                return parent
        return None
    return None


class Partitioning:
    name = "Partitioning"

    def run(self, plan: ir.Plan, db: Database, settings) -> ir.Plan:
        self._rewrite(plan, db)
        return plan

    def _rewrite(self, p: ir.Plan, db: Database) -> None:
        for c in ir.children(p):
            self._rewrite(c, db)
        if not isinstance(p, ir.Join) or p.strategy != "generic":
            return
        if p.kind in ("inner", "left"):
            t = aligned_table(p.build, db)
            if t is None:
                return
            sch = db.table(t).schema
            if p.stream_key2 is not None:
                # composite PK -> 2-D partitioned array (§3.2.1)
                if sch.primary_key == (p.build_key, p.build_key2):
                    fk = sch.fk_for(p.build_key)
                    parent = key_parent_table(p.stream, p.stream_key, db)
                    if fk is not None and parent == fk.ref_table:
                        p.strategy = "bucket_gather"
                        p.build_table = t
                        _, p.bucket_width = db.fk_bucket(t, p.build_key)
                return
            build_is_pk = (sch.primary_key == (p.build_key,)
                           or _is_dense_group_key(p.build, p.build_key, db, t))
            stream_parent = key_parent_table(p.stream, p.stream_key, db)
            if build_is_pk and stream_parent == t:
                p.strategy = "pk_gather"
                p.build_table = t
                p.domain = db.table(t).nrows
        else:  # semi / anti
            parent = key_parent_table(p.stream, p.stream_key, db)
            build_parent = key_parent_table(p.build, p.build_key, db)
            if parent is not None and build_parent == parent:
                p.strategy = "exists_flag"
                p.domain = db.table(parent).nrows


def _is_dense_group_key(p: ir.Plan, key: str, db: Database, t: str) -> bool:
    """Build side is a dense Agg keyed on `key` spanning table t's PK."""
    if isinstance(p, (ir.Select, ir.Project)):
        return _is_dense_group_key(p.child, key, db, t)
    return (isinstance(p, ir.Agg) and p.strategy == "dense"
            and p.group_by == [key]
            and p.domains == [db.table(t).nrows])
