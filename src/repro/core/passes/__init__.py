from repro.core.passes.pipeline import LADDER, Settings, build_pipeline, optimize, preset

__all__ = ["Settings", "build_pipeline", "optimize", "preset", "LADDER"]
