"""The SC-analogue transformation pipeline (paper §2.2, Fig 5b).

Each optimization is a `Pass`: a black-box plan→plan transformer with no
dependence on other passes or on the engine base code.  `build_pipeline`
assembles the explicit, settings-driven pipeline exactly as Fig 5b does —
passes can be turned on/off independently and reordered, and constant
folding / simplification runs after each domain-specific pass (the paper's
``ParamPromDCEAndPartiallyEvaluate`` interleaving).

Engine-configuration ladder (paper Table III) is expressed as `Settings`
presets at the bottom of this file.
"""
from __future__ import annotations

import dataclasses
from typing import Protocol

from repro.core import ir


@dataclasses.dataclass
class Settings:
    # --- execution style -----------------------------------------------------
    # 'volcano'  : interpreted operator-at-a-time numpy engine (DBX analogue)
    # 'compiled' : whole-query staged JAX program (LegoBase analogue)
    engine: str = "compiled"
    # operator fusion across the whole query; False inserts optimization
    # barriers between operators ≈ template-expansion compilers that codegen
    # operators independently (HyPer-style scope limit, paper §1/Fig 2).
    fusion: bool = True
    # --- domain-specific optimizations (paper §3) ----------------------------
    partitioning: bool = True       # §3.2.1 PK/FK partitioned joins
    dense_agg: bool = True          # §3.2.2 hash-map lowering to arrays
    date_index: bool = True         # §3.2.3 date indices
    string_dict: bool = True        # §3.4 string dictionaries
    column_pruning: bool = True     # §3.6.1 unused-attribute removal
    cse: bool = True                # §3.6 CSE / partial evaluation
    hoist: bool = True              # §3.5 domain-specific code motion
    layout: str = "column"          # §3.3: 'column' (SoA) or 'row' (AoS)
    # --- beyond-paper ---------------------------------------------------------
    # sharded execution over a 1-D device mesh (passes/sharding.py):
    # 1 = single device (no mesh), 0 = auto (every visible device),
    # n>1 = exactly n.  The resolved count joins the plan-cache key — the
    # same plan at a different mesh shape is a different compiled program
    # with different per-shard capacities.
    shards: int = 1
    use_pallas: bool = False        # fuse hot paths into Pallas TPU kernels
    # Pallas kernel execution mode: None = auto (interpret only when no
    # TPU/GPU backend is present), True/False = forced.
    pallas_interpret: "bool | None" = None
    topk_limit: bool = True         # ORDER BY+LIMIT k -> top-k selection
    dense_agg_cap: int = 1 << 22    # max dense key domain (worst-case alloc)
    # --- selection-vector compaction (passes/compaction.py) -------------------
    compaction: bool = True         # compact masked frames at planned points
    compact_margin: float = 2.0     # capacity headroom over estimated rows
    compact_min_rows: int = 512     # never compact frames smaller than this
    # adaptive capacity feedback (plan_cache.py): observed per-point valid
    # counts drive re-planning — after `compact_replan_after` overflows the
    # entry re-plans with capacities derived from observed max counts, and
    # after `compact_shrink_after` consecutive large underuses (observed
    # < capacity/4 at every point) capacities shrink to the measured
    # bucket.  Each transition costs at most one retrace per direction.
    compact_feedback: bool = True   # on at the `opt` rung (with compaction)
    compact_replan_after: int = 3   # overflows before re-planning up
    compact_shrink_after: int = 4   # consecutive underuses before shrinking
    # internal (set by CompiledQuery for the overflow twin, never by
    # presets): plant measure-only points (capacity 0, frame untouched)
    # at every candidate site instead of real compaction, so a fallback
    # execution reports every site's TRUE count — a count measured below
    # an overflowed point is truncated, and re-planning from truncated
    # counts converges one layer per k overflows instead of in one step.
    compact_measure_only: bool = False
    # --- static analysis / verification (core/analysis) -----------------------
    # run the inter-pass verifier on the input plan and after every pass:
    # a well-formedness violation raises PlanInvariantError naming the
    # offending pass (pass bisection for free).  On by default — the check
    # is a few plan walks per optimize, which only runs at compile time;
    # latency-critical serving paths that re-optimize per plan shape can
    # switch it off (dataclasses.replace(settings, verify_passes=False)).
    verify_passes: bool = True


class Pass(Protocol):
    name: str

    def run(self, plan: ir.Plan, db, settings: Settings) -> ir.Plan: ...


def build_pipeline(settings: Settings, bindings: dict | None = None,
                   est_params: dict | None = None,
                   observed: dict | None = None) -> list[Pass]:
    from repro.core.passes.column_pruning import ColumnPruning
    from repro.core.passes.compaction import Compaction
    from repro.core.passes.cse_dce import FoldAndSimplify
    from repro.core.passes.date_index import DateIndex
    from repro.core.passes.fusion import SelectFusion
    from repro.core.passes.hashmap_lowering import HashMapLowering
    from repro.core.passes.param_binding import ParamBinding
    from repro.core.passes.partitioning import Partitioning
    from repro.core.passes.string_dict import StringDictionary

    pipeline: list[Pass] = []
    if bindings:
        # resolve Params first so every downstream pass sees plain literals
        # (full specialization); without bindings the plan stays
        # param-residual and numeric Params become staged-program inputs.
        pipeline.append(ParamBinding(bindings))
    pipeline.append(SelectFusion())           # always: canonicalizes Select chains
    if settings.cse:
        pipeline.append(FoldAndSimplify())
    if settings.date_index:
        pipeline.append(DateIndex())
    if settings.dense_agg:
        pipeline.append(HashMapLowering())
    if settings.partitioning:
        pipeline.append(Partitioning())
    if settings.string_dict:
        pipeline.append(StringDictionary())
    if settings.cse:
        pipeline.append(FoldAndSimplify())
    if settings.shards != 1:
        # after the join/agg strategies are fixed (it keys off them) and
        # before ColumnPruning (Exchange nodes are schema-transparent) /
        # Compaction (capacities must be planned per shard).
        from repro.core.passes.sharding import Sharding

        pipeline.append(Sharding())
    if settings.column_pruning:
        pipeline.append(ColumnPruning())      # prune post-rewrite
    if settings.compaction:
        # last: capacities are planned against the final operator strategies
        # (join lowering, dense aggs, date slices) chosen above.
        # `est_params` are the first-seen runtime bindings (initial
        # estimates for Param-bounded predicates); `observed` maps
        # candidate point ids to measured valid counts and overrides the
        # static estimates on re-plan (adaptive capacity feedback).
        pipeline.append(Compaction(est_params=est_params, observed=observed))
    return pipeline


def optimize(plan: ir.Plan, db, settings: Settings,
             bindings: dict | None = None,
             est_params: dict | None = None,
             observed: dict | None = None) -> ir.Plan:
    pipeline = build_pipeline(settings, bindings, est_params, observed)
    if not settings.verify_passes:
        for p in pipeline:
            plan = p.run(plan, db, settings)
        return plan
    from repro.core.analysis.verify import verify_plan

    # verify the hand-written input too (pass_name 'input'), then after
    # each pass; final-only rules (e.g. key-pack) run after the last one
    verify_plan(plan, db, settings, pass_name="input", final=False)
    last = len(pipeline) - 1
    for i, p in enumerate(pipeline):
        plan = p.run(plan, db, settings)
        verify_plan(plan, db, settings, pass_name=p.name, final=(i == last))
    return plan


# ---------------------------------------------------------------------------
# Engine ladder presets (paper Table III)
# ---------------------------------------------------------------------------

def preset(name: str) -> Settings:
    if name == "dbx":            # commercial in-memory DBMS, no compilation
        return Settings(engine="volcano", fusion=False, partitioning=False,
                        dense_agg=False, date_index=False, string_dict=False,
                        column_pruning=False, cse=False, hoist=False,
                        compaction=False)
    if name == "naive":          # LegoBase(Naive): inlining/push only
        return Settings(engine="compiled", fusion=True, partitioning=False,
                        dense_agg=False, date_index=False, string_dict=False,
                        column_pruning=False, cse=False, hoist=False,
                        topk_limit=False, compaction=False)
    if name == "template":       # HyPer-style: per-operator codegen scope
        return Settings(engine="compiled", fusion=False, partitioning=True,
                        dense_agg=False, date_index=False, string_dict=False,
                        column_pruning=False, cse=False, hoist=False,
                        topk_limit=False, compaction=False)
    if name == "tpch":           # LegoBase(TPC-H/C): + partitioning
        return Settings(engine="compiled", fusion=True, partitioning=True,
                        dense_agg=False, date_index=False, string_dict=False,
                        column_pruning=False, cse=False, hoist=False,
                        topk_limit=False, compaction=False)
    if name == "strdict":        # LegoBase(StrDict/C)
        return Settings(engine="compiled", fusion=True, partitioning=True,
                        dense_agg=False, date_index=False, string_dict=True,
                        column_pruning=False, cse=False, hoist=False,
                        topk_limit=False, compaction=False)
    if name == "opt":            # LegoBase(Opt/C): everything
        return Settings()
    if name == "opt-pallas":     # beyond paper: + Pallas fused kernels
        return Settings(use_pallas=True)
    if name == "opt-shard":      # beyond paper: + mesh-sharded execution
        return Settings(shards=0)
    if name == "mask-only":      # serving degradation rung: see degrade()
        return degrade(Settings())
    raise KeyError(name)


def degrade(settings: Settings) -> Settings:
    """The serving degradation rung for `settings` (QueryServer's ladder,
    docs §10): keep every semantic rewrite but drop the latency-tuning
    machinery whose compile cost is unaffordable under overload —
    compaction (capacity planning + gather points), its adaptive
    feedback (re-plans retrace), and the per-optimize pass verifier.
    Frames stay mask-only, so results are bit-identical; only the
    padded-row waste changes.  Because `Settings` joins the plan-cache
    key, degraded entries coexist with full entries for the same plan."""
    return dataclasses.replace(settings, compaction=False,
                               compact_feedback=False, verify_passes=False)


LADDER = ["dbx", "naive", "tpch", "strdict", "opt"]
