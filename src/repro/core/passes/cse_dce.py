"""Partial evaluation + simplification (paper §3.6).

Constant folding over every expression in the plan (the SC
``PartiallyEvaluate`` step that runs after each domain-specific pass), and
removal of Selects whose predicate folded to TRUE.  Expression-level CSE is
performed by the staging evaluator (structural memoization in `EvalEnv`);
dead *column* elimination is the ColumnPruning pass.
"""
from __future__ import annotations

from repro.core import ir
from repro.core.expr import Const, fold_constants


def transform_exprs(p: ir.Plan, fn) -> None:
    """Apply `fn` to every expression in the plan, in place."""
    for node in ir.walk(p):
        if isinstance(node, ir.Select):
            node.pred = fn(node.pred)
        elif isinstance(node, ir.Project):
            node.outputs = {k: fn(v) for k, v in node.outputs.items()}
        elif isinstance(node, ir.Agg):
            for spec in node.aggs:
                if spec.expr is not None:
                    spec.expr = fn(spec.expr)


class FoldAndSimplify:
    name = "FoldAndSimplify"

    def run(self, plan: ir.Plan, db, settings) -> ir.Plan:
        transform_exprs(plan, fold_constants)
        return _drop_true_selects(plan)


def _drop_true_selects(p: ir.Plan) -> ir.Plan:
    kids = [_drop_true_selects(c) for c in ir.children(p)]
    ir.replace_children(p, kids)
    if isinstance(p, ir.Select) and isinstance(p.pred, Const) and p.pred.value:
        return p.child
    return p
