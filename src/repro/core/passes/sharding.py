"""Sharding: lower the plan onto a 1-D device mesh (beyond-paper).

The paper's partitioning (§3.2.1) is a *logical* specialization — joins
become gathers because the parent table IS the hash table.  This pass
makes the same idea *physical*: the partition root (and every table
FK-routed to it) is split across the mesh's data axis, and the whole
staged program runs under `shard_map`, each shard seeing only its block.

Where co-partitioning holds, nothing moves: a pk_gather between a routed
child and the root probes shard-locally (the FK rebases into the local
block).  Where it is violated, this pass plants an **explicit**
`ir.Exchange` node — never silent resharding:

  * generic / bucket_gather join builds over a partitioned subtree
    (key-order or positional access over the full frame);
  * pk_gather builds that are not co-partitioned with their probe side
    (stream part != build part, or the build is not the partition root);
  * global Sort / Limit and generic (sort-based) Agg inputs;
  * the plan root, when still partitioned at output.

Scalar and dense aggregations get **no** Exchange: their operators
combine shard-local partials in place (psum/pmin/pmax), and exists_flag
builds union their dense flag vectors with a pmax — both strictly
cheaper than materializing the gathered frame.

The verifier (analysis/verify.py) re-derives the same partition
properties and enforces (a) no partitioned frame reaches a
shard-variant operator, (b) every Exchange is load-bearing, and (c) the
per-query Exchange count never exceeds the number of eligible
consumers.
"""
from __future__ import annotations

from repro.core import ir
from repro.core.passes.pipeline import Settings


def partitioned_tables(db, settings: Settings) -> set[str]:
    """Tables the Sharding pass will partition (root + FK-routed).

    Passes that run *earlier* (DateIndex) consult this to keep their
    hands off: a global date-clustering permutation and a row-range /
    routed partition cannot compose — the permutation would scramble
    block ownership.
    """
    from repro.core.mesh import resolve_shards

    n = resolve_shards(settings)
    if n == 1:
        return set()
    sp = db.shard_plan(n)
    return {t for t in db.tables if sp.part_of(t) is not None}


class Sharding:
    name = "Sharding"

    def run(self, plan: ir.Plan, db, settings: Settings) -> ir.Plan:
        from repro.core.mesh import resolve_shards

        n = resolve_shards(settings)
        if n == 1:
            return plan
        sp = db.shard_plan(n)
        plan, part = self._walk(plan, sp, n)
        if part is not None:
            # partitioned at output (plan root is an eligible consumer):
            # gather so the caller sees the full result on every shard.
            plan = ir.Exchange(plan, key=None, kind="gather")
        return plan

    # The walk mirrors the operators' Frame.part threading exactly:
    # returns (possibly rewritten subtree, partition root or None).
    def _walk(self, p: ir.Plan, sp, n: int):
        if isinstance(p, ir.Scan):
            part = sp.part_of(p.table)
            if part is None or p.date_slice is not None:
                # date-sliced scans read the date-clustered permutation,
                # which DateIndex only builds for unpartitioned tables —
                # partitioned_tables() keeps the two passes disjoint, so
                # this arm only fires for hand-built plans.
                return p, None
            p.shard = ir.ShardInfo(part=part, n_shards=n,
                                   per_shard_rows=sp.rows_per_shard(p.table))
            return p, part

        if isinstance(p, ir.Join):
            p.stream, s_part = self._walk(p.stream, sp, n)
            p.build, b_part = self._walk(p.build, sp, n)
            if p.strategy == "exists_flag":
                # dense membership flags are permutation-safe: the
                # operator pmax-unions shard-local flag vectors in place.
                return p, s_part
            if p.strategy == "pk_gather":
                co = (b_part is not None and s_part == b_part
                      and b_part == p.build_table)
                if b_part is not None and not co:
                    p.build = ir.Exchange(p.build, key=p.build_key)
                return p, s_part
            # generic / bucket_gather need the whole build frame
            # (sort order resp. global positional addressing).
            if b_part is not None:
                p.build = ir.Exchange(p.build, key=p.build_key)
            return p, s_part

        if isinstance(p, ir.Agg):
            p.child, c_part = self._walk(p.child, sp, n)
            if p.strategy in ("scalar", "dense"):
                # shard-local partials + in-operator psum/pmin/pmax
                # combine; output is replicated.
                return p, None
            if c_part is not None:
                key = p.group_by[0] if p.group_by else None
                p.child = ir.Exchange(p.child, key=key)
            return p, None

        if isinstance(p, (ir.Sort, ir.Limit)):
            p.child, c_part = self._walk(p.child, sp, n)
            if isinstance(p, ir.Limit) and isinstance(p.child, ir.Sort):
                return p, None  # the Sort arm below already gathered
            if c_part is not None:
                key = (p.keys[0][0] if isinstance(p, ir.Sort) and p.keys
                       else None)
                p.child = ir.Exchange(p.child, key=key)
            return p, None

        if isinstance(p, ir.Exchange):  # hand-planted
            p.child, _ = self._walk(p.child, sp, n)
            return p, None

        # Select / Project / Compact: partition passes straight through
        p.child, c_part = self._walk(p.child, sp, n)
        return p, c_part
