"""Date indices (paper §3.2.3), TPU-native form.

The paper clusters rows into year buckets at load time so a date predicate
can skip whole buckets.  With columnar storage we cluster by the *full*
date (load-time sort, `Database.date_cluster`) and lower a date-range
conjunct into a **static row-slice over the clustered permutation**,
resolved host-side at staging time.  The bucket granularity becomes exact,
so the residual per-tuple `if` disappears entirely — a strict improvement
with the same load-time mechanism.

Restriction: a date-sliced scan is re-ordered/subset, which breaks the
parent-row alignment the Partitioning pass needs on the *build* side of an
inner join; the pass therefore only rewrites scans that never serve as an
inner-join build input.
"""
from __future__ import annotations

from repro.core import ir
from repro.core.expr import Cmp, Col, Const, conjoin, conjuncts
from repro.relational.loader import Database
from repro.relational.schema import ColKind


class DateIndex:
    name = "DateIndex"

    def run(self, plan: ir.Plan, db: Database, settings) -> ir.Plan:
        skip = _inner_build_tables(plan)
        if getattr(settings, "shards", 1) != 1:
            # a date-clustered permutation and a range/routed partition
            # cannot compose (the global sort scrambles block ownership):
            # the Sharding pass wins on the tables it will partition.
            from repro.core.passes.sharding import partitioned_tables

            skip = skip | partitioned_tables(db, settings)
        return _rewrite(plan, db, skip)


def _inner_build_tables(plan: ir.Plan) -> set[str]:
    out: set[str] = set()
    for node in ir.walk(plan):
        if isinstance(node, ir.Join) and node.kind in ("inner", "left"):
            for sub in ir.walk(node.build):
                if isinstance(sub, ir.Scan):
                    out.add(sub.table)
    return out


def _rewrite(p: ir.Plan, db: Database, skip: set[str]) -> ir.Plan:
    kids = [_rewrite(c, db, skip) for c in ir.children(p)]
    ir.replace_children(p, kids)

    if not (isinstance(p, ir.Select) and isinstance(p.child, ir.Scan)):
        return p
    scan = p.child
    if scan.table in skip or scan.date_slice is not None:
        return p

    table = db.table(scan.table)
    parts = conjuncts(p.pred)
    # collect per-date-column bounds of the form  Col(date) <op> Const
    bounds: dict[str, dict[str, int]] = {}
    used: dict[str, list] = {}
    for c in parts:
        # A Param bound (rhs not Const) cannot be resolved to a static row
        # slice at staging time: the conjunct is left in the Select and the
        # plan stays param-residual — the predicate evaluates per tuple with
        # the parameter as a runtime scalar input.
        if not (isinstance(c, Cmp) and isinstance(c.lhs, Col)
                and isinstance(c.rhs, Const)):
            continue
        name = c.lhs.name
        if not (table.schema.has_col(name)
                and table.schema.col(name).kind == ColKind.DATE):
            continue
        b = bounds.setdefault(name, {})
        v = int(c.rhs.value)
        if c.op in (">=", ">"):
            b["lo"] = max(b.get("lo", -(1 << 30)), v + (1 if c.op == ">" else 0))
        elif c.op in ("<", "<="):
            b["hi"] = min(b.get("hi", 1 << 30), v + (1 if c.op == "<=" else 0))
        else:
            continue
        used.setdefault(name, []).append(c)

    if not bounds:
        return p
    # choose the most selective date column (estimated from load-time stats)
    best, best_sel = None, 2.0
    for name, b in bounds.items():
        st = table.stats[name]
        span = max(st.max - st.min, 1.0)
        sel = (min(b.get("hi", 1 << 30), st.max + 1)
               - max(b.get("lo", -(1 << 30)), st.min)) / span
        if sel < best_sel:
            best, best_sel = name, sel
    b = bounds[best]
    scan.date_slice = ir.DateSlice(best,
                                   b.get("lo", None),
                                   b.get("hi", None))
    rest = [c for c in parts if c not in used[best]]
    if not rest:
        return scan
    return ir.Select(scan, conjoin(rest))
