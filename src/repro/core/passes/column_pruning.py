"""Unused-attribute removal (paper §3.6.1 / struct-field removal §3.7).

Walks the plan top-down computing the set of columns each subtree must
produce and sets `Scan.columns` to exactly that set — pruned columns are
never registered as inputs of the staged program, so they are never loaded
to device (the paper's "avoids loading these unnecessary attributes into
memory").
"""
from __future__ import annotations

from repro.core import ir
from repro.core.expr import expr_columns
from repro.relational.loader import Database


def output_columns(p: ir.Plan, db: Database) -> set[str]:
    if isinstance(p, ir.Scan):
        cols = db.table(p.table).schema.column_names
        return set(cols if p.columns is None else p.columns)
    if isinstance(p, ir.Select):
        return output_columns(p.child, db)
    if isinstance(p, ir.Project):
        base = output_columns(p.child, db) if p.keep_input else set()
        return base | set(p.outputs)
    if isinstance(p, ir.Join):
        s = output_columns(p.stream, db)
        if p.kind in ("semi", "anti"):
            return s
        return s | output_columns(p.build, db)
    if isinstance(p, ir.Agg):
        return set(p.group_by) | set(p.carry) | {a.name for a in p.aggs}
    if isinstance(p, (ir.Sort, ir.Limit, ir.Compact, ir.Exchange)):
        return output_columns(p.child, db)
    raise TypeError(type(p))


class ColumnPruning:
    name = "ColumnPruning"

    def run(self, plan: ir.Plan, db: Database, settings) -> ir.Plan:
        _prune(plan, output_columns(plan, db), db)
        return plan


def _prune(p: ir.Plan, needed: set[str], db: Database) -> None:
    if isinstance(p, ir.Scan):
        avail = set(db.table(p.table).schema.column_names)
        cols = sorted(needed & avail)
        if p.date_slice is not None and p.date_slice.col in avail:
            # the clustered permutation is the only remnant of the date col
            pass
        p.columns = cols
        return
    if isinstance(p, ir.Select):
        _prune(p.child, needed | expr_columns(p.pred), db)
        return
    if isinstance(p, ir.Project):
        child_needed = set(needed) - set(p.outputs) if not p.keep_input else set(needed) - set(p.outputs)
        for name, e in p.outputs.items():
            if name in needed or not p.keep_input:
                child_needed |= expr_columns(e)
        if p.keep_input:
            child_needed |= needed - set(p.outputs)
        _prune(p.child, child_needed, db)
        return
    if isinstance(p, ir.Join):
        s_avail = output_columns(p.stream, db)
        b_avail = output_columns(p.build, db)
        s_keys = {p.stream_key} | ({p.stream_key2} if p.stream_key2 else set())
        b_keys = {p.build_key} | ({p.build_key2} if p.build_key2 else set())
        _prune(p.stream, (needed & s_avail) | s_keys, db)
        _prune(p.build, ((needed - s_avail) & b_avail) | b_keys, db)
        return
    if isinstance(p, ir.Agg):
        child_needed = set(p.group_by) | set(p.carry)
        for a in p.aggs:
            if a.expr is not None:
                child_needed |= expr_columns(a.expr)
        _prune(p.child, child_needed, db)
        return
    if isinstance(p, ir.Sort):
        _prune(p.child, needed | {k for k, _ in p.keys}, db)
        return
    if isinstance(p, (ir.Limit, ir.Compact, ir.Exchange)):
        # an Exchange gathers whatever its child produces: no new needs
        _prune(p.child, needed, db)
        return
    raise TypeError(type(p))
