"""Query plan IR (logical nodes progressively annotated into physical form).

Progressive lowering (paper §2.3): the plan starts purely logical
(strategy fields at their 'generic' defaults) and each SC-style pass
annotates/rewrites it — Join.strategy 'generic'→'pk_gather', Agg.strategy
'generic'→'dense'/'scalar', Scan.date_slice set, string predicates rewritten
to code predicates, Scan.columns pruned.  `compile.py` then stages the
lowered plan into a single JAX function; `volcano.py` interprets the
*unlowered* plan operator-at-a-time.

Join orientation convention: `build` is the parent/PK side (the side a
hash table would be built on), `stream` is the probe side.  All TPC-H
equi-joins orient naturally with the FK holder streaming.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.expr import Expr


@dataclasses.dataclass
class DateSlice:
    """Static row-range over a date-clustered permutation (§3.2.3)."""
    col: str
    lo: Optional[int]  # inclusive day, None = open
    hi: Optional[int]  # exclusive day, None = open


@dataclasses.dataclass
class ShardInfo:
    """Physical partitioning annotation (Sharding pass, §3.2.1 made
    physical): the scan's rows live partitioned over the mesh's data axis.

    `part` is the partition-root table — the range-partitioned parent
    whose PK range decides row ownership.  The root itself has
    `part == table` (row-range by dense PK, shard s owns rows
    [s*P, (s+1)*P)); an FK child is hash-routed so every row lands on the
    shard owning its parent (`owner = fk // P`).  Two scans with the same
    `part` are co-partitioned: a pk_gather between them never crosses
    shards.  `per_shard_rows` is the static padded per-shard row count
    (the frame's physical height inside shard_map)."""
    part: str
    n_shards: int
    per_shard_rows: int


@dataclasses.dataclass
class Scan:
    table: str
    # set by ColumnPruning: None = all columns
    columns: Optional[list[str]] = None
    # set by DateIndex: replaces the matching conjuncts of an enclosing Select
    date_slice: Optional[DateSlice] = None
    # set by Sharding: table is partitioned over the data axis
    shard: Optional[ShardInfo] = None


@dataclasses.dataclass
class Select:
    child: "Plan"
    pred: Expr


@dataclasses.dataclass
class Project:
    child: "Plan"
    outputs: dict[str, Expr]  # name -> expr; also acts as rename
    keep_input: bool = True   # keep the child's columns alongside


@dataclasses.dataclass
class Join:
    stream: "Plan"
    build: "Plan"
    stream_key: str
    build_key: str
    kind: str = "inner"          # inner | semi | anti | left
    strategy: str = "generic"    # generic | pk_gather | exists_flag | bucket_gather
    build_table: Optional[str] = None  # parent table when pk_gather
    domain: Optional[int] = None       # key domain when exists_flag
    # composite-key equi joins (paper §3.2.1 composite PKs, e.g. partsupp):
    # second key pair; bucket_gather probes the load-time 2-D partitioned
    # array on the first key and discriminates on the second within buckets.
    stream_key2: Optional[str] = None
    build_key2: Optional[str] = None
    bucket_width: Optional[int] = None


@dataclasses.dataclass
class AggSpec:
    name: str
    fn: str          # sum | count | avg | min | max
    expr: Optional[Expr] = None  # None for count(*)


@dataclasses.dataclass
class Agg:
    child: "Plan"
    group_by: list[str]
    aggs: list[AggSpec]
    # columns functionally dependent on the group key (e.g. Q3's o_orderdate
    # given group key l_orderkey) — carried via a 'max' aggregate.
    carry: list[str] = dataclasses.field(default_factory=list)
    strategy: str = "generic"    # generic | dense | scalar  (HashMapLowering)
    # for dense: mixed-radix index expr metadata filled by the pass
    domains: Optional[list[int]] = None
    # statistics hints for derived group keys (paper §3.5.2: key domains
    # inferred from load-time statistics), e.g. Q13's per-customer count.
    domain_hints: dict[str, int] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Compact:
    """Selection-vector compaction (paper §3.2 data-structure
    specialization, XLA-native form): gather the child's mask-valid rows
    into a dense frame of statically planned `capacity` rows.

    Inserted by the Compaction pass after selective operators and before
    expensive consumers, so downstream sorts/gathers/aggregations run over
    `capacity` rows instead of the child's full cardinality.  JAX's
    static-shape constraint makes `capacity` a compile-time constant
    (a power-of-two bucket over the estimated valid-row count); if more
    rows survive at runtime than the planner estimated, the staged
    program's overflow flag fires and the runtime re-executes the
    uncompacted twin plan (CompiledQuery's fallback) — compaction is a
    performance contract, never a correctness one.

    `point_id` names the *candidate site* this point was planted at: the
    Compaction pass numbers every site it considers (planted or not) in
    walk order, so an id stays stable across re-plans even when planting
    decisions change.  The staged program reports each point's true valid
    count keyed by this id, and `PlanCache`'s feedback store uses the
    same ids to override the static estimates on re-plan.  Hand-planted
    nodes (point_id None) get a stable `h<i>` id: the Compaction pass
    assigns it during its walk (so the adaptive feedback can re-plan
    hand-planted capacities too), or compile time does when the pass is
    off.

    `translate=True` additionally emits the CSR key→slot translation
    vector over the child's row domain (`slot_of[row] = compacted slot,
    -1 when invalid`), carried on the staged Frame.  This is what lets a
    `pk_gather` build side be compacted: the join probes `slot_of` by key
    value first, translating parent-positional addressing into the
    compacted frame (q17-class selective builds).  The Compaction pass
    plants translate points on pk_gather build sides under
    `Settings.use_pallas`; the verifier accepts a translated build where
    it would otherwise require positional alignment.
    """
    child: "Plan"
    capacity: int
    point_id: Optional[str] = None
    translate: bool = False


@dataclasses.dataclass
class Exchange:
    """Explicit cross-shard data movement (planted by the Sharding pass).

    Sits between a partitioned producer and a consumer that needs a
    different physical distribution.  Only planted where co-partitioning
    is violated — generic/bucket_gather join builds, pk_gather builds
    whose probe side is partitioned on a different root, global sorts,
    generic (sort-based) aggregations, and the plan root.  Scalar and
    dense aggregations do NOT get an Exchange: they combine shard-local
    partials in-operator through psum/pmin/pmax.

    kind:
      gather — all-gather the shard blocks along the data axis so every
               shard holds the full (global) frame; padded rows stay
               masked out.  Because the partition is row-range over a
               padded block layout, tiled all-gather reconstitutes global
               positional order, so parent-table alignment properties are
               restored (the verifier's Exchange rule relies on this).

    `key` names the column the downstream consumer keys on (diagnostic —
    a future repartition kind would hash on it)."""
    child: "Plan"
    key: Optional[str] = None
    kind: str = "gather"


@dataclasses.dataclass
class Sort:
    child: "Plan"
    keys: list[tuple[str, bool]]  # (col, ascending)


@dataclasses.dataclass
class Limit:
    child: "Plan"
    # a Param here is a *compile-time* parameter: the top-k rewrite needs a
    # static k, so it must be resolved (passes.param_binding) before staging.
    n: "int | object"


Plan = Scan | Select | Project | Join | Agg | Compact | Exchange | Sort | Limit


def children(p: Plan) -> list[Plan]:
    if isinstance(p, Scan):
        return []
    if isinstance(p, Join):
        return [p.stream, p.build]
    return [p.child]


def replace_children(p: Plan, new: list[Plan]) -> None:
    if isinstance(p, Scan):
        return
    if isinstance(p, Join):
        p.stream, p.build = new
        return
    p.child = new[0]


def walk(p: Plan):
    yield p
    for c in children(p):
        yield from walk(c)


def plan_repr(p: Plan, indent: int = 0) -> str:
    """Readable plan dump including the *physical* annotations the passes
    attach (strategies, date-slice bounds, pruned column lists, planned
    capacities) — what verifier errors and pass debugging quote, so a
    dump must pin down the exact lowering, not just the logical shape."""
    pad = "  " * indent
    if isinstance(p, Scan):
        extra = ""
        if p.date_slice:
            ds = p.date_slice
            extra += f" date_slice[{ds.col}:{ds.lo}..{ds.hi}]"
        if p.columns is not None:
            extra += f" cols={p.columns}"
        if p.shard is not None:
            extra += (f" shard[{p.shard.part}x{p.shard.n_shards}"
                      f"@{p.shard.per_shard_rows}]")
        return f"{pad}Scan({p.table}{extra})"
    if isinstance(p, Select):
        return f"{pad}Select\n{plan_repr(p.child, indent + 1)}"
    if isinstance(p, Project):
        keep = "" if p.keep_input else ", keep_input=False"
        return (f"{pad}Project({list(p.outputs)}{keep})\n"
                f"{plan_repr(p.child, indent + 1)}")
    if isinstance(p, Join):
        keys = f"{p.stream_key}={p.build_key}"
        if p.stream_key2 is not None or p.build_key2 is not None:
            keys += f", {p.stream_key2}={p.build_key2}"
        extra = ""
        if p.build_table is not None:
            extra += f" build_table={p.build_table}"
        if p.domain is not None:
            extra += f" domain={p.domain}"
        if p.bucket_width is not None:
            extra += f" bucket_width={p.bucket_width}"
        return (f"{pad}Join[{p.kind}/{p.strategy}]({keys}){extra}\n"
                f"{plan_repr(p.stream, indent + 1)}\n{plan_repr(p.build, indent + 1)}")
    if isinstance(p, Agg):
        extra = ""
        if p.carry:
            extra += f", carry={p.carry}"
        if p.domains is not None:
            extra += f", domains={p.domains}"
        return (f"{pad}Agg[{p.strategy}](by={p.group_by}, "
                f"aggs={[a.name for a in p.aggs]}{extra})\n"
                f"{plan_repr(p.child, indent + 1)}")
    if isinstance(p, Compact):
        pid = f", point={p.point_id}" if p.point_id is not None else ""
        tr = ", translate" if p.translate else ""
        return (f"{pad}Compact(cap={p.capacity}{pid}{tr})\n"
                f"{plan_repr(p.child, indent + 1)}")
    if isinstance(p, Exchange):
        key = f", key={p.key}" if p.key is not None else ""
        return (f"{pad}Exchange[{p.kind}]({key.lstrip(', ')})\n"
                f"{plan_repr(p.child, indent + 1)}")
    if isinstance(p, Sort):
        return f"{pad}Sort({p.keys})\n{plan_repr(p.child, indent + 1)}"
    if isinstance(p, Limit):
        return f"{pad}Limit({p.n})\n{plan_repr(p.child, indent + 1)}"
    raise TypeError(type(p))
