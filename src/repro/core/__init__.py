"""The paper's primary contribution: an SC-style staged query compiler,
organized in three layers (docs/architecture.md):

  expr.py / ir.py     — expression + plan IR (incl. Param query parameters)
  passes/             — the optimization-pass library (paper §3)
  operators/          — physical operators: stage(node, ctx) -> Frame
  compile.py          — the staging driver producing one XLA program
                        (scalar and vmapped bind-many entry points)
  plan_cache.py       — runtime: compile-once / bind-many plan cache,
                        batched `execute_many` over plan-key groups
  volcano.py          — interpreted baseline engine (no compilation)
"""
from repro.core.compile import CompiledQuery
from repro.core.passes.pipeline import (LADDER, Settings, degrade, optimize,
                                        preset)
from repro.core.plan_cache import PlanCache
from repro.core.volcano import VolcanoEngine

__all__ = ["CompiledQuery", "PlanCache", "VolcanoEngine", "Settings",
           "optimize", "preset", "degrade", "LADDER"]
