"""The paper's primary contribution: an SC-style staged query compiler,
organized in three layers (docs/architecture.md):

  expr.py / ir.py     — expression + plan IR (incl. Param query parameters)
  passes/             — the optimization-pass library (paper §3)
  operators/          — physical operators: stage(node, ctx) -> Frame
  compile.py          — the staging driver producing one XLA program
                        (scalar and vmapped bind-many entry points)
  plan_cache.py       — runtime: compile-once / bind-many plan cache,
                        batched `execute_many` over plan-key groups;
                        tier-aware cold serving + background promotion
  volcano.py          — interpreted baseline engine (no compilation);
                        `OracleQuery` is the tier ladder's bottom rung
  tiering.py          — the execution-tier ladder (oracle -> interpret
                        -> compiled -> opt-pallas) + Runnable protocol
  persist.py          — warm-state persistence (feedback store + warm
                        metadata; JAX compilation-cache wiring)
"""
from repro.core.compile import CompiledQuery
from repro.core.passes.pipeline import (LADDER, Settings, degrade, optimize,
                                        preset)
from repro.core.persist import enable_compilation_cache
from repro.core.plan_cache import PlanCache
from repro.core.tiering import (COMPILED, INTERPRET, OPT_PALLAS, ORACLE,
                                TIERS, ExecutionTier, Runnable, TierLadder)
from repro.core.volcano import OracleQuery, VolcanoEngine

__all__ = ["CompiledQuery", "PlanCache", "VolcanoEngine", "OracleQuery",
           "Settings", "optimize", "preset", "degrade", "LADDER",
           "ExecutionTier", "TierLadder", "Runnable", "TIERS",
           "ORACLE", "INTERPRET", "COMPILED", "OPT_PALLAS",
           "enable_compilation_cache"]
