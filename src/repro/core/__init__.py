"""The paper's primary contribution: an SC-style staged query compiler.

  expr.py / ir.py     — expression + plan IR
  passes/             — the optimization-pass library (paper §3)
  compile.py          — whole-query staging to one specialized XLA program
  volcano.py          — interpreted baseline engine (no compilation)
"""
from repro.core.compile import CompiledQuery
from repro.core.passes.pipeline import LADDER, Settings, optimize, preset
from repro.core.volcano import VolcanoEngine

__all__ = ["CompiledQuery", "VolcanoEngine", "Settings", "optimize",
           "preset", "LADDER"]
