"""Backend shim: the staging walker runs twice — once eagerly on numpy
(8-row samples, to collect the input set and exercise static decisions) and
once under jax tracing (the real staged program).  This shim abstracts the
handful of ops whose spelling differs."""
from __future__ import annotations

import numpy as np


class NumpyBackend:
    name = "numpy"
    xp = np

    @staticmethod
    def take(arr, idx):
        n = arr.shape[0]
        if n == 0:  # collection walk over an empty sample slice
            return np.zeros((len(idx),) + arr.shape[1:], dtype=arr.dtype)
        return arr[np.clip(idx, 0, n - 1)]

    @staticmethod
    def segment_sum(data, ids, n):
        out = np.zeros((n,) + data.shape[1:], dtype=data.dtype)
        np.add.at(out, np.clip(ids, 0, n - 1), data)
        return out

    @staticmethod
    def segment_max(data, ids, n, fill):
        out = np.full((n,) + data.shape[1:], fill, dtype=data.dtype)
        np.maximum.at(out, np.clip(ids, 0, n - 1), data)
        return out

    @staticmethod
    def segment_min(data, ids, n, fill):
        out = np.full((n,) + data.shape[1:], fill, dtype=data.dtype)
        np.minimum.at(out, np.clip(ids, 0, n - 1), data)
        return out

    @staticmethod
    def lexsort(keys):
        return np.lexsort(tuple(keys))

    @staticmethod
    def barrier(x):
        return x

    @staticmethod
    def searchsorted(a, v):
        return np.searchsorted(a, v)


class JaxBackend:
    name = "jax"

    def __init__(self):
        import jax
        import jax.numpy as jnp

        self.xp = jnp
        self._jax = jax

    def take(self, arr, idx):
        # jnp gather clamps out-of-bounds indices by default
        return arr[idx]

    def segment_sum(self, data, ids, n):
        import jax

        return jax.ops.segment_sum(data, ids, num_segments=n)

    def segment_max(self, data, ids, n, fill):
        import jax
        import jax.numpy as jnp

        out = jax.ops.segment_max(data, ids, num_segments=n)
        # segment_max fills empty segments with -inf/min; normalize to fill
        neutral = jnp.asarray(fill, dtype=data.dtype)
        lo = -jnp.inf if data.dtype.kind == "f" else jnp.iinfo(data.dtype).min
        return jnp.where(out == lo, neutral, out)

    def segment_min(self, data, ids, n, fill):
        import jax
        import jax.numpy as jnp

        out = jax.ops.segment_min(data, ids, num_segments=n)
        neutral = jnp.asarray(fill, dtype=data.dtype)
        hi = jnp.inf if data.dtype.kind == "f" else jnp.iinfo(data.dtype).max
        return jnp.where(out == hi, neutral, out)

    def lexsort(self, keys):
        import jax.numpy as jnp

        return jnp.lexsort(tuple(keys))

    def barrier(self, x):
        import jax

        return jax.lax.optimization_barrier(x)

    def searchsorted(self, a, v):
        import jax.numpy as jnp

        return jnp.searchsorted(a, v)
