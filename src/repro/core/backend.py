"""Backend shim: the staging walker runs twice — once eagerly on numpy
(8-row samples, to collect the input set and exercise static decisions) and
once under jax tracing (the real staged program).  This shim abstracts the
handful of ops whose spelling differs."""
from __future__ import annotations

import numpy as np


class NumpyBackend:
    name = "numpy"
    xp = np

    @staticmethod
    def take(arr, idx):
        n = arr.shape[0]
        if n == 0:  # collection walk over an empty sample slice
            return np.zeros((len(idx),) + arr.shape[1:], dtype=arr.dtype)
        return arr[np.clip(idx, 0, n - 1)]

    @staticmethod
    def segment_sum(data, ids, n):
        out = np.zeros((n,) + data.shape[1:], dtype=data.dtype)
        np.add.at(out, np.clip(ids, 0, n - 1), data)
        return out

    @staticmethod
    def segment_max(data, ids, n, fill):
        out = np.full((n,) + data.shape[1:], fill, dtype=data.dtype)
        np.maximum.at(out, np.clip(ids, 0, n - 1), data)
        return out

    @staticmethod
    def segment_min(data, ids, n, fill):
        out = np.full((n,) + data.shape[1:], fill, dtype=data.dtype)
        np.minimum.at(out, np.clip(ids, 0, n - 1), data)
        return out

    @staticmethod
    def lexsort(keys):
        return np.lexsort(tuple(keys))

    @staticmethod
    def compact(mask, capacity):
        """(idx int32[capacity], count int32): row ids of the mask's valid
        rows, in order, zero-padded past `count`.  `count` may exceed
        `capacity` (the caller's overflow signal); the surplus rows are
        dropped from idx."""
        valid = np.flatnonzero(mask).astype(np.int32)
        count = np.int32(valid.size)
        idx = np.zeros((capacity,), dtype=np.int32)
        k = min(capacity, valid.size)
        idx[:k] = valid[:k]
        return idx, count

    @staticmethod
    def barrier(x):
        return x

    @staticmethod
    def searchsorted(a, v):
        return np.searchsorted(a, v)

    # -- mesh collectives: identity on the single-sample collection walk,
    # -- so sharded staging decisions see a plain one-shard world
    @staticmethod
    def psum(x, axis):
        return x

    @staticmethod
    def pmax(x, axis):
        return x

    @staticmethod
    def pmin(x, axis):
        return x

    @staticmethod
    def all_gather(x, axis, tiled=False):
        return x if tiled else np.asarray(x)[None]

    @staticmethod
    def axis_index(axis):
        return np.int32(0)


class JaxBackend:
    name = "jax"

    def __init__(self):
        import jax
        import jax.numpy as jnp

        self.xp = jnp
        self._jax = jax

    def take(self, arr, idx):
        # jnp gather clamps out-of-bounds indices by default
        return arr[idx]

    def segment_sum(self, data, ids, n):
        import jax

        return jax.ops.segment_sum(data, ids, num_segments=n)

    def segment_max(self, data, ids, n, fill):
        import jax
        import jax.numpy as jnp

        out = jax.ops.segment_max(data, ids, num_segments=n)
        # segment_max fills empty segments with -inf/min; normalize to fill
        neutral = jnp.asarray(fill, dtype=data.dtype)
        lo = -jnp.inf if data.dtype.kind == "f" else jnp.iinfo(data.dtype).min
        return jnp.where(out == lo, neutral, out)

    def segment_min(self, data, ids, n, fill):
        import jax
        import jax.numpy as jnp

        out = jax.ops.segment_min(data, ids, num_segments=n)
        neutral = jnp.asarray(fill, dtype=data.dtype)
        hi = jnp.inf if data.dtype.kind == "f" else jnp.iinfo(data.dtype).max
        return jnp.where(out == hi, neutral, out)

    def lexsort(self, keys):
        import jax.numpy as jnp

        return jnp.lexsort(tuple(keys))

    def compact(self, mask, capacity):
        """Cumsum + binary-search compaction (vmap-safe, static shapes).

        `cumsum(mask)` is non-decreasing, so the row id of the j-th valid
        row is the first position where the running count reaches j+1 — a
        vectorized `searchsorted` over the `capacity` output slots.  This
        is a pure gather formulation: XLA's CPU scatter executes updates
        serially (~100x slower than the rest of the pipeline combined),
        while cumsum + batched binary search stay vectorized.  Slots past
        the valid count search past the end and clamp to n-1; the caller's
        pad mask (`arange(capacity) < count`) hides them, and
        `count > capacity` is the overflow flag.
        """
        import jax.numpy as jnp

        c = jnp.cumsum(mask.astype(jnp.int32))
        count = c[-1]
        idx = jnp.searchsorted(
            c, jnp.arange(1, capacity + 1, dtype=jnp.int32))
        n = mask.shape[0]
        return jnp.clip(idx, 0, n - 1).astype(jnp.int32), count

    def barrier(self, x):
        import jax

        return jax.lax.optimization_barrier(x)

    def searchsorted(self, a, v):
        import jax.numpy as jnp

        return jnp.searchsorted(a, v)

    # -- mesh collectives (only traced inside shard_map: `axis` must be a
    # -- bound mesh axis name, which compile.py guarantees by setting
    # -- StageCtx.axis iff the staged fn is shard_map-wrapped)
    def psum(self, x, axis):
        import jax

        return jax.lax.psum(x, axis)

    def pmax(self, x, axis):
        import jax

        return jax.lax.pmax(x, axis)

    def pmin(self, x, axis):
        import jax

        return jax.lax.pmin(x, axis)

    def all_gather(self, x, axis, tiled=False):
        import jax

        return jax.lax.all_gather(x, axis, tiled=tiled)

    def axis_index(self, axis):
        import jax

        return jax.lax.axis_index(axis)
