"""Whole-query staging driver: lowered plan -> one specialized JAX program.

This is the LegoBase code generator, reorganized into explicit layers:

  * the *physical operators* live in `repro.core.operators` — one module
    per operator, each a pure `stage(node, ctx) -> Frame` function over the
    shared `StageCtx`;
  * this module is the driver: it runs the operator dispatch twice — once
    eagerly on numpy with 8-row samples (the collection walk, which
    registers the exact input set: per-query specialized loading, §3.6.1)
    and once under `jax.jit` (the traced walk producing the fused XLA
    program) — and wraps the result in a `CompiledQuery`;
  * the *runtime layer* (`repro.core.plan_cache`, `repro.serve`) reuses
    CompiledQuery across executions.

Query-specific literals (date-slice bounds, dictionary codes, key domains,
strides, pruned column sets) are baked in at staging time exactly as the
paper's generated C bakes them in.  `Param` nodes are the exception: a
numeric parameter becomes a *scalar input* of the staged program
(`param/<name>`), so `run(params=...)` re-executes the already-jitted XLA
callable under new bindings without re-staging or re-compiling — the
compile-once / bind-many amortization of Dashti et al.

Beyond bind-many: `run_many(bindings_list)` executes N bindings of the
same plan as ONE XLA dispatch.  The staged body is wrapped in `jax.vmap`
with `in_axes=None` for base columns / index structures (table data is
traced once and shared across the batch) and `in_axes=0` for the
`param/<name>` scalars, which become leading-axis vectors of shape (B,).
Batch sizes are padded up to power-of-two buckets (`bucket_size`) by
repeating the last binding and slicing the results, so batch-size churn
costs at most log2(max batch) retraces of the vmapped program.

With `Settings.fusion = False` an `optimization_barrier` is placed between
operator regions, reproducing the limited optimization scope of
template-expansion query compilers (paper Fig 2) for the ladder experiment.

Selection-vector compaction (passes/compaction.py) gives the staged program
a third output: the OR of every compaction point's runtime overflow flag.
When it fires, the planner's static capacity buckets dropped rows, so
`run`/`run_many` discard the outputs and re-execute through the lazily
compiled *uncompacted twin* of the same logical plan — compaction is a
performance bet whose worst case is latency, never wrong results.
"""
from __future__ import annotations

import copy
import dataclasses
import threading
import time
from typing import Optional

import numpy as np

from repro.core import ir
from repro.core.backend import JaxBackend, NumpyBackend
from repro.core.expr import Param
from repro.core.operators import StageCtx, frame_nrows
from repro.core.passes.param_binding import plan_params
from repro.core.passes.pipeline import Settings, optimize
from repro.relational.loader import Database

_SAMPLE = 8

# module-level staging counter: incremented once per CompiledQuery
# construction.  The runtime layer's cache tests assert on this to prove
# that re-binding parameters performs no re-staging.  (QueryServer compiles
# on pool threads, so the increment takes a lock.)
STAGINGS = 0
_STAGINGS_LOCK = threading.Lock()


def bucket_size(n: int) -> int:
    """Power-of-two batch bucket: the (B,) param axis is padded up to this
    so the vmapped program retraces at most log2(max batch) times."""
    if n < 1:
        raise ValueError(f"batch must be non-empty (got {n})")
    return 1 << (n - 1).bit_length()


class CompiledQuery:
    """A staged, jitted query.  `params` supplies bindings for every
    runtime (numeric) Param left residual in the optimized plan; they are
    also the values used during the collection walk.  Compile-time params
    (string values, Limit.n) must have been substituted before
    construction — pass `bindings` to `optimize`, or go through
    `PlanCache`."""

    def __init__(self, plan: ir.Plan, db: Database, settings: Settings,
                 params: Optional[dict] = None):
        import jax

        global STAGINGS
        with _STAGINGS_LOCK:
            STAGINGS += 1

        self.db = db
        self.settings = settings
        # compaction plants static-capacity points from cardinality
        # *estimates*; keep a pristine copy of the logical plan so an
        # estimate that undershoots at runtime (the overflow flag) can
        # compile the uncompacted twin lazily.  Hand-planted Compact nodes
        # can overflow even with the pass off, so the copy is gated on
        # either — only plans that provably stay uncompacted skip it.
        pristine = copy.deepcopy(plan) \
            if settings.compaction or any(isinstance(n, ir.Compact)
                                          for n in ir.walk(plan)) else None
        t0 = time.perf_counter()
        self.plan = optimize(plan, db, settings)
        self.pass_time = time.perf_counter() - t0
        self.compaction_points = sum(
            1 for n in ir.walk(self.plan) if isinstance(n, ir.Compact))
        self.capacities = tuple(
            n.capacity for n in ir.walk(self.plan)
            if isinstance(n, ir.Compact))
        self._pristine = pristine if self.compaction_points else None
        self._fallback: Optional["CompiledQuery"] = None
        self._fallback_lock = threading.Lock()
        self.n_overflows = 0      # executions (or batch slots) that fell back

        spec = plan_params(self.plan)
        structural = sorted(n for n, i in spec.items() if i.structural)
        if structural:
            raise TypeError(
                f"compile-time parameters {structural} are unresolved; "
                "bind them via optimize(..., bindings=...) or PlanCache")
        self.param_spec: dict[str, str] = {n: i.dtype for n, i in spec.items()}
        self.param_defaults = {n: (params or {})[n] for n in self.param_spec
                               if n in (params or {})}
        missing = sorted(set(self.param_spec) - set(self.param_defaults))
        if missing:
            raise KeyError(f"no binding supplied for parameters {missing}")

        # 1. collection walk (numpy, 8-row samples): registers inputs and
        #    output schema; every static decision is exercised here.
        t0 = time.perf_counter()
        self.inputs: dict[str, np.ndarray] = {}

        def collect_input(key, make):
            if key not in self.inputs:
                self.inputs[key] = np.asarray(make())
            v = self.inputs[key]
            return v if v.ndim == 0 else v[:_SAMPLE]   # params are scalars

        sampler = StageCtx(db, settings, NumpyBackend(), collect_input,
                           self.param_defaults)
        sample_frame = sampler.stage(self.plan)
        self.out_meta = [(name, b.kind, b.table, b.col)
                         for name, b in sample_frame.cols.items()]
        # a dead-but-declared param would desync the jit input tree:
        # register every declared param unconditionally.
        for name, dtype in self.param_spec.items():
            sampler.param(Param(name, dtype))

        # 2. the staged program.  `body` is the staged walk shared by the
        #    scalar and the batched entry point; the entry points differ
        #    only in how the `param/<name>` inputs are shaped (scalar vs
        #    leading-axis vector split by vmap) and in which trace counter
        #    they bump.
        self.n_traces = 0         # scalar program traces (must stay 1)
        self.n_batch_traces = 0   # vmapped traces: one per new bucket size
        self.n_executions = 0     # XLA dispatches via run()/run_many()

        def body(inputs, batched=False):
            ctx = StageCtx(db, settings, JaxBackend(),
                           lambda key, make: inputs[key],
                           self.param_defaults, batched=batched)
            frame = ctx.stage(self.plan)
            out = {name: b.arr for name, b in frame.cols.items()}
            n = frame_nrows(frame)
            mask = frame.mask if frame.mask is not None \
                else ctx.xp.ones((n,), dtype=bool)
            # third program output: OR of every compaction point's
            # overflow flag (constant False when the plan has none)
            oflow = ctx.xp.zeros((), dtype=bool)
            for f in ctx.overflow:
                oflow = oflow | f
            return out, mask, oflow

        def fn(inputs):
            self.n_traces += 1   # host side effect: runs only while tracing
            return body(inputs)

        def fn_many(inputs):
            # inputs: base columns as in `fn`, `param/<name>` of shape (B,).
            # vmap splits the param axis, so `body` stages the identical
            # scalar program per slot while base columns are closed over
            # (broadcast, in_axes=None): table data enters the XLA program
            # once, shared across the whole batch.
            self.n_batch_traces += 1
            base = {k: v for k, v in inputs.items()
                    if not k.startswith("param/")}
            pvec = {k: v for k, v in inputs.items()
                    if k.startswith("param/")}
            return jax.vmap(
                lambda p: body({**base, **p}, batched=True))(pvec)

        self.fn = fn
        self._jitted = jax.jit(fn)
        self._jitted_many = jax.jit(fn_many)
        self.stage_time = time.perf_counter() - t0
        self._compile_time: Optional[float] = None

    # -- explicit compile (for the Fig-22 experiment) -------------------------
    def compile(self):
        import jax

        t0 = time.perf_counter()
        lowered = jax.jit(self.fn).lower(self.inputs)
        self.lower_time = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        self._compile_time = time.perf_counter() - t0
        self.lowered = lowered
        self.compiled = compiled
        return compiled

    # -- parameter re-binding --------------------------------------------------
    def bind(self, params: Optional[dict] = None) -> dict[str, np.ndarray]:
        """Input dict for one execution: base columns + index structures
        (shared across bindings) and the per-execution parameter scalars.

        `params=None` executes under the construction-time bindings; a
        non-None dict must name *every* runtime parameter — a partial dict
        would silently mix bindings from two requests."""
        merged = self._check_bindings(params)
        if not self.param_spec:
            return self.inputs
        inputs = dict(self.inputs)
        for name, dtype in self.param_spec.items():
            inputs[f"param/{name}"] = np.asarray(merged[name], dtype=dtype)
        return inputs

    def _check_bindings(self, params: Optional[dict]) -> dict:
        if params is None:
            return self.param_defaults
        unknown = sorted(set(params) - set(self.param_spec))
        if unknown:
            raise KeyError(f"unknown parameters {unknown}; this plan "
                           f"takes {sorted(self.param_spec)}")
        missing = sorted(set(self.param_spec) - set(params))
        if missing:
            raise KeyError(f"no binding supplied for parameters "
                           f"{missing}")
        return params

    def bind_many(self, bindings_list) -> dict[str, np.ndarray]:
        """Input dict for one *batched* execution: base columns unchanged,
        `param/<name>` stacked to a (bucket,) leading-axis vector — the
        batch padded to `bucket_size(B)` by repeating the last binding
        (callers slice the results back to B rows).  A None entry stands
        for the construction-time bindings, like `run(params=None)`."""
        merged = [self._check_bindings(b) for b in bindings_list]
        pad = bucket_size(len(merged)) - len(merged)
        merged = merged + [merged[-1]] * pad
        inputs = dict(self.inputs)
        for name, dtype in self.param_spec.items():
            inputs[f"param/{name}"] = np.stack(
                [np.asarray(b[name], dtype=dtype) for b in merged])
        return inputs

    def _fallback_query(self) -> "CompiledQuery":
        """The uncompacted twin: same logical plan, compaction off.
        Compiled lazily on the first overflow, at most once."""
        from repro.core.passes.compaction import strip_compaction

        with self._fallback_lock:
            if self._fallback is None:
                # hand-planted Compact nodes survive pass-disabling: strip
                # them too, or the twin would overflow all over again
                self._fallback = CompiledQuery(
                    strip_compaction(self._pristine), self.db,
                    dataclasses.replace(self.settings, compaction=False),
                    params=self.param_defaults)
                self._pristine = None   # handed over (passes mutated it)
            return self._fallback

    def run(self, params: Optional[dict] = None) -> dict[str, np.ndarray]:
        import jax

        self.n_executions += 1
        out, mask, oflow = self._jitted(self.bind(params))
        if self.compaction_points and bool(np.asarray(oflow)):
            # a capacity bucket overflowed: the compacted frames dropped
            # rows, so the outputs are unusable — re-execute uncompacted
            self.n_overflows += 1
            return self._fallback_query().run(params)
        out = jax.tree.map(np.asarray, out)
        mask = np.asarray(mask)
        return self._decode(out, mask)

    def run_many(self, bindings_list) -> list[dict[str, np.ndarray]]:
        """Execute N bindings as ONE XLA dispatch (the vmapped program).

        Returns one decoded result dict per binding, positionally matching
        `bindings_list`; each is identical to `run(bindings_list[i])`.
        A plan with no runtime params degenerates to a single scalar
        execution whose result is replicated."""
        bindings_list = list(bindings_list)
        if not bindings_list:
            return []
        if not self.param_spec:
            for b in bindings_list:
                self._check_bindings(b)
            res = self.run()
            # independent array copies per slot, matching N run() calls
            # (callers may mutate their result in place)
            return [{k: np.copy(v) for k, v in res.items()}
                    for _ in bindings_list]
        import jax

        self.n_executions += 1
        out, mask, oflow = self._jitted_many(self.bind_many(bindings_list))
        out = jax.tree.map(np.asarray, out)
        mask = np.asarray(mask)
        oflow = np.asarray(oflow)
        results = [self._decode({k: v[i] for k, v in out.items()}, mask[i])
                   if not (self.compaction_points and oflow[i]) else None
                   for i in range(len(bindings_list))]
        bad = [i for i, r in enumerate(results) if r is None]
        if bad:
            # per-slot overflow: only the overflowing bindings re-execute
            # through the uncompacted twin (itself one vmapped dispatch)
            self.n_overflows += len(bad)
            redo = self._fallback_query().run_many(
                [bindings_list[i] for i in bad])
            for i, r in zip(bad, redo):
                results[i] = r
        return results

    def input_nbytes(self) -> int:
        return int(sum(v.nbytes for v in self.inputs.values()))

    def _decode(self, out: dict[str, np.ndarray], mask: np.ndarray
                ) -> dict[str, np.ndarray]:
        return _decode_frame(out, mask, self.out_meta)


def _decode_frame(out, mask, out_meta) -> dict[str, np.ndarray]:
        res = {}
        for name, kind, table, colname in out_meta:
            v = out[name][mask]
            if kind == "codes":
                res[name] = table.vocabs[colname][np.clip(v, 0, None)].astype(str)
            elif kind == "chars":
                w = v.shape[1]
                b = np.ascontiguousarray(v).view(f"S{w}")[:, 0]
                res[name] = np.char.decode(
                    np.char.rstrip(b, b"\x00"), "ascii").astype(str)
            elif kind == "words":
                vocab = table.word_vocabs[colname]
                res[name] = np.array(
                    [" ".join(str(vocab[c]) for c in row if c >= 0)
                     for row in v])
            elif kind == "wordchars":
                w = v.shape[1]
                b = np.ascontiguousarray(v).view(f"S{w}")[:, 0]
                res[name] = np.char.decode(
                    np.char.rstrip(b, b"\x00"), "ascii").astype(str)
            else:
                res[name] = v
        return res


class CompiledQueryBatch:
    """Beyond-paper: cross-QUERY compilation.

    The paper's scope stops at one query; staging a *batch* of plans into a
    single XLA program lets the backend share work across queries — common
    base-column loads, shared dictionary inputs, identical scan+filter
    subplans (Q1/Q6 both stream lineitem) are CSE'd by XLA, and one fused
    executable amortizes dispatch.  `run()` returns per-query results
    identical to individual `CompiledQuery.run()`.
    """

    def __init__(self, plans, db: Database, settings: Settings):
        import jax

        self.queries = [CompiledQuery(p, db, settings) for p in plans]
        self.inputs: dict[str, np.ndarray] = {}
        for q in self.queries:
            self.inputs.update(q.inputs)
        fns = [q.fn for q in self.queries]

        def batch_fn(inputs):
            return tuple(fn(inputs) for fn in fns)

        self.fn = batch_fn
        self._jitted = jax.jit(batch_fn)

    def run(self) -> list[dict[str, np.ndarray]]:
        import jax

        outs = self._jitted(self.inputs)
        results = []
        for q, (out, mask, oflow) in zip(self.queries, outs):
            if q.compaction_points and bool(np.asarray(oflow)):
                # rare: that query's capacity overflowed — go straight to
                # its uncompacted twin (q.run() would re-execute the
                # compacted program only to watch it overflow again)
                q.n_overflows += 1
                results.append(q._fallback_query().run())
                continue
            out = jax.tree.map(np.asarray, out)
            results.append(_decode_frame(out, np.asarray(mask), q.out_meta))
        return results

    def input_nbytes(self) -> int:
        return int(sum(v.nbytes for v in self.inputs.values()))
