"""Whole-query staging: lowered plan -> one specialized JAX program.

This is the LegoBase code generator.  Given a pass-pipeline-optimized plan
(`repro.core.passes`), `CompiledQuery` stages the *entire* query — operators,
data-structure accesses, string operations, auxiliary functions — into a
single JAX function whose only inputs are the referenced base columns and
load-time index structures, then JIT-compiles it with XLA.  All
query-specific information (date-slice bounds, dictionary codes, key
domains, strides, pruned column sets) is baked in at staging time, exactly
as the paper's generated C bakes them into the emitted program.

Staging runs the plan walker twice:
  1. a *collection walk*, eagerly on numpy with 8-row samples, which
     registers the exact input set (per-query specialized loading — the
     §3.6.1 "unused attributes are never loaded") and exercises all static
     decisions;
  2. the *traced walk* inside `jax.jit`, producing the fused XLA program.

With `Settings.fusion = False` an `optimization_barrier` is placed between
operator regions, reproducing the limited optimization scope of
template-expansion query compilers (paper Fig 2) for the ladder experiment.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import numpy as np

from repro.core import ir
from repro.core.backend import JaxBackend, NumpyBackend
from repro.core.expr import Col, EvalEnv, eval_expr
from repro.core.passes.pipeline import Settings, optimize
from repro.relational.loader import Database
from repro.relational.schema import ColKind

_SAMPLE = 8
_I32MAX = np.int32(2**31 - 1)
_F32BIG = np.float32(3.0e38)


@dataclasses.dataclass
class Binding:
    arr: Any
    kind: str                     # num | codes | chars | words | wordchars
    table: Optional[object] = None  # source Table (for vocab decode)
    col: Optional[str] = None


@dataclasses.dataclass
class Frame:
    cols: dict[str, Binding]
    mask: Any = None              # bool array or None (all valid)
    pending: list = dataclasses.field(default_factory=list)

    def copy(self) -> "Frame":
        return Frame(dict(self.cols), self.mask, list(self.pending))


class FrameEnv(EvalEnv):
    def __init__(self, frame: Frame, backend, cse: bool):
        super().__init__(backend.xp, cse)
        self.frame = frame

    def _b(self, name: str) -> Binding:
        return self.frame.cols[name]

    def get_num(self, name):
        b = self._b(name)
        assert b.kind in ("num", "codes"), f"{name} is {b.kind}, not numeric"
        return b.arr

    def get_codes(self, name):
        b = self._b(name)
        assert b.kind == "codes", f"{name} has no dictionary codes ({b.kind})"
        return b.arr

    def get_chars(self, name):
        b = self._b(name)
        assert b.kind == "chars", f"{name} has no char matrix ({b.kind})"
        return b.arr

    def get_words(self, name):
        b = self._b(name)
        assert b.kind == "words", f"{name} has no word codes ({b.kind})"
        return b.arr

    def get_word_chars(self, name):
        b = self._b(name)
        assert b.kind == "wordchars", f"{name} has no text chars ({b.kind})"
        return b.arr


def _ones_mask(xp, n):
    return xp.ones((n,), dtype=bool)


def _and(xp, m1, m2):
    if m1 is None:
        return m2
    if m2 is None:
        return m1
    return m1 & m2


def _frame_nrows(f: Frame) -> int:
    b = next(iter(f.cols.values()))
    return b.arr.shape[0]


class Stager:
    def __init__(self, db: Database, settings: Settings, backend, input_fn):
        self.db = db
        self.s = settings
        self.be = backend
        self.input = input_fn

    # ------------------------------------------------------------------ scan
    def _scan(self, scan: ir.Scan) -> Frame:
        db, be, s = self.db, self.be, self.s
        t = db.table(scan.table)
        cols = scan.columns if scan.columns is not None else t.schema.column_names
        perm = None
        if scan.date_slice is not None:
            ds = scan.date_slice
            _, start, end = db.date_slice(scan.table, ds.col, ds.lo, ds.hi)
            pfull = self.input(f"{scan.table}/dateperm/{ds.col}",
                               lambda: db.date_cluster(scan.table, ds.col)[0])
            perm = pfull[min(start, pfull.shape[0]):min(end, pfull.shape[0])]

        rowmat = None
        rowcols: list[str] = []
        if s.layout == "row":
            rowcols = [c for c in cols
                       if t.schema.col(c).kind in (ColKind.INT, ColKind.FLOAT,
                                                   ColKind.DATE)]
            if rowcols:
                key = f"{scan.table}/rowmat/" + ",".join(rowcols)
                rowmat = self.input(
                    key, lambda: np.stack(
                        [t.data[c].astype(np.float32) for c in rowcols], axis=1))
                # The barrier forces the full AoS record to be read before any
                # column is extracted (paper §3.3: rows can't skip attributes).
                rowmat = be.barrier(rowmat)
                if perm is not None:
                    rowmat = be.barrier(be.take(rowmat, perm))

        bindings: dict[str, Binding] = {}
        for c in cols:
            cdef = t.schema.col(c)
            if cdef.kind in (ColKind.INT, ColKind.FLOAT, ColKind.DATE):
                if rowmat is not None:
                    j = rowcols.index(c)
                    arr = rowmat[:, j]
                    if cdef.kind != ColKind.FLOAT:
                        arr = arr.astype(np.int32)
                else:
                    arr = self.input(f"{scan.table}/col/{c}", lambda c=c: t.data[c])
                    if perm is not None:
                        arr = be.take(arr, perm)
                bindings[c] = Binding(arr, "num", t, c)
            elif cdef.kind == ColKind.CAT:
                if self.s.string_dict:
                    arr = self.input(f"{scan.table}/col/{c}", lambda c=c: t.data[c])
                    kind = "codes"
                else:
                    arr = self.input(f"{scan.table}/chars/{c}",
                                     lambda c=c: t.char_matrix(c))
                    kind = "chars"
                if perm is not None:
                    arr = be.take(arr, perm)
                bindings[c] = Binding(arr, kind, t, c)
            else:  # TEXT
                if self.s.string_dict:
                    arr = self.input(f"{scan.table}/col/{c}", lambda c=c: t.data[c])
                    kind = "words"
                else:
                    arr = self.input(f"{scan.table}/chars/{c}",
                                     lambda c=c: t.char_matrix(c))
                    kind = "wordchars"
                if perm is not None:
                    arr = be.take(arr, perm)
                bindings[c] = Binding(arr, kind, t, c)
        return Frame(bindings)

    # ---------------------------------------------------------------- select
    def _select(self, sel: ir.Select, defer: bool) -> Frame:
        f = self.stage(sel.child, defer)
        if defer:
            f.pending.append(sel.pred)
            return f
        env = FrameEnv(f, self.be, self.s.cse)
        m = eval_expr(sel.pred, env)
        f.mask = _and(self.be.xp, f.mask, m)
        return f

    # --------------------------------------------------------------- project
    def _project(self, proj: ir.Project, defer: bool) -> Frame:
        f = self.stage(proj.child, defer)
        env = FrameEnv(f, self.be, self.s.cse)
        new = dict(f.cols) if proj.keep_input else {}
        for name, e in proj.outputs.items():
            if isinstance(e, Col) and e.name in f.cols:
                new[name] = f.cols[e.name]
            else:
                new[name] = Binding(eval_expr(e, env), "num")
        out = Frame(new, f.mask, f.pending)
        return out

    # ------------------------------------------------------------------ join
    def _join(self, j: ir.Join) -> Frame:
        be, xp = self.be, self.be.xp
        stream = self.stage(j.stream)
        if j.strategy == "pk_gather":
            build = self.stage(j.build, defer=not self.s.hoist)
            idx = stream.cols[j.stream_key].arr
            bmask_g = None
            if build.mask is not None:
                bmask_g = be.take(build.mask, idx)
            cols = dict(stream.cols)
            for name, b in build.cols.items():
                if name in cols:
                    continue
                g = be.take(b.arr, idx)
                if j.kind == "left" and bmask_g is not None and g.ndim == 1:
                    g = xp.where(bmask_g, g, 0)  # missing match -> default 0
                cols[name] = Binding(g, b.kind, b.table, b.col)
            mask = stream.mask
            if j.kind != "left" and bmask_g is not None:
                mask = _and(xp, mask, bmask_g)
            out = Frame(cols, mask)
            if build.pending:
                env = FrameEnv(out, be, self.s.cse)
                for pred in build.pending:
                    out.mask = _and(xp, out.mask, eval_expr(pred, env))
            return self._barrier(out)

        if j.strategy == "bucket_gather":
            # composite-PK join via the load-time 2-D partitioned array
            # (§3.2.1): bucket on key1, discriminate on key2 within the
            # statically-bounded bucket width.
            build = self.stage(j.build, defer=not self.s.hoist)
            w = j.bucket_width
            mat = self.input(
                f"{j.build_table}/fkbucket/{j.build_key}",
                lambda: self.db.fk_bucket(j.build_table, j.build_key)[0])
            rows = be.take(mat, stream.cols[j.stream_key].arr)   # (n, W)
            bkey2 = build.cols[j.build_key2].arr
            skey2 = stream.cols[j.stream_key2].arr
            bmask = build.mask
            idx = None
            hit = None
            for slot in range(w):
                r = rows[:, slot]
                ok = r >= 0
                cand = be.take(bkey2, xp.clip(r, 0, None))
                m = ok & (cand == skey2)
                if bmask is not None:
                    m = m & be.take(bmask, xp.clip(r, 0, None))
                idx = xp.where(m, r, 0) if idx is None else xp.where(m, r, idx)
                hit = m if hit is None else (hit | m)
            cols = dict(stream.cols)
            for name, b in build.cols.items():
                if name in cols:
                    continue
                cols[name] = Binding(be.take(b.arr, idx), b.kind, b.table,
                                     b.col)
            out = Frame(cols, _and(xp, stream.mask, hit))
            if build.pending:
                env = FrameEnv(out, be, self.s.cse)
                for pred in build.pending:
                    out.mask = _and(xp, out.mask, eval_expr(pred, env))
            return self._barrier(out)

        if j.strategy == "exists_flag":
            build = self.stage(j.build)
            n_b = _frame_nrows(build)
            bkey = build.cols[j.build_key].arr
            bm = build.mask if build.mask is not None else _ones_mask(xp, n_b)
            flags = be.segment_max(bm.astype(np.int32), bkey, j.domain, 0) > 0
            hit = be.take(flags, stream.cols[j.stream_key].arr)
            if j.kind == "anti":
                hit = ~hit
            stream.mask = _and(xp, stream.mask, hit)
            return self._barrier(stream)

        # generic sort-based equi join (build keys unique: PK or group keys)
        build = self.stage(j.build)
        n_b = _frame_nrows(build)
        if j.stream_key2 is not None:
            # composite key: pack into uint32 (k1·K2 + k2; bound documented)
            k2b = self._key2_bound(j, stream, build)
            bkey = (build.cols[j.build_key].arr.astype(np.uint32) * k2b
                    + build.cols[j.build_key2].arr.astype(np.uint32))
            skey_stream = (stream.cols[j.stream_key].arr.astype(np.uint32)
                           * k2b
                           + stream.cols[j.stream_key2].arr.astype(np.uint32))
            sentinel = np.uint32(2**32 - 1)
        else:
            bkey = build.cols[j.build_key].arr.astype(np.int32)
            skey_stream = stream.cols[j.stream_key].arr
            sentinel = _I32MAX
        bm = build.mask if build.mask is not None else _ones_mask(xp, n_b)
        keys = xp.where(bm, bkey, sentinel)
        order = xp.argsort(keys)
        skeys = be.take(keys, order)
        pos = be.searchsorted(skeys, skey_stream)
        pos = xp.clip(pos, 0, max(n_b - 1, 0))
        hit = be.take(skeys, pos) == skey_stream
        if j.kind == "semi":
            stream.mask = _and(xp, stream.mask, hit)
            return self._barrier(stream)
        if j.kind == "anti":
            stream.mask = _and(xp, stream.mask, ~hit)
            return self._barrier(stream)
        bidx = be.take(order, pos)
        cols = dict(stream.cols)
        for name, b in build.cols.items():
            if name in cols:
                continue
            g = be.take(b.arr, bidx)
            if j.kind == "left" and g.ndim == 1:
                g = xp.where(hit, g, 0)
            cols[name] = Binding(g, b.kind, b.table, b.col)
        mask = stream.mask if j.kind == "left" else _and(xp, stream.mask, hit)
        return self._barrier(Frame(cols, mask))

    def _key2_bound(self, j: ir.Join, stream: Frame, build: Frame) -> np.uint32:
        """Static bound for the second key (from base-table stats)."""
        for frame in (build, stream):
            key = j.build_key2 if frame is build else j.stream_key2
            b = frame.cols[key]
            if b.table is not None and b.col in b.table.stats:
                return np.uint32(int(b.table.stats[b.col].max) + 1)
        return np.uint32(1 << 20)

    # ------------------------------------------------------------------- agg
    def _agg(self, a: ir.Agg) -> Frame:
        be, xp = self.be, self.be.xp
        f = self.stage(a.child)
        n = _frame_nrows(f)
        env = FrameEnv(f, be, self.s.cse)
        mask = f.mask if f.mask is not None else _ones_mask(xp, n)
        mi32 = mask.astype(np.int32)
        vals = {}
        for spec in a.aggs:
            if spec.expr is not None:
                vals[spec.name] = eval_expr(spec.expr, env)

        def _finalize(spec, sums, counts, mins, maxs):
            if spec.fn == "sum":
                return sums[spec.name]
            if spec.fn == "count":
                return counts[spec.name]
            if spec.fn == "avg":
                c = counts[spec.name]
                return sums[spec.name] / xp.maximum(c, 1).astype(np.float32)
            if spec.fn == "min":
                return mins[spec.name]
            if spec.fn == "max":
                return maxs[spec.name]
            raise ValueError(spec.fn)

        def _kernel_ok(D):
            return (self.s.use_pallas and self.be.name == "jax" and D <= 4096
                    and all(s_.fn in ("sum", "count", "avg") for s_ in a.aggs)
                    and all(v.ndim == 1 for v in vals.values()))

        if a.strategy == "scalar" or not a.group_by:
            # (the 'scalar' annotation additionally enables kernel fusion;
            # functionally an empty group-by is always a single group)
            if _kernel_ok(1):
                from repro.kernels import ops as kops

                names = [s_.name for s_ in a.aggs if s_.expr is not None]
                sums_m, cnt = kops.filter_agg_query(
                    mask, xp.zeros((n,), dtype=np.int32),
                    [vals[nm].astype(np.float32) for nm in names], 1)
                cols = {}
                for spec in a.aggs:
                    if spec.fn == "sum":
                        v = sums_m[0:1, names.index(spec.name)]
                    elif spec.fn == "count":
                        v = cnt[0:1].astype(np.int32)
                    else:  # avg
                        v = (sums_m[0:1, names.index(spec.name)]
                             / xp.maximum(cnt[0:1], 1.0))
                    cols[spec.name] = Binding(v, "num")
                return self._barrier(Frame(cols, None))
            cols = {}
            for spec in a.aggs:
                if spec.fn == "count":
                    v = mi32.sum()[None]
                elif spec.fn == "sum":
                    v = xp.where(mask, vals[spec.name], 0).sum()[None]
                elif spec.fn == "avg":
                    sv = xp.where(mask, vals[spec.name], 0).sum()
                    cv = mi32.sum()
                    v = (sv / xp.maximum(cv, 1).astype(np.float32))[None]
                elif spec.fn == "min":
                    v = xp.where(mask, vals[spec.name], _F32BIG).min()[None]
                elif spec.fn == "max":
                    v = xp.where(mask, vals[spec.name], -_F32BIG).max()[None]
                cols[spec.name] = Binding(v, "num")
            return self._barrier(Frame(cols, None))

        if a.strategy == "dense":
            D = 1
            for d in a.domains:
                D *= d
            # mixed-radix composite index (strides baked at staging time)
            idx = None
            strides = []
            st = 1
            for d in reversed(a.domains):
                strides.append(st)
                st *= d
            strides = list(reversed(strides))
            for g, d, stg in zip(a.group_by, a.domains, strides):
                part = f.cols[g].arr.astype(np.int32) * np.int32(stg)
                idx = part if idx is None else idx + part
            idx = xp.clip(idx, 0, D - 1)
            kernel_sums = kernel_counts = None
            if _kernel_ok(D):
                from repro.kernels import ops as kops

                names = [s_.name for s_ in a.aggs if s_.expr is not None]
                sums_m, cnt = kops.filter_agg_query(
                    mask, idx, [vals[nm].astype(np.float32) for nm in names], D)
                kernel_sums = {nm: sums_m[:, i] for i, nm in enumerate(names)}
                kernel_counts = cnt
                present = (cnt > 0).astype(np.int32)
            else:
                present = be.segment_max(mi32, idx, D, 0)
            cols: dict[str, Binding] = {}
            ar = xp.arange(D, dtype=np.int32)
            for g, d, stg in zip(a.group_by, a.domains, strides):
                b = f.cols[g]
                keyvals = (ar // np.int32(stg)) % np.int32(d)
                cols[g] = Binding(keyvals, b.kind, b.table, b.col)
            for c in a.carry:
                b = f.cols[c]
                if b.arr.ndim == 2:
                    data = xp.where(mask[:, None], b.arr, 0)
                    cols[c] = Binding(be.segment_max(data, idx, D, 0),
                                      b.kind, b.table, b.col)
                else:
                    if b.arr.dtype.kind == "f":
                        data = xp.where(mask, b.arr, -_F32BIG)
                        fill = np.float32(0)
                    else:
                        data = xp.where(mask, b.arr, np.int32(-1)
                                        ).astype(b.arr.dtype)
                        fill = np.array(0, b.arr.dtype)
                    cols[c] = Binding(be.segment_max(data, idx, D, fill),
                                      b.kind, b.table, b.col)
            sums, counts, mins, maxs = {}, {}, {}, {}
            for spec in a.aggs:
                if spec.fn in ("sum", "avg"):
                    sums[spec.name] = (kernel_sums[spec.name]
                                       if kernel_sums is not None else
                                       be.segment_sum(
                                           xp.where(mask, vals[spec.name], 0),
                                           idx, D))
                if spec.fn in ("count", "avg"):
                    counts[spec.name] = (kernel_counts.astype(np.int32)
                                         if kernel_counts is not None else
                                         be.segment_sum(mi32, idx, D))
                if spec.fn == "min":
                    mins[spec.name] = be.segment_min(
                        xp.where(mask, vals[spec.name], _F32BIG), idx, D, _F32BIG)
                if spec.fn == "max":
                    maxs[spec.name] = be.segment_max(
                        xp.where(mask, vals[spec.name], -_F32BIG), idx, D,
                        -_F32BIG)
            for spec in a.aggs:
                cols[spec.name] = Binding(
                    _finalize(spec, sums, counts, mins, maxs), "num")
            return self._barrier(Frame(cols, present > 0))

        # ---- generic sort-based grouping (the un-specialized hash map) ----
        sort_keys: list = []   # major..minor
        for g in a.group_by:
            b = f.cols[g]
            if b.arr.ndim == 2:
                sort_keys.extend([b.arr[:, k] for k in range(b.arr.shape[1])])
            else:
                sort_keys.append(b.arr)
        invalid = ~mask
        order = be.lexsort(list(reversed(sort_keys)) + [invalid])
        smask = be.take(mask, order)
        skeys = [be.take(k, order) for k in sort_keys]
        diff = None
        for k in skeys:
            d = xp.concatenate([xp.ones((1,), dtype=bool), k[1:] != k[:-1]])
            diff = d if diff is None else (diff | d)
        new_group = diff & smask
        flag2 = new_group | ~smask
        gid = xp.cumsum(flag2.astype(np.int32)) - 1
        n_groups = new_group.astype(np.int32).sum()
        ar = xp.arange(n, dtype=np.int32)
        starts = be.segment_min(ar, gid, n, np.int32(0))
        cols = {}
        for g in a.group_by + list(a.carry):
            b = f.cols[g]
            sorted_arr = be.take(b.arr, order)
            cols[g] = Binding(be.take(sorted_arr, starts), b.kind, b.table, b.col)
        sums, counts, mins, maxs = {}, {}, {}, {}
        smi32 = smask.astype(np.int32)
        for spec in a.aggs:
            sv = be.take(vals[spec.name], order) if spec.expr is not None else None
            if spec.fn in ("sum", "avg"):
                sums[spec.name] = be.segment_sum(xp.where(smask, sv, 0), gid, n)
            if spec.fn in ("count", "avg"):
                counts[spec.name] = be.segment_sum(smi32, gid, n)
            if spec.fn == "min":
                mins[spec.name] = be.segment_min(
                    xp.where(smask, sv, _F32BIG), gid, n, _F32BIG)
            if spec.fn == "max":
                maxs[spec.name] = be.segment_max(
                    xp.where(smask, sv, -_F32BIG), gid, n, -_F32BIG)
        for spec in a.aggs:
            cols[spec.name] = Binding(
                _finalize(spec, sums, counts, mins, maxs), "num")
        return self._barrier(Frame(cols, ar < n_groups))

    # ------------------------------------------------------------------ sort
    def _sort(self, srt: ir.Sort) -> Frame:
        f = self.stage(srt.child)
        return self._sort_frame(f, srt.keys)

    def _sort_frame(self, f: Frame, sort_keys) -> Frame:
        be, xp = self.be, self.be.xp
        n = _frame_nrows(f)
        mask = f.mask if f.mask is not None else _ones_mask(xp, n)
        keys = []  # major..minor
        for name, asc in sort_keys:
            b = f.cols[name]
            if b.arr.ndim == 2:
                for k in range(b.arr.shape[1]):
                    kk = b.arr[:, k]
                    keys.append(kk if asc else (np.uint8(255) - kk))
            else:
                arr = b.arr
                keys.append(arr if asc else -arr)
        order = be.lexsort(list(reversed(keys)) + [~mask])
        cols = {name: Binding(be.take(b.arr, order), b.kind, b.table, b.col)
                for name, b in f.cols.items()}
        return Frame(cols, be.take(mask, order))

    # ----------------------------------------------------------------- limit
    def _limit(self, lim: ir.Limit) -> Frame:
        # Beyond-paper: ORDER BY <numeric> LIMIT k lowers to top-k selection
        # on the primary sort key + an exact k-row sort (the global sort over
        # the padded aggregation domain is wasted work when only k rows
        # survive) — the masked_topk Pallas kernel is the TPU form of this.
        if (self.s.topk_limit and isinstance(lim.child, ir.Sort)
                and lim.child.keys):
            srt = lim.child
            f = self.stage(srt.child)
            name0, asc0 = srt.keys[0]
            b0 = f.cols[name0]
            if b0.arr.ndim == 1:
                xp, be = self.be.xp, self.be
                n_rows = _frame_nrows(f)
                k = min(lim.n, n_rows)
                key = b0.arr.astype(np.float32)
                key = key if not asc0 else -key
                if f.mask is not None:
                    key = xp.where(f.mask, key, -_F32BIG)
                if self.be.name == "jax":
                    import jax

                    _, idx = jax.lax.top_k(key, k)
                else:
                    idx = np.argsort(-key, kind="stable")[:k]
                cols = {nm: Binding(be.take(b.arr, idx), b.kind, b.table,
                                    b.col) for nm, b in f.cols.items()}
                mask = None if f.mask is None else be.take(f.mask, idx)
                sub = Frame(cols, mask)
                return self._sort_frame(sub, srt.keys)
        f = self.stage(lim.child)
        n = min(lim.n, _frame_nrows(f))
        cols = {name: Binding(b.arr[:n], b.kind, b.table, b.col)
                for name, b in f.cols.items()}
        mask = None if f.mask is None else f.mask[:n]
        return Frame(cols, mask)

    # ------------------------------------------------------------------ misc
    def _barrier(self, f: Frame) -> Frame:
        """fusion=False: cut the XLA fusion scope at operator boundaries."""
        if self.s.fusion or self.be.name == "numpy":
            return f
        arrs = {n: b.arr for n, b in f.cols.items()}
        wrapped = self.be.barrier(arrs)
        cols = {n: Binding(wrapped[n], b.kind, b.table, b.col)
                for n, b in f.cols.items()}
        mask = None if f.mask is None else self.be.barrier(f.mask)
        return Frame(cols, mask, f.pending)

    def stage(self, p: ir.Plan, defer: bool = False) -> Frame:
        if isinstance(p, ir.Scan):
            return self._scan(p)
        if isinstance(p, ir.Select):
            return self._select(p, defer)
        if isinstance(p, ir.Project):
            return self._project(p, defer)
        if isinstance(p, ir.Join):
            return self._join(p)
        if isinstance(p, ir.Agg):
            return self._agg(p)
        if isinstance(p, ir.Sort):
            return self._sort(p)
        if isinstance(p, ir.Limit):
            return self._limit(p)
        raise TypeError(type(p))


# ---------------------------------------------------------------------------
# CompiledQuery: passes -> collection walk -> jit -> run
# ---------------------------------------------------------------------------

class CompiledQuery:
    def __init__(self, plan: ir.Plan, db: Database, settings: Settings):
        import jax

        self.db = db
        self.settings = settings
        t0 = time.perf_counter()
        self.plan = optimize(plan, db, settings)
        self.pass_time = time.perf_counter() - t0

        # 1. collection walk (numpy, 8-row samples): registers inputs and
        #    output schema; every static decision is exercised here.
        t0 = time.perf_counter()
        self.inputs: dict[str, np.ndarray] = {}

        def collect_input(key, make):
            if key not in self.inputs:
                self.inputs[key] = np.asarray(make())
            v = self.inputs[key]
            return v[:_SAMPLE]

        nb = NumpyBackend()
        sampler = Stager(db, settings, nb, collect_input)
        sample_frame = sampler.stage(self.plan)
        self.out_meta = [(name, b.kind, b.table, b.col)
                         for name, b in sample_frame.cols.items()]

        # 2. the staged program.
        def fn(inputs):
            jb = JaxBackend()
            st = Stager(db, settings, jb, lambda key, make: inputs[key])
            frame = st.stage(self.plan)
            out = {name: b.arr for name, b in frame.cols.items()}
            n = _frame_nrows(frame)
            mask = frame.mask if frame.mask is not None \
                else jb.xp.ones((n,), dtype=bool)
            return out, mask

        self.fn = fn
        self._jitted = jax.jit(fn)
        self.stage_time = time.perf_counter() - t0
        self._compile_time: Optional[float] = None

    # -- explicit compile (for the Fig-22 experiment) -------------------------
    def compile(self):
        import jax

        t0 = time.perf_counter()
        lowered = jax.jit(self.fn).lower(self.inputs)
        self.lower_time = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        self._compile_time = time.perf_counter() - t0
        self.lowered = lowered
        self.compiled = compiled
        return compiled

    def run(self) -> dict[str, np.ndarray]:
        import jax

        out, mask = self._jitted(self.inputs)
        out = jax.tree.map(np.asarray, out)
        mask = np.asarray(mask)
        return self._decode(out, mask)

    def input_nbytes(self) -> int:
        return int(sum(v.nbytes for v in self.inputs.values()))

    def _decode(self, out: dict[str, np.ndarray], mask: np.ndarray
                ) -> dict[str, np.ndarray]:
        return _decode_frame(out, mask, self.out_meta)


def _decode_frame(out, mask, out_meta) -> dict[str, np.ndarray]:
        res = {}
        for name, kind, table, colname in out_meta:
            v = out[name][mask]
            if kind == "codes":
                res[name] = table.vocabs[colname][np.clip(v, 0, None)].astype(str)
            elif kind == "chars":
                w = v.shape[1]
                b = np.ascontiguousarray(v).view(f"S{w}")[:, 0]
                res[name] = np.char.decode(
                    np.char.rstrip(b, b"\x00"), "ascii").astype(str)
            elif kind == "words":
                vocab = table.word_vocabs[colname]
                res[name] = np.array(
                    [" ".join(str(vocab[c]) for c in row if c >= 0)
                     for row in v])
            elif kind == "wordchars":
                w = v.shape[1]
                b = np.ascontiguousarray(v).view(f"S{w}")[:, 0]
                res[name] = np.char.decode(
                    np.char.rstrip(b, b"\x00"), "ascii").astype(str)
            else:
                res[name] = v
        return res


class CompiledQueryBatch:
    """Beyond-paper: cross-QUERY compilation.

    The paper's scope stops at one query; staging a *batch* of plans into a
    single XLA program lets the backend share work across queries — common
    base-column loads, shared dictionary inputs, identical scan+filter
    subplans (Q1/Q6 both stream lineitem) are CSE'd by XLA, and one fused
    executable amortizes dispatch.  `run()` returns per-query results
    identical to individual `CompiledQuery.run()`.
    """

    def __init__(self, plans, db: Database, settings: Settings):
        import jax

        self.queries = [CompiledQuery(p, db, settings) for p in plans]
        self.inputs: dict[str, np.ndarray] = {}
        for q in self.queries:
            self.inputs.update(q.inputs)
        fns = [q.fn for q in self.queries]

        def batch_fn(inputs):
            return tuple(fn(inputs) for fn in fns)

        self.fn = batch_fn
        self._jitted = jax.jit(batch_fn)

    def run(self) -> list[dict[str, np.ndarray]]:
        import jax

        outs = self._jitted(self.inputs)
        results = []
        for q, (out, mask) in zip(self.queries, outs):
            out = jax.tree.map(np.asarray, out)
            results.append(_decode_frame(out, np.asarray(mask), q.out_meta))
        return results

    def input_nbytes(self) -> int:
        return int(sum(v.nbytes for v in self.inputs.values()))
