"""Whole-query staging driver: lowered plan -> one specialized JAX program.

This is the LegoBase code generator, reorganized into explicit layers:

  * the *physical operators* live in `repro.core.operators` — one module
    per operator, each a pure `stage(node, ctx) -> Frame` function over the
    shared `StageCtx`;
  * this module is the driver: it runs the operator dispatch twice — once
    eagerly on numpy with 8-row samples (the collection walk, which
    registers the exact input set: per-query specialized loading, §3.6.1)
    and once under `jax.jit` (the traced walk producing the fused XLA
    program) — and wraps the result in a `CompiledQuery`;
  * the *runtime layer* (`repro.core.plan_cache`, `repro.serve`) reuses
    CompiledQuery across executions.

Query-specific literals (date-slice bounds, dictionary codes, key domains,
strides, pruned column sets) are baked in at staging time exactly as the
paper's generated C bakes them in.  `Param` nodes are the exception: a
numeric parameter becomes a *scalar input* of the staged program
(`param/<name>`), so `run(params=...)` re-executes the already-jitted XLA
callable under new bindings without re-staging or re-compiling — the
compile-once / bind-many amortization of Dashti et al.

Beyond bind-many: `run_many(bindings_list)` executes N bindings of the
same plan as ONE XLA dispatch.  The staged body is wrapped in `jax.vmap`
with `in_axes=None` for base columns / index structures (table data is
traced once and shared across the batch) and `in_axes=0` for the
`param/<name>` scalars, which become leading-axis vectors of shape (B,).
Batch sizes are padded up to power-of-two buckets (`bucket_size`) by
repeating the last binding and slicing the results, so batch-size churn
costs at most log2(max batch) retraces of the vmapped program.

With `Settings.fusion = False` an `optimization_barrier` is placed between
operator regions, reproducing the limited optimization scope of
template-expansion query compilers (paper Fig 2) for the ladder experiment.

Selection-vector compaction (passes/compaction.py) gives the staged program
a third output: a dict mapping each compaction point's id to its TRUE
valid count at runtime.  A count above the point's planned capacity means
the static buckets dropped rows, so `run`/`run_many` discard the outputs
and re-execute through the lazily compiled *uncompacted twin* of the same
logical plan — compaction is a performance bet whose worst case is
latency, never wrong results.  The counts themselves are accumulated per
entry (`observed_max`, underuse streaks) and harvested by `PlanCache`'s
feedback store, which re-plans capacities from measured headroom after
repeated overflows and shrinks them after sustained underuse.
"""
from __future__ import annotations

import copy
import dataclasses
import threading
import time
from typing import Optional

import numpy as np

from repro.core import ir
from repro.core.backend import JaxBackend, NumpyBackend
from repro.core.expr import Param
from repro.core.mesh import AXIS, data_mesh, resolve_shards, shard_map_fn
from repro.core.operators import StageCtx, frame_nrows
from repro.core.passes.param_binding import plan_params
from repro.core.passes.pipeline import Settings, optimize
from repro.relational.loader import Database

_SAMPLE = 8

# module-level staging counter: incremented once per CompiledQuery
# construction.  The runtime layer's cache tests assert on this to prove
# that re-binding parameters performs no re-staging.  (QueryServer compiles
# on pool threads, so the increment takes a lock.)
STAGINGS = 0
_STAGINGS_LOCK = threading.Lock()


def bucket_size(n: int) -> int:
    """Power-of-two batch bucket: the (B,) param axis is padded up to this
    so the vmapped program retraces at most log2(max batch) times."""
    if n < 1:
        raise ValueError(f"batch must be non-empty (got {n})")
    return 1 << (n - 1).bit_length()


class CompiledQuery:
    """A staged, jitted query.  `params` supplies bindings for every
    runtime (numeric) Param left residual in the optimized plan; they are
    also the values used during the collection walk.  Compile-time params
    (string values, Limit.n) must have been substituted before
    construction — pass `bindings` to `optimize`, or go through
    `PlanCache`."""

    # tiering.Runnable surface: batched execution pads to pow2 buckets
    # (PlanCache.run_many charges the pad slots), and the tier name
    # defaults from the settings — the tiered cache overwrites it when it
    # builds this program as a specific ladder rung (e.g. 'interpret').
    pads_batches = True

    def __init__(self, plan: ir.Plan, db: Database, settings: Settings,
                 params: Optional[dict] = None,
                 est_params: Optional[dict] = None,
                 observed: Optional[dict] = None):
        import jax

        global STAGINGS
        with _STAGINGS_LOCK:
            STAGINGS += 1

        self.db = db
        self.settings = settings
        self.tier_name = "opt-pallas" if settings.use_pallas else "compiled"
        # compaction plants static-capacity points from cardinality
        # *estimates*; keep a pristine copy of the logical plan so an
        # estimate that undershoots at runtime (the overflow flag) can
        # compile the uncompacted twin lazily.  Hand-planted Compact nodes
        # can overflow even with the pass off, so the copy is gated on
        # either — only plans that provably stay uncompacted skip it.
        # A measure-only twin plants nothing that can overflow, so it
        # never needs a fallback of its own: skip the deepcopy.
        pristine = copy.deepcopy(plan) \
            if (settings.compaction and not settings.compact_measure_only) \
            or any(isinstance(n, ir.Compact) and n.capacity > 0
                   for n in ir.walk(plan)) else None
        t0 = time.perf_counter()
        # estimation inputs for the Compaction pass: initial-binding values
        # default to the construction-time params; `observed` carries the
        # feedback store's measured counts on a re-plan.  PlanCache passes
        # both explicitly so an entry's capacities always match the
        # memoized capacity signature in its cache key.
        self.plan = optimize(plan, db, settings,
                             est_params=est_params if est_params is not None
                             else (params or {}),
                             observed=observed)
        self.pass_time = time.perf_counter() - t0
        # one walk over the optimized plan: hand-planted Compact nodes get
        # stable `h<i>` ids (no pass-assigned candidate id), then the
        # points split into real compaction points (capacity > 0) and
        # measure-only probes (capacity 0 — the overflow twin's
        # observation points, which count but never truncate and can
        # never overflow)
        h, compacts = 0, []
        for n in ir.walk(self.plan):
            if isinstance(n, ir.Compact):
                if n.point_id is None:
                    n.point_id = f"h{h}"
                    h += 1
                compacts.append(n)
        real = [n for n in compacts if n.capacity > 0]
        self.compaction_points = len(real)
        self.capacities = tuple(n.capacity for n in real)
        self.point_caps = {n.point_id: int(n.capacity) for n in real}
        # translate points carry the key→slot contract whose overflow
        # drops whole-query results: PlanCache's shrink decay exempts them
        # so their capacities floor at the all-time measured max
        self.translate_points = {n.point_id for n in real if n.translate}
        self.measure_points = len(compacts) - len(real)
        self._pristine = pristine if self.compaction_points else None
        self._fallback: Optional["CompiledQuery"] = None
        self._fallback_lock = threading.Lock()
        self.n_overflows = 0      # executions (or batch slots) that fell back
        # adaptive-feedback observation state (harvested by PlanCache):
        # all-time max true count per point, and the current run of
        # consecutive all-points-underused executions with its window max
        self._obs_lock = threading.Lock()
        self.observed_max: dict[str, int] = {}
        # per-shard all-time max vectors (shape (n_shards,)) — the sharded
        # program reports every point's count per shard, and the skew
        # between slots is what the bench/feedback surfaces read
        self.observed_shard: dict[str, np.ndarray] = {}
        self.under_streak = 0     # consecutive executions, every point <cap/4
        self.streak_max: dict[str, int] = {}   # max counts within the streak
        self._cache_key: Optional[tuple] = None   # set by PlanCache

        # sharded execution: the Sharding pass resolved the same settings,
        # so the mesh shape here matches the per-shard capacities it
        # planted.  The staged fn is shard_map-wrapped below; partitioned
        # inputs are device_put with a NamedSharding after the collection
        # walk so jit consumes them without host-side resharding.
        self.n_shards = resolve_shards(settings)
        self._mesh = data_mesh(self.n_shards) if self.n_shards > 1 else None

        spec = plan_params(self.plan)
        structural = sorted(n for n, i in spec.items() if i.structural)
        if structural:
            raise TypeError(
                f"compile-time parameters {structural} are unresolved; "
                "bind them via optimize(..., bindings=...) or PlanCache")
        self.param_spec: dict[str, str] = {n: i.dtype for n, i in spec.items()}
        self.param_defaults = {n: (params or {})[n] for n in self.param_spec
                               if n in (params or {})}
        missing = sorted(set(self.param_spec) - set(self.param_defaults))
        if missing:
            raise KeyError(f"no binding supplied for parameters {missing}")

        # 1. collection walk (numpy, 8-row samples): registers inputs and
        #    output schema; every static decision is exercised here.
        t0 = time.perf_counter()
        self.inputs: dict[str, np.ndarray] = {}

        def collect_input(key, make):
            if key not in self.inputs:
                self.inputs[key] = np.asarray(make())
            v = self.inputs[key]
            return v if v.ndim == 0 else v[:_SAMPLE]   # params are scalars

        sp = db.shard_plan(self.n_shards) if self.n_shards > 1 else None
        axis = AXIS if self._mesh is not None else None
        sampler = StageCtx(db, settings, NumpyBackend(), collect_input,
                           self.param_defaults, axis=axis,
                           n_shards=self.n_shards, shard_plan=sp)
        sample_frame = sampler.stage(self.plan)
        self.out_meta = [(name, b.kind, b.table, b.col)
                         for name, b in sample_frame.cols.items()]
        # input keys whose arrays are partitioned over the data axis
        # (registered by sharded Scans during the collection walk)
        self.sharded_keys = frozenset(sampler.sharded_keys)
        # a dead-but-declared param would desync the jit input tree:
        # register every declared param unconditionally.
        for name, dtype in self.param_spec.items():
            sampler.param(Param(name, dtype))
        if self._mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec

            ns = NamedSharding(self._mesh, PartitionSpec(AXIS))
            for k in self.sharded_keys:
                self.inputs[k] = jax.device_put(self.inputs[k], ns)

        # 2. the staged program.  `body` is the staged walk shared by the
        #    scalar and the batched entry point; the entry points differ
        #    only in how the `param/<name>` inputs are shaped (scalar vs
        #    leading-axis vector split by vmap) and in which trace counter
        #    they bump.
        self.n_traces = 0         # scalar program traces (must stay 1)
        self.n_batch_traces = 0   # vmapped traces: one per new bucket size
        self.n_executions = 0     # XLA dispatches via run()/run_many()

        def body(inputs, batched=False):
            ctx = StageCtx(db, settings, JaxBackend(),
                           lambda key, make: inputs[key],
                           self.param_defaults, batched=batched,
                           axis=axis, n_shards=self.n_shards, shard_plan=sp)
            frame = ctx.stage(self.plan)
            out = {name: b.arr for name, b in frame.cols.items()}
            n = frame_nrows(frame)
            mask = frame.mask if frame.mask is not None \
                else ctx.xp.ones((n,), dtype=bool)
            # third program output: every compaction point's TRUE valid
            # count, keyed by point id (empty dict when the plan has
            # none).  count > capacity is the overflow signal; the counts
            # feed the plan cache's capacity feedback either way.  Under
            # the mesh each count is a shard-local scalar — all-gather to
            # a replicated (n_shards,) vector so the host sees per-shard
            # demand (overflow = max over slots).
            counts = dict(ctx.compact_counts)
            if self._mesh is not None:
                be = ctx.backend
                counts = {pid: be.all_gather(c, AXIS)
                          for pid, c in counts.items()}
            return out, mask, counts

        def fn(inputs):
            self.n_traces += 1   # host side effect: runs only while tracing
            return body(inputs)

        def fn_many(inputs):
            # inputs: base columns as in `fn`, `param/<name>` of shape (B,).
            # vmap splits the param axis, so `body` stages the identical
            # scalar program per slot while base columns are closed over
            # (broadcast, in_axes=None): table data enters the XLA program
            # once, shared across the whole batch.
            self.n_batch_traces += 1
            base = {k: v for k, v in inputs.items()
                    if not k.startswith("param/")}
            pvec = {k: v for k, v in inputs.items()
                    if k.startswith("param/")}
            return jax.vmap(
                lambda p: body({**base, **p}, batched=True))(pvec)

        def shard_wrap(inner):
            # the staged walk runs per shard under shard_map: partitioned
            # inputs split along the data axis, everything else (params
            # included) replicated.  Every output is replicated — the plan
            # ends in combined aggregates or above a gather Exchange, and
            # the counts are all-gathered in `body` — so out_specs is P().
            # The in_specs dict is built per call because `bind` adds
            # param/<name> keys the collection-time input set lacks.
            from jax.sharding import PartitionSpec

            def call(inputs):
                specs = {k: (PartitionSpec(AXIS) if k in self.sharded_keys
                             else PartitionSpec())
                         for k in inputs}
                return shard_map_fn(inner, self._mesh, in_specs=(specs,),
                                    out_specs=PartitionSpec())(inputs)
            return call

        self.fn = fn if self._mesh is None else shard_wrap(fn)
        self._jitted = jax.jit(self.fn)
        self._jitted_many = jax.jit(
            fn_many if self._mesh is None else shard_wrap(fn_many))
        self.stage_time = time.perf_counter() - t0
        self._compile_time: Optional[float] = None

    # -- explicit compile (for the Fig-22 experiment) -------------------------
    def compile(self):
        import jax

        t0 = time.perf_counter()
        lowered = jax.jit(self.fn).lower(self.inputs)
        self.lower_time = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        self._compile_time = time.perf_counter() - t0
        self.lowered = lowered
        self.compiled = compiled
        return compiled

    # -- parameter re-binding --------------------------------------------------
    def bind(self, params: Optional[dict] = None) -> dict[str, np.ndarray]:
        """Input dict for one execution: base columns + index structures
        (shared across bindings) and the per-execution parameter scalars.

        `params=None` executes under the construction-time bindings; a
        non-None dict must name *every* runtime parameter — a partial dict
        would silently mix bindings from two requests."""
        merged = self._check_bindings(params)
        if not self.param_spec:
            return self.inputs
        inputs = dict(self.inputs)
        for name, dtype in self.param_spec.items():
            inputs[f"param/{name}"] = np.asarray(merged[name], dtype=dtype)
        return inputs

    def _check_bindings(self, params: Optional[dict]) -> dict:
        if params is None:
            return self.param_defaults
        unknown = sorted(set(params) - set(self.param_spec))
        if unknown:
            raise KeyError(f"unknown parameters {unknown}; this plan "
                           f"takes {sorted(self.param_spec)}")
        missing = sorted(set(self.param_spec) - set(params))
        if missing:
            raise KeyError(f"no binding supplied for parameters "
                           f"{missing}")
        return params

    def bind_many(self, bindings_list) -> dict[str, np.ndarray]:
        """Input dict for one *batched* execution: base columns unchanged,
        `param/<name>` stacked to a (bucket,) leading-axis vector — the
        batch padded to `bucket_size(B)` by repeating the last binding
        (callers slice the results back to B rows).  A None entry stands
        for the construction-time bindings, like `run(params=None)`."""
        merged = [self._check_bindings(b) for b in bindings_list]
        pad = bucket_size(len(merged)) - len(merged)
        merged = merged + [merged[-1]] * pad
        inputs = dict(self.inputs)
        for name, dtype in self.param_spec.items():
            inputs[f"param/{name}"] = np.stack(
                [np.asarray(b[name], dtype=dtype) for b in merged])
        return inputs

    def _fallback_query(self) -> "CompiledQuery":
        """The uncompacted twin: same logical plan, no truncating points.
        Compiled lazily on the first overflow, at most once.  With the
        pass enabled it runs in *measure-only* mode: every candidate site
        gets a capacity-0 probe reporting its TRUE valid count, so one
        fallback execution hands the feedback store the exact demand at
        every site (counts from the compacted program are truncated below
        an overflowed point, and re-planning from truncated counts would
        converge one layer per k overflows instead of in one step)."""
        from repro.core.passes.compaction import strip_compaction

        with self._fallback_lock:
            if self._fallback is None:
                # hand-planted Compact nodes survive pass-disabling: strip
                # them too, or the twin would overflow all over again
                self._fallback = CompiledQuery(
                    strip_compaction(self._pristine), self.db,
                    dataclasses.replace(self.settings,
                                        compact_measure_only=True),
                    params=self.param_defaults)
                self._pristine = None   # handed over (passes mutated it)
            return self._fallback

    def _merge_twin_observations(self, twin: "CompiledQuery") -> None:
        """Fold the twin's measured true counts into this entry's
        observation state, where PlanCache's feedback step harvests
        them.  Max-merge: idempotent across repeated fallbacks."""
        with twin._obs_lock:
            obs = dict(twin.observed_max)
        with self._obs_lock:
            for pid, c in obs.items():
                if c > self.observed_max.get(pid, -1):
                    self.observed_max[pid] = c

    def _observe_shards(self, vecs: dict[str, np.ndarray]) -> None:
        """Elementwise-max merge of per-shard count vectors (shape
        (n_shards,)) into the all-time per-shard state."""
        with self._obs_lock:
            for pid, v in vecs.items():
                old = self.observed_shard.get(pid)
                self.observed_shard[pid] = \
                    v.copy() if old is None else np.maximum(old, v)

    def _observe(self, slot_counts: list[dict]) -> None:
        """Feedback accounting for a list of per-execution (or per-real-
        batch-slot) true-count dicts: all-time max per point, plus the
        consecutive-underuse streak and its window max (the shrink
        signal decays — a historical spike must not pin capacity up)."""
        with self._obs_lock:
            for counts in slot_counts:
                oflow = False
                under = any(pid in self.point_caps for pid in counts)
                for pid, c in counts.items():
                    if c > self.observed_max.get(pid, -1):
                        self.observed_max[pid] = c
                    cap = self.point_caps.get(pid)
                    if cap is None:     # measure-only probe: count only
                        continue
                    if c > cap:
                        oflow = True
                    if 4 * c >= cap:
                        under = False
                if oflow or not under:
                    self.under_streak = 0
                    self.streak_max = {}
                else:
                    self.under_streak += 1
                    for pid, c in counts.items():
                        if c > self.streak_max.get(pid, -1):
                            self.streak_max[pid] = c

    def run(self, params: Optional[dict] = None) -> dict[str, np.ndarray]:
        import jax

        self.n_executions += 1
        out, mask, counts = self._jitted(self.bind(params))
        if self.compaction_points or self.measure_points:
            # sharded programs report an (n_shards,) vector per point;
            # overflow and the scalar feedback both key off the worst shard
            vecs = {pid: np.atleast_1d(np.asarray(c)).reshape(-1)
                    for pid, c in counts.items()}
            counts = {pid: int(v.max()) for pid, v in vecs.items()}
            self._observe([counts])
            if self.n_shards > 1:
                self._observe_shards(vecs)
            if any(c > self.point_caps[pid] for pid, c in counts.items()
                   if pid in self.point_caps):
                # a capacity bucket overflowed: the compacted frames
                # dropped rows, so the outputs are unusable — re-execute
                # uncompacted; the twin's measure probes report every
                # site's TRUE count, folded back for the feedback store
                self.n_overflows += 1
                twin = self._fallback_query()
                res = twin.run(params)
                self._merge_twin_observations(twin)
                return res
        out = jax.tree.map(np.asarray, out)
        mask = np.asarray(mask)
        return self._decode(out, mask)

    def run_many(self, bindings_list) -> list[dict[str, np.ndarray]]:
        """Execute N bindings as ONE XLA dispatch (the vmapped program).

        Returns one decoded result dict per binding, positionally matching
        `bindings_list`; each is identical to `run(bindings_list[i])`.
        A plan with no runtime params degenerates to a single scalar
        execution whose result is replicated."""
        bindings_list = list(bindings_list)
        if not bindings_list:
            return []
        if not self.param_spec:
            for b in bindings_list:
                self._check_bindings(b)
            res = self.run()
            # independent array copies per slot, matching N run() calls
            # (callers may mutate their result in place)
            return [{k: np.copy(v) for k, v in res.items()}
                    for _ in bindings_list]
        import jax

        self.n_executions += 1
        out, mask, counts = self._jitted_many(self.bind_many(bindings_list))
        out = jax.tree.map(np.asarray, out)
        mask = np.asarray(mask)
        n_real = len(bindings_list)
        bad: list[int] = []
        if self.compaction_points or self.measure_points:
            # the bucket's pad slots (indices >= n_real, repeats of the
            # last binding) are masked out of overflow accounting, the
            # feedback observations, and the fallback re-runs: rows
            # nobody asked for must not trigger re-planning or wasted
            # uncompacted-twin executions
            # per-point shapes: (B,) unsharded, (B, n_shards) sharded —
            # np.max over a slot's entry covers both
            counts = {pid: np.asarray(c) for pid, c in counts.items()}
            slot_counts = [{pid: int(np.max(v[i]))
                            for pid, v in counts.items()}
                           for i in range(n_real)]
            self._observe(slot_counts)
            if self.n_shards > 1 and counts:
                self._observe_shards(
                    {pid: np.atleast_1d(np.max(v[:n_real], axis=0))
                     for pid, v in counts.items()})
            bad = [i for i, sc in enumerate(slot_counts)
                   if any(c > self.point_caps[pid] for pid, c in sc.items()
                          if pid in self.point_caps)]
        bad_set = set(bad)
        results = [None if i in bad_set
                   else self._decode({k: v[i] for k, v in out.items()},
                                     mask[i])
                   for i in range(n_real)]
        if bad:
            # per-slot overflow: only the overflowing bindings re-execute
            # through the uncompacted twin (itself one vmapped dispatch)
            self.n_overflows += len(bad)
            twin = self._fallback_query()
            redo = twin.run_many([bindings_list[i] for i in bad])
            self._merge_twin_observations(twin)
            for i, r in zip(bad, redo):
                results[i] = r
        return results

    def input_nbytes(self) -> int:
        return int(sum(v.nbytes for v in self.inputs.values()))

    def _decode(self, out: dict[str, np.ndarray], mask: np.ndarray
                ) -> dict[str, np.ndarray]:
        return _decode_frame(out, mask, self.out_meta)


def _decode_frame(out, mask, out_meta) -> dict[str, np.ndarray]:
        res = {}
        for name, kind, table, colname in out_meta:
            v = out[name][mask]
            if kind == "codes":
                res[name] = table.vocabs[colname][np.clip(v, 0, None)].astype(str)
            elif kind == "chars":
                w = v.shape[1]
                b = np.ascontiguousarray(v).view(f"S{w}")[:, 0]
                res[name] = np.char.decode(
                    np.char.rstrip(b, b"\x00"), "ascii").astype(str)
            elif kind == "words":
                vocab = table.word_vocabs[colname]
                res[name] = np.array(
                    [" ".join(str(vocab[c]) for c in row if c >= 0)
                     for row in v])
            elif kind == "wordchars":
                w = v.shape[1]
                b = np.ascontiguousarray(v).view(f"S{w}")[:, 0]
                res[name] = np.char.decode(
                    np.char.rstrip(b, b"\x00"), "ascii").astype(str)
            else:
                res[name] = v
        return res


class CompiledQueryBatch:
    """Beyond-paper: cross-QUERY compilation.

    The paper's scope stops at one query; staging a *batch* of plans into a
    single XLA program lets the backend share work across queries — common
    base-column loads, shared dictionary inputs, identical scan+filter
    subplans (Q1/Q6 both stream lineitem) are CSE'd by XLA, and one fused
    executable amortizes dispatch.  `run()` returns per-query results
    identical to individual `CompiledQuery.run()`.
    """

    def __init__(self, plans, db: Database, settings: Settings):
        import jax

        if resolve_shards(settings) != 1:
            # each member would need its own shard_map scope and its own
            # partitioned input aliases; cross-query CSE across shard_map
            # boundaries buys nothing, so the combination is rejected
            # rather than half-supported
            raise NotImplementedError(
                "CompiledQueryBatch does not compose with sharded "
                "execution (Settings.shards != 1)")
        self.queries = [CompiledQuery(p, db, settings) for p in plans]
        self.inputs: dict[str, np.ndarray] = {}
        for q in self.queries:
            self.inputs.update(q.inputs)
        fns = [q.fn for q in self.queries]

        def batch_fn(inputs):
            return tuple(fn(inputs) for fn in fns)

        self.fn = batch_fn
        self._jitted = jax.jit(batch_fn)

    def run(self) -> list[dict[str, np.ndarray]]:
        import jax

        outs = self._jitted(self.inputs)
        results = []
        for q, (out, mask, counts) in zip(self.queries, outs):
            if q.compaction_points or q.measure_points:
                counts = {pid: int(np.asarray(c))
                          for pid, c in counts.items()}
                q._observe([counts])
                if any(c > q.point_caps[pid] for pid, c in counts.items()
                       if pid in q.point_caps):
                    # rare: that query's capacity overflowed — go straight
                    # to its uncompacted twin (q.run() would re-execute
                    # the compacted program only to watch it overflow
                    # again)
                    q.n_overflows += 1
                    twin = q._fallback_query()
                    results.append(twin.run())
                    q._merge_twin_observations(twin)
                    continue
            out = jax.tree.map(np.asarray, out)
            results.append(_decode_frame(out, np.asarray(mask), q.out_meta))
        return results

    def input_nbytes(self) -> int:
        return int(sum(v.nbytes for v in self.inputs.values()))
