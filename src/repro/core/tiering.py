"""First-class execution tiers: one ladder from interpreter to kernels.

Before this module, "how should this plan execute" was answered by three
uncoordinated mechanisms — `Settings.engine` ('volcano'/'compiled'), the
mask-only `pipeline.degrade` rung the server used for load shedding, and
the `opt-pallas` kernel rung — each with its own call-site convention.
The ladder makes the choice a first-class, ordered value:

    oracle (0)     — the interpreted Volcano engine (`volcano.OracleQuery`).
                     Zero compile cost: ready the moment the plan exists.
    interpret (1)  — the staged program under `pipeline.degrade` settings:
                     mask-only frames, no compaction machinery, no pass
                     verifier.  Same results, cheapest compile.
    compiled (2)   — the full staged + jitted program (`CompiledQuery`)
                     under the caller's settings, Pallas off.
    opt-pallas (3) — the same with the Pallas mega-kernel rung enabled.

Every tier satisfies the same `Runnable` contract (`run`, `run_many`, the
staged-outputs observation surface), so any tier is substitutable at the
call site.  Two subsystems walk the SAME ladder in opposite directions:

  * `PlanCache` *climbs* it — a cold request is served by the best ready
    tier (the oracle, instantly) while a bounded background thread
    compiles the target tier and hot-swaps the entry (docs §11);
  * `QueryServer` *descends* it — admission overload demotes new windows
    to a lower tier's settings instead of maintaining a private
    mask-only path (docs §10's ladder, re-expressed).

Tiers are value objects; `TierLadder` binds them to a concrete target
`Settings` and answers "what settings realize tier t for this target".
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Protocol, runtime_checkable

import numpy as np

from repro.core.passes.pipeline import Settings, degrade


@dataclasses.dataclass(frozen=True, order=True)
class ExecutionTier:
    """One rung: totally ordered by rank (higher = more compiled)."""
    rank: int
    name: str

    def __repr__(self) -> str:
        return f"ExecutionTier({self.name!r}, rank={self.rank})"


ORACLE = ExecutionTier(0, "oracle")
INTERPRET = ExecutionTier(1, "interpret")
COMPILED = ExecutionTier(2, "compiled")
OPT_PALLAS = ExecutionTier(3, "opt-pallas")

TIERS = (ORACLE, INTERPRET, COMPILED, OPT_PALLAS)
_BY_NAME = {t.name: t for t in TIERS}


def tier(name: str) -> ExecutionTier:
    try:
        return _BY_NAME[name]
    except KeyError:
        raise KeyError(f"unknown execution tier {name!r}; "
                       f"ladder is {[t.name for t in TIERS]}") from None


@runtime_checkable
class Runnable(Protocol):
    """What every tier's executable exposes (the CompiledQuery contract).

    `run(params)` executes one binding; `run_many(bindings_list)` executes
    N bindings positionally.  Binding validation is identical across
    tiers: a dict must name exactly the plan's runtime parameters, and
    None means the construction-time defaults.  The observation surface
    (`compaction_points`, `n_overflows`, `observed_max`, ...) exists on
    every tier so `PlanCache`'s accounting and feedback harvesting never
    special-case the tier they run against — tiers without compaction
    machinery report zero points and are skipped naturally."""

    tier_name: str
    param_spec: dict
    compaction_points: int
    n_overflows: int

    def run(self, params: Optional[dict] = None) -> dict[str, np.ndarray]:
        ...

    def run_many(self, bindings_list) -> list[dict[str, np.ndarray]]:
        ...


class TierLadder:
    """The ladder bound to a concrete target `Settings`.

    The target tier is read off the settings: `opt-pallas` when
    `use_pallas`, else `compiled` (a 'volcano' engine setting degenerates
    the ladder to the oracle alone).  `settings_for(t)` answers what
    settings realize tier `t` while preserving every semantic choice of
    the target — the interpret tier is exactly `pipeline.degrade(target)`
    (the server's historical mask-only rung), so results are
    bit-identical at every rung and only the latency machinery differs.
    """

    def __init__(self, settings: Settings):
        self.base = settings
        if settings.engine != "compiled":
            self.target = ORACLE
        elif settings.use_pallas:
            self.target = OPT_PALLAS
        else:
            self.target = COMPILED

    def tiers(self) -> list[ExecutionTier]:
        """Rungs of this ladder, bottom (cheapest to ready) to target."""
        return [t for t in TIERS if t.rank <= self.target.rank]

    def settings_for(self, t: ExecutionTier) -> Settings:
        if t.rank > self.target.rank:
            raise ValueError(f"{t.name} is above this ladder's target "
                             f"({self.target.name})")
        if t is ORACLE:
            return dataclasses.replace(self.base, engine="volcano")
        if t is INTERPRET:
            return degrade(self.base)
        if t is COMPILED and self.target is OPT_PALLAS:
            return dataclasses.replace(self.base, use_pallas=False)
        return self.base

    def demote(self, t: ExecutionTier, n: int = 1) -> ExecutionTier:
        """`n` rungs below `t`, clamped to the ladder's bottom."""
        return TIERS[max(t.rank - n, 0)]

    def promotion_path(self, ready: ExecutionTier,
                       through: bool = False) -> list[ExecutionTier]:
        """Tiers a background promoter should build, in order, starting
        above `ready`.  Default: straight to the target (one compile);
        `through=True` climbs rung by rung (an interpret-tier program
        becomes servable before the full compile lands — cheaper partial
        promotion at the cost of one extra compile)."""
        if through:
            return [t for t in self.tiers()
                    if ready.rank < t.rank <= self.target.rank
                    and t is not ORACLE]
        return [self.target] if ready.rank < self.target.rank else []
