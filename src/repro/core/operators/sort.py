"""Sort: masked lexsort over the frame (invalid rows sort last)."""
from __future__ import annotations

import numpy as np

from repro.core import ir
from repro.core.operators.base import (Binding, Frame, StageCtx, frame_nrows,
                                       ones_mask)


def stage(srt: ir.Sort, ctx: StageCtx, defer: bool = False) -> Frame:
    f = ctx.stage(srt.child)
    return sort_frame(f, srt.keys, ctx)


def sort_frame(f: Frame, sort_keys, ctx: StageCtx) -> Frame:
    be, xp = ctx.backend, ctx.xp
    n = frame_nrows(f)
    mask = f.mask if f.mask is not None else ones_mask(xp, n)
    keys = []  # major..minor
    for name, asc in sort_keys:
        b = f.cols[name]
        if b.arr.ndim == 2:
            for k in range(b.arr.shape[1]):
                kk = b.arr[:, k]
                keys.append(kk if asc else (np.uint8(255) - kk))
        else:
            arr = b.arr
            keys.append(arr if asc else -arr)
    order = be.lexsort(list(reversed(keys)) + [~mask])
    cols = {name: Binding(be.take(b.arr, order), b.kind, b.table, b.col)
            for name, b in f.cols.items()}
    return Frame(cols, be.take(mask, order), part=f.part)
