"""The physical-operator layer: one module per operator, each a pure
function `stage(node, ctx, defer=False) -> Frame` over the shared
`StageCtx`.  `repro.core.compile` is the driver that runs this dispatch
twice (numpy collection walk, traced JAX walk) and wraps the result in a
`CompiledQuery`."""
from __future__ import annotations

from repro.core import ir
from repro.core.operators import (agg, compact, exchange, join, limit,
                                  project, scan, select, sort)
from repro.core.operators.base import (Binding, Frame, FrameEnv, StageCtx,
                                       frame_nrows)

_DISPATCH = {
    ir.Scan: scan.stage,
    ir.Select: select.stage,
    ir.Project: project.stage,
    ir.Join: join.stage,
    ir.Agg: agg.stage,
    ir.Compact: compact.stage,
    ir.Exchange: exchange.stage,
    ir.Sort: sort.stage,
    ir.Limit: limit.stage,
}


def stage(node: ir.Plan, ctx: StageCtx, defer: bool = False) -> Frame:
    fn = _DISPATCH.get(type(node))
    if fn is None:
        raise TypeError(type(node))
    return fn(node, ctx, defer)


__all__ = ["Binding", "Frame", "FrameEnv", "StageCtx", "frame_nrows",
           "stage"]
