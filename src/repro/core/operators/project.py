"""Project: computed outputs / renames over the child frame."""
from __future__ import annotations

from repro.core import ir
from repro.core.expr import Col, eval_expr
from repro.core.operators.base import Binding, Frame, StageCtx


def stage(proj: ir.Project, ctx: StageCtx, defer: bool = False) -> Frame:
    f = ctx.stage(proj.child, defer)
    env = ctx.env(f)
    new = dict(f.cols) if proj.keep_input else {}
    for name, e in proj.outputs.items():
        if isinstance(e, Col) and e.name in f.cols:
            new[name] = f.cols[e.name]
        else:
            new[name] = Binding(eval_expr(e, env), "num")
    # a Project is elementwise: the compaction pass sinks Compact points
    # below Projects, so capacity/slot_of must survive the projection
    return Frame(new, f.mask, f.pending, f.capacity, f.slot_of, f.part)
