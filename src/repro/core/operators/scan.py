"""Scan: base-table access with per-query specialized loading (§3.6.1).

Registers exactly the columns the optimized plan references as inputs of
the staged program, applies the date-clustered permutation slice when
DateIndex annotated one (§3.2.3), and — under the AoS layout setting —
forces whole-record reads through an optimization barrier (§3.3).
"""
from __future__ import annotations

import numpy as np

from repro.core import ir
from repro.core.operators.base import Binding, Frame, StageCtx
from repro.relational.schema import ColKind


def stage(scan: ir.Scan, ctx: StageCtx, defer: bool = False) -> Frame:
    db, be, s = ctx.db, ctx.backend, ctx.settings
    t = db.table(scan.table)
    cols = scan.columns if scan.columns is not None else t.schema.column_names

    # Sharding-pass annotation: this scan's arrays live partitioned over
    # the data axis.  Partitioned copies are registered under shard-scoped
    # input keys (so the same table can also feed a replicated scan in
    # another plan without key collisions) and recorded in
    # `ctx.sharded_keys` — compile.py turns that set into shard_map
    # in_specs.  The pass never co-annotates a date_slice (the clustered
    # permutation is global) so the two paths don't interact.
    sp = None
    if scan.shard is not None:
        sp = db.shard_plan(scan.shard.n_shards)

    def reg(suffix, thunk):
        if sp is None:
            return ctx.input(f"{scan.table}/{suffix}", thunk)
        key = f"{scan.table}/shard{sp.n}/{suffix}"
        ctx.sharded_keys.add(key)
        return ctx.input(key, lambda: sp.col(scan.table, suffix, thunk))

    perm = None
    if scan.date_slice is not None:
        ds = scan.date_slice
        _, start, end = db.date_slice(scan.table, ds.col, ds.lo, ds.hi)
        pfull = ctx.input(f"{scan.table}/dateperm/{ds.col}",
                          lambda: db.date_cluster(scan.table, ds.col)[0])
        perm = pfull[min(start, pfull.shape[0]):min(end, pfull.shape[0])]

    rowmat = None
    rowcols: list[str] = []
    if s.layout == "row":
        rowcols = [c for c in cols
                   if t.schema.col(c).kind in (ColKind.INT, ColKind.FLOAT,
                                               ColKind.DATE)]
        if rowcols:
            rowmat = reg(
                "rowmat/" + ",".join(rowcols),
                lambda: np.stack(
                    [t.data[c].astype(np.float32) for c in rowcols], axis=1))
            # The barrier forces the full AoS record to be read before any
            # column is extracted (paper §3.3: rows can't skip attributes).
            rowmat = be.barrier(rowmat)
            if perm is not None:
                rowmat = be.barrier(be.take(rowmat, perm))

    bindings: dict[str, Binding] = {}
    for c in cols:
        cdef = t.schema.col(c)
        if cdef.kind in (ColKind.INT, ColKind.FLOAT, ColKind.DATE):
            if rowmat is not None:
                j = rowcols.index(c)
                arr = rowmat[:, j]
                if cdef.kind != ColKind.FLOAT:
                    arr = arr.astype(np.int32)
            else:
                arr = reg(f"col/{c}", lambda c=c: t.data[c])
                if perm is not None:
                    arr = be.take(arr, perm)
            bindings[c] = Binding(arr, "num", t, c)
        elif cdef.kind == ColKind.CAT:
            if s.string_dict:
                arr = reg(f"col/{c}", lambda c=c: t.data[c])
                kind = "codes"
            else:
                arr = reg(f"chars/{c}", lambda c=c: t.char_matrix(c))
                kind = "chars"
            if perm is not None:
                arr = be.take(arr, perm)
            bindings[c] = Binding(arr, kind, t, c)
        else:  # TEXT
            if s.string_dict:
                arr = reg(f"col/{c}", lambda c=c: t.data[c])
                kind = "words"
            else:
                arr = reg(f"chars/{c}", lambda c=c: t.char_matrix(c))
                kind = "wordchars"
            if perm is not None:
                arr = be.take(arr, perm)
            bindings[c] = Binding(arr, kind, t, c)

    if sp is None:
        return Frame(bindings)
    mkey = f"{scan.table}/shard{sp.n}/mask"
    ctx.sharded_keys.add(mkey)
    mask = ctx.input(mkey, lambda: sp.valid_mask(scan.table))
    return Frame(bindings, mask, part=scan.shard.part)
