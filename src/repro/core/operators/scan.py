"""Scan: base-table access with per-query specialized loading (§3.6.1).

Registers exactly the columns the optimized plan references as inputs of
the staged program, applies the date-clustered permutation slice when
DateIndex annotated one (§3.2.3), and — under the AoS layout setting —
forces whole-record reads through an optimization barrier (§3.3).
"""
from __future__ import annotations

import numpy as np

from repro.core import ir
from repro.core.operators.base import Binding, Frame, StageCtx
from repro.relational.schema import ColKind


def stage(scan: ir.Scan, ctx: StageCtx, defer: bool = False) -> Frame:
    db, be, s = ctx.db, ctx.backend, ctx.settings
    t = db.table(scan.table)
    cols = scan.columns if scan.columns is not None else t.schema.column_names
    perm = None
    if scan.date_slice is not None:
        ds = scan.date_slice
        _, start, end = db.date_slice(scan.table, ds.col, ds.lo, ds.hi)
        pfull = ctx.input(f"{scan.table}/dateperm/{ds.col}",
                          lambda: db.date_cluster(scan.table, ds.col)[0])
        perm = pfull[min(start, pfull.shape[0]):min(end, pfull.shape[0])]

    rowmat = None
    rowcols: list[str] = []
    if s.layout == "row":
        rowcols = [c for c in cols
                   if t.schema.col(c).kind in (ColKind.INT, ColKind.FLOAT,
                                               ColKind.DATE)]
        if rowcols:
            key = f"{scan.table}/rowmat/" + ",".join(rowcols)
            rowmat = ctx.input(
                key, lambda: np.stack(
                    [t.data[c].astype(np.float32) for c in rowcols], axis=1))
            # The barrier forces the full AoS record to be read before any
            # column is extracted (paper §3.3: rows can't skip attributes).
            rowmat = be.barrier(rowmat)
            if perm is not None:
                rowmat = be.barrier(be.take(rowmat, perm))

    bindings: dict[str, Binding] = {}
    for c in cols:
        cdef = t.schema.col(c)
        if cdef.kind in (ColKind.INT, ColKind.FLOAT, ColKind.DATE):
            if rowmat is not None:
                j = rowcols.index(c)
                arr = rowmat[:, j]
                if cdef.kind != ColKind.FLOAT:
                    arr = arr.astype(np.int32)
            else:
                arr = ctx.input(f"{scan.table}/col/{c}", lambda c=c: t.data[c])
                if perm is not None:
                    arr = be.take(arr, perm)
            bindings[c] = Binding(arr, "num", t, c)
        elif cdef.kind == ColKind.CAT:
            if s.string_dict:
                arr = ctx.input(f"{scan.table}/col/{c}", lambda c=c: t.data[c])
                kind = "codes"
            else:
                arr = ctx.input(f"{scan.table}/chars/{c}",
                                lambda c=c: t.char_matrix(c))
                kind = "chars"
            if perm is not None:
                arr = be.take(arr, perm)
            bindings[c] = Binding(arr, kind, t, c)
        else:  # TEXT
            if s.string_dict:
                arr = ctx.input(f"{scan.table}/col/{c}", lambda c=c: t.data[c])
                kind = "words"
            else:
                arr = ctx.input(f"{scan.table}/chars/{c}",
                                lambda c=c: t.char_matrix(c))
                kind = "wordchars"
            if perm is not None:
                arr = be.take(arr, perm)
            bindings[c] = Binding(arr, kind, t, c)
    return Frame(bindings)
