"""Scan: base-table access with per-query specialized loading (§3.6.1).

Registers exactly the columns the optimized plan references as inputs of
the staged program, applies the date-clustered permutation slice when
DateIndex annotated one (§3.2.3), and — under the AoS layout setting —
forces whole-record reads through an optimization barrier (§3.3).
"""
from __future__ import annotations

import numpy as np

from repro.core import ir
from repro.core.operators.base import Binding, Frame, StageCtx
from repro.relational.schema import ColKind


def stage(scan: ir.Scan, ctx: StageCtx, defer: bool = False) -> Frame:
    db, be, s = ctx.db, ctx.backend, ctx.settings
    t = db.table(scan.table)
    cols = scan.columns if scan.columns is not None else t.schema.column_names

    # Sharding-pass annotation: this scan's arrays live partitioned over
    # the data axis.  Partitioned copies are registered under shard-scoped
    # input keys (so the same table can also feed a replicated scan in
    # another plan without key collisions) and recorded in
    # `ctx.sharded_keys` — compile.py turns that set into shard_map
    # in_specs.  The pass never co-annotates a date_slice (the clustered
    # permutation is global) so the two paths don't interact.
    sp = None
    if scan.shard is not None:
        sp = db.shard_plan(scan.shard.n_shards)

    def reg(suffix, thunk):
        if sp is None:
            return ctx.input(f"{scan.table}/{suffix}", thunk)
        key = f"{scan.table}/shard{sp.n}/{suffix}"
        ctx.sharded_keys.add(key)
        return ctx.input(key, lambda: sp.col(scan.table, suffix, thunk))

    perm = None
    if scan.date_slice is not None:
        ds = scan.date_slice
        _, start, end = db.date_slice(scan.table, ds.col, ds.lo, ds.hi)
        pfull = ctx.input(f"{scan.table}/dateperm/{ds.col}",
                          lambda: db.date_cluster(scan.table, ds.col)[0])
        perm = pfull[min(start, pfull.shape[0]):min(end, pfull.shape[0])]

    rowmats: dict[str, tuple] = {}   # dtype group -> (record matrix, cols)
    if s.layout == "row":
        # One record matrix PER DTYPE GROUP: stacking INT/DATE columns
        # into a single float32 matrix silently corrupts any integer
        # above 2^24 (float32 carries a 24-bit significand), so keys and
        # wide counters round-trip wrong.  Splitting keeps the AoS
        # discipline — every column in a group is materialized as one
        # record read — without laundering ints through floats.
        groups: dict[str, list[str]] = {"int": [], "float": []}
        for c in cols:
            k = t.schema.col(c).kind
            if k in (ColKind.INT, ColKind.DATE):
                groups["int"].append(c)
            elif k == ColKind.FLOAT:
                groups["float"].append(c)
        for g, gcols in groups.items():
            if not gcols:
                continue
            dt = np.int32 if g == "int" else np.float32
            mat = reg(
                f"rowmat/{g}/" + ",".join(gcols),
                lambda gcols=gcols, dt=dt: np.stack(
                    [t.data[c].astype(dt) for c in gcols], axis=1))
            # The barrier forces the full AoS record to be read before any
            # column is extracted (paper §3.3: rows can't skip attributes).
            mat = be.barrier(mat)
            if perm is not None:
                mat = be.barrier(be.take(mat, perm))
            rowmats[g] = (mat, gcols)

    bindings: dict[str, Binding] = {}
    for c in cols:
        cdef = t.schema.col(c)
        if cdef.kind in (ColKind.INT, ColKind.FLOAT, ColKind.DATE):
            g = "float" if cdef.kind == ColKind.FLOAT else "int"
            if g in rowmats:
                mat, gcols = rowmats[g]
                arr = mat[:, gcols.index(c)]
            else:
                arr = reg(f"col/{c}", lambda c=c: t.data[c])
                if perm is not None:
                    arr = be.take(arr, perm)
            bindings[c] = Binding(arr, "num", t, c)
        elif cdef.kind == ColKind.CAT:
            if s.string_dict:
                arr = reg(f"col/{c}", lambda c=c: t.data[c])
                kind = "codes"
            else:
                arr = reg(f"chars/{c}", lambda c=c: t.char_matrix(c))
                kind = "chars"
            if perm is not None:
                arr = be.take(arr, perm)
            bindings[c] = Binding(arr, kind, t, c)
        else:  # TEXT
            if s.string_dict:
                arr = reg(f"col/{c}", lambda c=c: t.data[c])
                kind = "words"
            else:
                arr = reg(f"chars/{c}", lambda c=c: t.char_matrix(c))
                kind = "wordchars"
            if perm is not None:
                arr = be.take(arr, perm)
            bindings[c] = Binding(arr, kind, t, c)

    if sp is None:
        return Frame(bindings)
    mkey = f"{scan.table}/shard{sp.n}/mask"
    ctx.sharded_keys.add(mkey)
    mask = ctx.input(mkey, lambda: sp.valid_mask(scan.table))
    return Frame(bindings, mask, part=scan.shard.part)
