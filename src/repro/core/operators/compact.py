"""Compact: selection-vector compaction to a static capacity bucket.

The mask-carrying execution model pays full-table cost in every operator
downstream of a selective predicate: a 0.2%-selectivity query still
gathers, sorts and segment-reduces over every row.  `Compact` converts the
frame to the dense, layout-specialized representation the paper's §3.2
argues for: `backend.compact(mask, capacity)` ranks the valid rows with a
cumsum and scatters their ids into an index vector of *statically planned*
`capacity` (JAX shapes must be static), then every column is gathered down
to `capacity` rows.  Downstream operators are oblivious — they see an
ordinary, much smaller Frame whose mask marks only the pad slots.

If more rows survive than the planner estimated, the surplus is dropped
from the index vector and the point's overflow flag (`count > capacity`)
is raised through `StageCtx.note_overflow`; the compile driver surfaces it
as the staged program's third output and `CompiledQuery` re-executes the
uncompacted fallback plan, so an estimate can only ever cost time.
"""
from __future__ import annotations

import numpy as np

from repro.core import ir
from repro.core.operators.base import (Binding, Frame, StageCtx, frame_nrows,
                                       ones_mask)


def stage(c: ir.Compact, ctx: StageCtx, defer: bool = False) -> Frame:
    f = ctx.stage(c.child)
    be, xp = ctx.backend, ctx.xp
    n = frame_nrows(f)
    cap = int(c.capacity)
    if cap >= n:
        # nothing to win (also: the 8-row collection walk, where the frame
        # is a sample slice — schema and input registration are unaffected)
        return f
    mask = f.mask if f.mask is not None else ones_mask(xp, n)
    idx, count = be.compact(mask, cap)
    ctx.note_overflow(count > cap)
    cols = {name: Binding(be.take(b.arr, idx), b.kind, b.table, b.col)
            for name, b in f.cols.items()}
    newmask = xp.arange(cap, dtype=np.int32) < count
    return Frame(cols, newmask, f.pending, capacity=cap)
