"""Compact: selection-vector compaction to a static capacity bucket.

The mask-carrying execution model pays full-table cost in every operator
downstream of a selective predicate: a 0.2%-selectivity query still
gathers, sorts and segment-reduces over every row.  `Compact` converts the
frame to the dense, layout-specialized representation the paper's §3.2
argues for: `backend.compact(mask, capacity)` ranks the valid rows with a
cumsum and scatters their ids into an index vector of *statically planned*
`capacity` (JAX shapes must be static), then every column is gathered down
to `capacity` rows.  Downstream operators are oblivious — they see an
ordinary, much smaller Frame whose mask marks only the pad slots.

If more rows survive than the planner estimated, the surplus is dropped
from the index vector; the point's TRUE valid count is registered through
`StageCtx.note_compact` and surfaced (keyed by point id) as part of the
staged program's third output.  `CompiledQuery` compares each count with
its planned capacity: on overflow it re-executes the uncompacted fallback
plan (an estimate can only ever cost time), and either way the measured
counts feed the plan cache's adaptive capacity feedback.
"""
from __future__ import annotations

import numpy as np

from repro.core import ir
from repro.core.operators.base import (Binding, Frame, StageCtx, frame_nrows,
                                       ones_mask)


def stage(c: ir.Compact, ctx: StageCtx, defer: bool = False) -> Frame:
    f = ctx.stage(c.child)
    be, xp = ctx.backend, ctx.xp
    n = frame_nrows(f)
    cap = int(c.capacity)
    if cap <= 0:
        # measure-only point (the overflow twin): report the true valid
        # count, touch nothing — no gather, no truncation, so every
        # point's count is exact even below another point's overflow
        count = xp.asarray(n, dtype=np.int32) if f.mask is None \
            else f.mask.astype(np.int32).sum()
        ctx.note_compact(c.point_id, count)
        return f
    if cap >= n:
        # nothing to win (also: the 8-row collection walk, where the frame
        # is a sample slice — schema and input registration are unaffected)
        return f
    mask = f.mask if f.mask is not None else ones_mask(xp, n)
    idx, count = be.compact(mask, cap)
    ctx.note_compact(c.point_id, count)
    cols = {name: Binding(be.take(b.arr, idx), b.kind, b.table, b.col)
            for name, b in f.cols.items()}
    newmask = xp.arange(cap, dtype=np.int32) < count
    return Frame(cols, newmask, f.pending, capacity=cap)
