"""Compact: selection-vector compaction to a static capacity bucket.

The mask-carrying execution model pays full-table cost in every operator
downstream of a selective predicate: a 0.2%-selectivity query still
gathers, sorts and segment-reduces over every row.  `Compact` converts the
frame to the dense, layout-specialized representation the paper's §3.2
argues for: `backend.compact(mask, capacity)` ranks the valid rows with a
cumsum and scatters their ids into an index vector of *statically planned*
`capacity` (JAX shapes must be static), then every column is gathered down
to `capacity` rows.  Downstream operators are oblivious — they see an
ordinary, much smaller Frame whose mask marks only the pad slots.

If more rows survive than the planner estimated, the surplus is dropped
from the index vector; the point's TRUE valid count is registered through
`StageCtx.note_compact` and surfaced (keyed by point id) as part of the
staged program's third output.  `CompiledQuery` compares each count with
its planned capacity: on overflow it re-executes the uncompacted fallback
plan (an estimate can only ever cost time), and either way the measured
counts feed the plan cache's adaptive capacity feedback.

Under `Settings.use_pallas` the XLA three-op sequence (cumsum →
searchsorted → gather-rank) is replaced by the single-HBM-pass Pallas
kernel (`repro.kernels.compact`), and when the child is a Select whose
predicate is kernel-safe over an elementwise chain, predicate evaluation
itself is fused into the same pass (`compact_pred`): the mask is never
materialized in HBM.  `translate` points additionally emit the CSR
key→slot vector consumed by `pk_gather` (see `ir.Compact`).
"""
from __future__ import annotations

import numpy as np

from repro.core import ir
from repro.core.operators import fused as fu
from repro.core.expr import eval_expr
from repro.core.operators.base import (Binding, Frame, StageCtx, and_masks,
                                       frame_nrows, ones_mask)


def _apply_pred(f: Frame, pred, ctx: StageCtx) -> None:
    """Fall back from in-kernel evaluation: apply the intercepted Select's
    predicate to the already-staged frame the ordinary way."""
    f.mask = and_masks(ctx.xp, f.mask, eval_expr(pred, ctx.env(f)))


def stage(c: ir.Compact, ctx: StageCtx, defer: bool = False) -> Frame:
    be, xp = ctx.backend, ctx.xp
    s = ctx.settings
    use_k = s.use_pallas and be.name == "jax"
    # fused interception: under the kernel path, a Select whose predicate
    # is kernel-safe over a pure elementwise chain is absorbed into the
    # compaction kernel — stage its *child* and keep the predicate.  The
    # structural checks run BEFORE staging so the Select is never staged
    # twice; any post-staging surprise falls back to normal evaluation.
    pred = None
    if (use_k and isinstance(c.child, ir.Select)
            and fu.elementwise_chain(c.child.child)
            and fu.kernel_safe(c.child.pred)):
        pred = c.child.pred
        f = ctx.stage(c.child.child)
        if f.mask is not None or f.pending:
            _apply_pred(f, pred, ctx)
            pred = None
    else:
        f = ctx.stage(c.child)
    n = frame_nrows(f)
    cap = int(c.capacity)
    if cap <= 0:
        # measure-only point (the overflow twin): report the true valid
        # count, touch nothing — no gather, no truncation, so every
        # point's count is exact even below another point's overflow
        if pred is not None:
            _apply_pred(f, pred, ctx)
        count = xp.asarray(n, dtype=np.int32) if f.mask is None \
            else f.mask.astype(np.int32).sum()
        ctx.note_compact(c.point_id, count)
        return f
    if cap >= n:
        # nothing to win (also: the 8-row collection walk, where the frame
        # is a sample slice — schema and input registration are unaffected)
        if pred is not None:
            _apply_pred(f, pred, ctx)
        return f
    operands = None
    if pred is not None:
        operands = fu.collect_operands(f, [pred], [], ctx)
        if operands is None:           # a referenced column isn't 1-D numeric
            _apply_pred(f, pred, ctx)
            pred = None
    slot = None
    if pred is not None:
        from repro.kernels import ops as kops

        cols_d, scalars, pnames = operands
        res = kops.compact_pred_query(
            cols_d, scalars, fu.make_tile_fn(pred, pnames), cap,
            translate=c.translate, interpret=s.pallas_interpret)
        idx, count = res[0], res[1]
        if c.translate:
            slot = res[2]
    else:
        mask = f.mask if f.mask is not None else ones_mask(xp, n)
        if use_k:
            from repro.kernels import ops as kops

            res = kops.compact_query(mask, cap, translate=c.translate,
                                     interpret=s.pallas_interpret)
            idx, count = res[0], res[1]
            if c.translate:
                slot = res[2]
        else:
            idx, count = be.compact(mask, cap)
            if c.translate:
                cs = xp.cumsum(mask.astype(np.int32))
                slot = xp.where(mask, cs - 1, np.int32(-1)).astype(np.int32)
    ctx.note_compact(c.point_id, count)
    cols = {name: Binding(be.take(b.arr, idx), b.kind, b.table, b.col)
            for name, b in f.cols.items()}
    newmask = xp.arange(cap, dtype=np.int32) < count
    return Frame(cols, newmask, f.pending, capacity=cap, slot_of=slot,
                 part=f.part)
