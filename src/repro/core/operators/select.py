"""Select: predicate evaluation into the frame's validity mask.

With `defer=True` (domain-specific code motion, §3.5) the predicate is
queued on the frame and evaluated by the consuming join *after* the gather,
hoisting the evaluation off the build side's full cardinality.
"""
from __future__ import annotations

from repro.core import ir
from repro.core.expr import eval_expr
from repro.core.operators.base import Frame, StageCtx, and_masks


def stage(sel: ir.Select, ctx: StageCtx, defer: bool = False) -> Frame:
    f = ctx.stage(sel.child, defer)
    if defer:
        f.pending.append(sel.pred)
        return f
    m = eval_expr(sel.pred, ctx.env(f))
    f.mask = and_masks(ctx.xp, f.mask, m)
    return f
