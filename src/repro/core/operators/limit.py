"""Limit, with the beyond-paper ORDER BY + LIMIT k -> top-k rewrite.

The global sort over the padded aggregation domain is wasted work when only
k rows survive; with `Settings.topk_limit` the primary sort key feeds a
top-k selection and only the k survivors are fully sorted.  `Limit.n` must
be a static int by the time staging runs (a Param limit is compile-time and
resolved by the ParamBinding pass).
"""
from __future__ import annotations

import numpy as np

from repro.core import ir
from repro.core.expr import Param
from repro.core.operators.base import (Binding, F32BIG, Frame, StageCtx,
                                       frame_nrows)
from repro.core.operators.sort import sort_frame


def stage(lim: ir.Limit, ctx: StageCtx, defer: bool = False) -> Frame:
    if isinstance(lim.n, Param):
        raise TypeError(f"Limit parameter {lim.n.name!r} must be bound at "
                        "compile time (top-k needs a static k)")
    if (ctx.settings.topk_limit and isinstance(lim.child, ir.Sort)
            and lim.child.keys):
        srt = lim.child
        f = ctx.stage(srt.child)
        name0, asc0 = srt.keys[0]
        b0 = f.cols[name0]
        if b0.arr.ndim == 1:
            be, xp = ctx.backend, ctx.xp
            n_rows = frame_nrows(f)
            k = min(lim.n, n_rows)
            key = b0.arr.astype(np.float32)
            key = key if not asc0 else -key
            if f.mask is not None:
                key = xp.where(f.mask, key, -F32BIG)
            if be.name == "jax":
                import jax

                _, idx = jax.lax.top_k(key, k)
            else:
                idx = np.argsort(-key, kind="stable")[:k]
            cols = {nm: Binding(be.take(b.arr, idx), b.kind, b.table,
                                b.col) for nm, b in f.cols.items()}
            mask = None if f.mask is None else be.take(f.mask, idx)
            sub = Frame(cols, mask, part=f.part)
            return sort_frame(sub, srt.keys, ctx)
    f = ctx.stage(lim.child)
    n = min(lim.n, frame_nrows(f))
    cols = {name: Binding(b.arr[:n], b.kind, b.table, b.col)
            for name, b in f.cols.items()}
    mask = None if f.mask is None else f.mask[:n]
    return Frame(cols, mask, part=f.part)
