"""Kernel-fusion bridge: compile plan expressions into Pallas tile closures.

The kernels in `repro.kernels` are deliberately core-independent — they
take named column blocks plus parameter scalars and caller-supplied tile
functions.  This module is the only place the two layers meet: it decides
whether a Select/Agg subtree is *kernel-safe* (every expression evaluates
elementwise over 1-D numeric/code columns — no char-matrix string ops, no
word matrices, nothing 2-D) and, when it is, packages the staged frame's
columns, registers the runtime parameters as kernel scalar inputs, and
wraps `eval_expr` in a `TileEnv` closure the kernel calls per (tile,)
block.  The closures are pure jnp: the SAME expression evaluator that
stages the unfused path runs inside the kernel, so fused and unfused
execution can never disagree on predicate semantics.
"""
from __future__ import annotations

from repro.core import expr as E
from repro.core import ir

# expression nodes whose evaluation is elementwise over 1-D operands (the
# char/word-matrix string ops need 2-D blocks — not kernel-representable)
_SAFE = (E.Col, E.Const, E.Param, E.Arith, E.Cmp, E.And, E.Or, E.Not,
         E.Where, E.Year, E.CodeEq, E.CodeIn, E.CodeRange)


def kernel_safe(e: E.Expr) -> bool:
    """True when every node of `e` evaluates elementwise on 1-D blocks."""
    if not isinstance(e, _SAFE):
        return False
    if isinstance(e, E.Param) and e.dtype == "str":
        return False
    if isinstance(e, (E.Arith, E.Cmp, E.And, E.Or)):
        return kernel_safe(e.lhs) and kernel_safe(e.rhs)
    if isinstance(e, (E.Not, E.Year)):
        return kernel_safe(e.operand)
    if isinstance(e, E.Where):
        return (kernel_safe(e.cond) and kernel_safe(e.then)
                and kernel_safe(e.other))
    return True


def expr_params(e: E.Expr) -> list[E.Param]:
    """Runtime Params of `e`, deduped by name, in first-visit order (the
    positional order scalars are handed to the kernel in)."""
    out: list[E.Param] = []
    seen: set[str] = set()

    def rec(x):
        if isinstance(x, E.Param):
            if x.name not in seen:
                seen.add(x.name)
                out.append(x)
        elif isinstance(x, (E.Arith, E.Cmp, E.And, E.Or)):
            rec(x.lhs), rec(x.rhs)
        elif isinstance(x, (E.Not, E.Year)):
            rec(x.operand)
        elif isinstance(x, E.Where):
            rec(x.cond), rec(x.then), rec(x.other)

    rec(e)
    return out


def elementwise_chain(p: ir.Plan) -> bool:
    """True when `p` is a Scan under (only) Projects — the frame has no
    mask, no pending predicates, and no other operator in between, so a
    fused kernel's in-kernel predicate is the frame's ONLY filter."""
    while isinstance(p, ir.Project):
        p = p.child
    return isinstance(p, ir.Scan)


class TileEnv(E.EvalEnv):
    """`eval_expr` environment over one kernel tile: columns resolve to
    the (tile,) blocks the kernel loaded, Params to its scalar refs."""

    def __init__(self, cols: dict, scalars: dict):
        import jax.numpy as jnp

        super().__init__(jnp, cse=True)
        self._cols = cols
        self._scalars = scalars

    def get_num(self, name):
        return self._cols[name]

    def get_codes(self, name):
        return self._cols[name]

    def get_param(self, p: E.Param):
        return self._scalars[p.name]


def collect_operands(frame, exprs: list, extra_cols: list, ctx):
    """(cols, scalars, param_names) for a kernel invocation, or None when
    any referenced column is not a 1-D numeric/code binding.

    cols maps every column any expr (or `extra_cols`) reads to its staged
    array; scalars is the positional list of traced parameter values
    (registered through `ctx.param`, so re-binding never re-stages);
    param_names matches scalars positionally.
    """
    names: set[str] = set(extra_cols)
    for e in exprs:
        names |= E.expr_columns(e)
    cols = {}
    for nm in sorted(names):
        b = frame.cols.get(nm)
        if b is None or b.kind not in ("num", "codes") \
                or getattr(b.arr, "ndim", 0) != 1:
            return None
        cols[nm] = b.arr
    params: list[E.Param] = []
    seen: set[str] = set()
    for e in exprs:
        for p in expr_params(e):
            if p.name not in seen:
                seen.add(p.name)
                params.append(p)
    scalars = [ctx.param(p) for p in params]
    return cols, scalars, [p.name for p in params]


def make_tile_fn(e: E.Expr, param_names: list[str]):
    """One expression -> kernel tile closure `(cols, scalars) -> block`."""
    def fn(cols, scalars):
        env = TileEnv(cols, dict(zip(param_names, scalars)))
        return E.eval_expr(e, env)
    return fn
