"""Exchange: explicit cross-shard data movement (ir.Exchange).

The only planted kind today is `gather`: all-gather every column (and the
validity mask) along the data axis so each shard holds the full global
frame — the lowering for consumers that need replicated input (generic
join builds, global sorts, sort-based aggregations, the plan root).

Layout consequences (see loader.ShardPlan): a root-partitioned frame
gathers back into global positional order (pad rows stay masked), so
parent-table alignment survives; a routed frame gathers into owner-grouped
order — a permutation of the table, fine for every consumer that forced
the Exchange (they are all order-insensitive or re-sort).

On the numpy collection walk the backend's collectives are identities, so
the operator is shape-transparent there — it registers no inputs of its
own.
"""
from __future__ import annotations

from repro.core import ir
from repro.core.operators.base import Binding, Frame, StageCtx, ones_mask


def stage(x: ir.Exchange, ctx: StageCtx, defer: bool = False) -> Frame:
    f = ctx.stage(x.child)
    if f.part is None:
        # already replicated: the pass only plants Exchange on partitioned
        # subtrees, but a defensive passthrough keeps hand-built plans valid
        return f
    be = ctx.backend
    n = None
    for b in f.cols.values():
        n = b.arr.shape[0]
        break
    mask = f.mask if f.mask is not None else ones_mask(ctx.xp, n)
    cols = {name: Binding(be.all_gather(b.arr, ctx.axis, tiled=True),
                          b.kind, b.table, b.col)
            for name, b in f.cols.items()}
    gmask = be.all_gather(mask, ctx.axis, tiled=True)
    # capacity/slot_of describe per-shard physical layouts; both are
    # meaningless on the gathered frame
    return Frame(cols, gmask, f.pending, part=None)
