"""Join: the four lowered strategies of §3.2.1.

  pk_gather     — PK/FK equi-join as a vectorized gather (the 1-D
                  partitioned array is the parent table itself);
  bucket_gather — composite-PK join probing the load-time 2-D partitioned
                  array (bucket on key1, discriminate on key2);
  exists_flag   — semi/anti membership via a dense boolean over the key
                  domain;
  generic       — sort + binary-search equi-join (unique build keys).
"""
from __future__ import annotations

import numpy as np

from repro.core import ir
from repro.core.expr import eval_expr
from repro.core.operators.base import (Binding, Frame, I32MAX, StageCtx,
                                       and_masks, frame_nrows, ones_mask)


def _apply_pending(out: Frame, build: Frame, ctx: StageCtx) -> None:
    if build.pending:
        env = ctx.env(out)
        for pred in build.pending:
            out.mask = and_masks(ctx.xp, out.mask, eval_expr(pred, env))


def stage(j: ir.Join, ctx: StageCtx, defer: bool = False) -> Frame:
    be, xp = ctx.backend, ctx.xp
    stream = ctx.stage(j.stream)

    if j.strategy == "pk_gather":
        build = ctx.stage(j.build, defer=not ctx.settings.hoist)
        if build.slot_of is not None:
            # compacted (translate) build side: the parent-positional
            # addressing pk_gather relies on is gone, so probe the CSR
            # key→slot vector first — slot_of lives on the parent row
            # domain, its values address the compacted frame.  A slot of
            # -1 is a mask-invalid parent row; a slot >= n_b is a row the
            # compaction overflowed past capacity (dropped here, but the
            # point's count already exceeds capacity so the runtime
            # re-executes the uncompacted fallback — never a wrong answer).
            n_b = frame_nrows(build)
            slot = be.take(build.slot_of, stream.cols[j.stream_key].arr)
            idx = xp.clip(slot, 0, n_b - 1)
            bmask_g = (slot >= 0) & (slot < n_b)
            if build.mask is not None:
                bmask_g = bmask_g & be.take(build.mask, idx)
        else:
            idx = stream.cols[j.stream_key].arr
            bmask_g = None
            if build.part is not None:
                # co-partitioned build (root range partition): the FK is a
                # *global* parent row id, the build frame holds only this
                # shard's block [s*P, (s+1)*P) — rebase to the local row.
                # A routed stream's keys land in-range by construction
                # (ShardPlan sends every row to its parent's owner); the
                # bound check still runs so hand-built plans fail masked,
                # not silently wrong.
                n_b = frame_nrows(build)
                base = be.axis_index(ctx.axis) * np.int32(n_b)
                local = idx - base
                bmask_g = (local >= 0) & (local < n_b)
                idx = xp.clip(local, 0, max(n_b - 1, 0))
            if build.mask is not None:
                got = be.take(build.mask, idx)
                bmask_g = got if bmask_g is None else bmask_g & got
        cols = dict(stream.cols)
        for name, b in build.cols.items():
            if name in cols:
                continue
            g = be.take(b.arr, idx)
            if j.kind == "left" and bmask_g is not None and g.ndim == 1:
                g = xp.where(bmask_g, g, 0)  # missing match -> default 0
            cols[name] = Binding(g, b.kind, b.table, b.col)
        mask = stream.mask
        if j.kind != "left" and bmask_g is not None:
            mask = and_masks(xp, mask, bmask_g)
        out = Frame(cols, mask, part=stream.part)
        _apply_pending(out, build, ctx)
        return ctx.barrier(out)

    if j.strategy == "bucket_gather":
        # composite-PK join via the load-time 2-D partitioned array
        # (§3.2.1): bucket on key1, discriminate on key2 within the
        # statically-bounded bucket width.
        build = ctx.stage(j.build, defer=not ctx.settings.hoist)
        _require_replicated(j, build, "bucket_gather")
        w = j.bucket_width
        mat = ctx.input(
            f"{j.build_table}/fkbucket/{j.build_key}",
            lambda: ctx.db.fk_bucket(j.build_table, j.build_key)[0])
        rows = be.take(mat, stream.cols[j.stream_key].arr)   # (n, W)
        bkey2 = build.cols[j.build_key2].arr
        skey2 = stream.cols[j.stream_key2].arr
        bmask = build.mask
        idx = None
        hit = None
        for slot in range(w):
            r = rows[:, slot]
            ok = r >= 0
            cand = be.take(bkey2, xp.clip(r, 0, None))
            m = ok & (cand == skey2)
            if bmask is not None:
                m = m & be.take(bmask, xp.clip(r, 0, None))
            idx = xp.where(m, r, 0) if idx is None else xp.where(m, r, idx)
            hit = m if hit is None else (hit | m)
        cols = dict(stream.cols)
        for name, b in build.cols.items():
            if name in cols:
                continue
            cols[name] = Binding(be.take(b.arr, idx), b.kind, b.table, b.col)
        out = Frame(cols, and_masks(xp, stream.mask, hit), part=stream.part)
        _apply_pending(out, build, ctx)
        return ctx.barrier(out)

    if j.strategy == "exists_flag":
        build = ctx.stage(j.build)
        n_b = frame_nrows(build)
        bkey = build.cols[j.build_key].arr
        bm = build.mask if build.mask is not None else ones_mask(xp, n_b)
        flags = be.segment_max(bm.astype(np.int32), bkey, j.domain, 0)
        if build.part is not None:
            # partitioned build: each shard scattered only its local rows
            # into the (global-domain) flag vector — union across shards.
            # The dense flag array is permutation-safe, so no Exchange is
            # needed for semi/anti membership.
            flags = be.pmax(flags, ctx.axis)
        flags = flags > 0
        hit = be.take(flags, stream.cols[j.stream_key].arr)
        if j.kind == "anti":
            hit = ~hit
        stream.mask = and_masks(xp, stream.mask, hit)
        return ctx.barrier(stream)

    # generic sort-based equi join (build keys unique: PK or group keys)
    build = ctx.stage(j.build)
    _require_replicated(j, build, "generic")
    n_b = frame_nrows(build)
    if j.stream_key2 is not None:
        # composite key: pack into uint32 (k1·K2 + k2; bound documented)
        k2b = _key2_bound(j, stream, build)
        bkey = (build.cols[j.build_key].arr.astype(np.uint32) * k2b
                + build.cols[j.build_key2].arr.astype(np.uint32))
        skey_stream = (stream.cols[j.stream_key].arr.astype(np.uint32)
                       * k2b
                       + stream.cols[j.stream_key2].arr.astype(np.uint32))
        sentinel = np.uint32(2**32 - 1)
    else:
        bkey = build.cols[j.build_key].arr.astype(np.int32)
        skey_stream = stream.cols[j.stream_key].arr
        sentinel = I32MAX
    bm = build.mask if build.mask is not None else ones_mask(xp, n_b)
    keys = xp.where(bm, bkey, sentinel)
    order = xp.argsort(keys)
    skeys = be.take(keys, order)
    pos = be.searchsorted(skeys, skey_stream)
    pos = xp.clip(pos, 0, max(n_b - 1, 0))
    hit = be.take(skeys, pos) == skey_stream
    if j.kind == "semi":
        stream.mask = and_masks(xp, stream.mask, hit)
        return ctx.barrier(stream)
    if j.kind == "anti":
        stream.mask = and_masks(xp, stream.mask, ~hit)
        return ctx.barrier(stream)
    bidx = be.take(order, pos)
    cols = dict(stream.cols)
    for name, b in build.cols.items():
        if name in cols:
            continue
        g = be.take(b.arr, bidx)
        if j.kind == "left" and g.ndim == 1:
            g = xp.where(hit, g, 0)
        cols[name] = Binding(g, b.kind, b.table, b.col)
    mask = stream.mask if j.kind == "left" else and_masks(xp, stream.mask, hit)
    return ctx.barrier(Frame(cols, mask, part=stream.part))


def _require_replicated(j: ir.Join, build: Frame, strategy: str) -> None:
    """Strategies that see only a shard-local slice of the build frame
    would silently drop matches; the Sharding pass plants a gather
    Exchange below them, so a partitioned build reaching staging is a
    plan bug, not a data condition."""
    if build.part is None:
        return
    from repro.core.analysis import PlanInvariantError

    raise PlanInvariantError(
        "shard-invariance",
        f"{strategy} join build on {j.build_key!r} is partitioned "
        f"(root={build.part}) — needs a gather Exchange",
        node=j, pass_name="staging")


def _stats_max(frame: Frame, key: str):
    b = frame.cols[key]
    if b.table is not None and b.col in b.table.stats:
        return int(b.table.stats[b.col].max)
    return None


def _key2_bound(j: ir.Join, stream: Frame, build: Frame) -> np.uint32:
    """Static bound for the second key of a composite-key pack.

    The generic composite join packs `k1 * K2 + k2` into uint32; K2 must
    exceed *both* sides' k2 values or distinct pairs collide, and the
    packed value must fit 32 bits or the pack wraps and matches garbage.
    Both bounds come from `analysis.composite_pack_bound` (the verifier's
    final-only `key-pack` rule applies the same arithmetic to ColInfo
    bounds at optimize time); staging re-checks against the *staged
    frames'* provenance — a silent-overflow pack never compiles, even on
    hand-built plans that bypassed the pipeline.
    """
    from repro.core.analysis import PlanInvariantError, composite_pack_bound

    k1_maxes = [m for m in (_stats_max(build, j.build_key),
                            _stats_max(stream, j.stream_key))
                if m is not None]
    k2_maxes = [m for m in (_stats_max(build, j.build_key2),
                            _stats_max(stream, j.stream_key2))
                if m is not None]
    K2, packed_max = composite_pack_bound(
        max(k1_maxes) if k1_maxes else None, k2_maxes)
    if packed_max is not None and packed_max >= 2**32:
        raise PlanInvariantError(
            "key-pack",
            f"composite join key ({j.stream_key},{j.stream_key2}) "
            f"cannot pack into uint32: max_k1={max(k1_maxes)} * "
            f"K2={K2} + {K2 - 1} = {packed_max} >= 2**32; "
            "the generic composite strategy needs a wider pack",
            node=j, pass_name="staging")
    return np.uint32(K2)
