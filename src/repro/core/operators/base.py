"""Shared staging context + frame types for the physical-operator layer.

Each operator module in `repro.core.operators` exposes

    stage(node, ctx, defer=False) -> Frame

and is a pure function of the plan node and the `StageCtx` — no operator
knows about any other (the GenDB-style modularity argument: operators are
independently testable units).  The same code runs twice per compilation:
eagerly on numpy 8-row samples (the collection walk, registering the staged
program's exact input set) and under `jax.jit` tracing (the staged walk
producing the fused XLA program).  `StageCtx.backend` is the only
difference between the two.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import numpy as np

from repro.core.expr import EvalEnv, Param

I32MAX = np.int32(2**31 - 1)
F32BIG = np.float32(3.0e38)


@dataclasses.dataclass
class Binding:
    arr: Any
    kind: str                     # num | codes | chars | words | wordchars
    table: Optional[object] = None  # source Table (for vocab decode)
    col: Optional[str] = None


@dataclasses.dataclass
class Frame:
    cols: dict[str, Binding]
    mask: Any = None              # bool array or None (all valid)
    pending: list = dataclasses.field(default_factory=list)
    # set by the Compact operator: this frame's physical row count is a
    # planner-assigned compaction capacity (valid rows are dense-packed at
    # the front; `mask` marks the pad slots).  Purely informational — no
    # operator branches on it — but tests and debugging read it.
    capacity: Any = None
    # set by a translate-Compact (ir.Compact.translate): the CSR key→slot
    # vector over the PRE-compaction row domain — slot_of[row] is the
    # row's position in this compacted frame, -1 when the row was
    # mask-invalid.  pk_gather consumes it to probe a compacted build
    # side by key value (overflowed rows map past `capacity`; the join
    # drops them and the point's overflow flag triggers the fallback).
    slot_of: Any = None
    # partition root table when this frame's rows are physically sharded
    # over the mesh's data axis (see loader.ShardPlan); None = replicated.
    # Set by the Scan operator from the Sharding pass's annotation and
    # threaded through every operator identically in both walks — it is
    # what tells a join to rebase positional indices, an aggregation to
    # psum its partials, and an Exchange to all-gather.
    part: Optional[str] = None

    def copy(self) -> "Frame":
        return Frame(dict(self.cols), self.mask, list(self.pending),
                     self.capacity, self.slot_of, self.part)


def frame_nrows(f: Frame) -> int:
    b = next(iter(f.cols.values()))
    return b.arr.shape[0]


def ones_mask(xp, n):
    return xp.ones((n,), dtype=bool)


def and_masks(xp, m1, m2):
    if m1 is None:
        return m2
    if m2 is None:
        return m1
    return m1 & m2


@dataclasses.dataclass
class StageCtx:
    """Everything an operator needs to stage itself.

    `input(key, make)` registers/fetches a named input of the staged
    program: during the collection walk it materializes `make()` and
    records it; during the traced walk it returns the corresponding traced
    array.  `params` holds the current runtime parameter bindings (used as
    concrete values in the collection walk and registered as scalar inputs
    `param/<name>` so re-binding never re-stages).

    `batched` marks the vmapped traced walk of `CompiledQuery.run_many`:
    the staged program's `param/<name>` inputs are *leading-axis vectors*
    of shape (B,) — one slot per concurrent binding — and `jax.vmap`
    splits that axis before operators run, so inside the walk every param
    is still the scalar the operator code expects (base columns are
    broadcast, `in_axes=None`).  The flag exists to make that axes
    contract checkable at the only point where params enter the program.
    """
    db: Any
    settings: Any
    backend: Any
    input: Callable[[str, Callable], Any]
    params: dict = dataclasses.field(default_factory=dict)
    batched: bool = False
    # traced per-compaction-point TRUE valid counts (int32 scalars), keyed
    # by the point's id.  The compile driver surfaces the whole dict as the
    # staged program's third output: a count above the point's capacity is
    # the overflow signal (the runtime re-executes the uncompacted
    # fallback plan), and the counts themselves feed PlanCache's adaptive
    # capacity feedback (re-plan/shrink from measured headroom).
    compact_counts: dict = dataclasses.field(default_factory=dict)
    n_compactions: int = 0        # Compact points actually staged this walk
    # sharded execution (Settings.shards > 1): `axis` is the mesh axis name
    # the staged fn is shard_map-wrapped over (None single-device — the
    # numpy collection walk gets the axis too, where collectives are
    # identities), `n_shards` its size, `shard_plan` the loader's
    # co-partitioning layout.  `sharded_keys` collects the input keys whose
    # arrays are partitioned over the axis — compile.py turns it into the
    # shard_map in_specs.
    axis: Optional[str] = None
    n_shards: int = 1
    shard_plan: Any = None
    sharded_keys: set = dataclasses.field(default_factory=set)

    @property
    def xp(self):
        return self.backend.xp

    def stage(self, plan, defer: bool = False) -> Frame:
        from repro.core import operators

        return operators.stage(plan, self, defer)

    def env(self, frame: Frame) -> "FrameEnv":
        return FrameEnv(frame, self)

    def param(self, p: Param):
        if p.dtype == "str":
            raise TypeError(f"string parameter {p.name!r} must be bound at "
                            "compile time (it has no runtime representation)")
        if p.name not in self.params:
            raise KeyError(f"unbound query parameter {p.name!r}")
        v = self.input(
            f"param/{p.name}",
            lambda: np.asarray(self.params[p.name], dtype=p.dtype))
        # axes contract: operators always see a scalar.  In the batched
        # walk the (B,) leading axis was split off by vmap before we got
        # here; a non-scalar value means a caller bound a vector where the
        # program expects one scalar per binding slot.
        if getattr(v, "ndim", 0) != 0:
            raise TypeError(
                f"param/{p.name} must reach operators as a scalar "
                f"(got shape {v.shape}; batched={self.batched})")
        return v

    def note_compact(self, point_id: str, count) -> None:
        """Register a compaction point's true valid count (a backend int
        scalar: concrete in the collection walk, traced under jit).  The
        count is the cumsum total over the full mask, so it is exact even
        when it exceeds the point's capacity — that excess IS the
        overflow signal, and its magnitude is what re-planning needs."""
        if point_id in self.compact_counts:
            raise ValueError(f"compaction point {point_id!r} staged twice")
        self.compact_counts[point_id] = count
        self.n_compactions += 1

    def barrier(self, f: Frame) -> Frame:
        """fusion=False: cut the XLA fusion scope at operator boundaries."""
        if self.settings.fusion or self.backend.name == "numpy":
            return f
        arrs = {n: b.arr for n, b in f.cols.items()}
        wrapped = self.backend.barrier(arrs)
        cols = {n: Binding(wrapped[n], b.kind, b.table, b.col)
                for n, b in f.cols.items()}
        mask = None if f.mask is None else self.backend.barrier(f.mask)
        slot = None if f.slot_of is None else self.backend.barrier(f.slot_of)
        return Frame(cols, mask, f.pending, f.capacity, slot, f.part)


class FrameEnv(EvalEnv):
    """Expression environment over a staged Frame."""

    def __init__(self, frame: Frame, ctx: StageCtx):
        super().__init__(ctx.backend.xp, ctx.settings.cse)
        self.frame = frame
        self.ctx = ctx

    def _b(self, name: str) -> Binding:
        return self.frame.cols[name]

    def get_num(self, name):
        b = self._b(name)
        assert b.kind in ("num", "codes"), f"{name} is {b.kind}, not numeric"
        return b.arr

    def get_codes(self, name):
        b = self._b(name)
        assert b.kind == "codes", f"{name} has no dictionary codes ({b.kind})"
        return b.arr

    def get_chars(self, name):
        b = self._b(name)
        assert b.kind == "chars", f"{name} has no char matrix ({b.kind})"
        return b.arr

    def get_words(self, name):
        b = self._b(name)
        assert b.kind == "words", f"{name} has no word codes ({b.kind})"
        return b.arr

    def get_word_chars(self, name):
        b = self._b(name)
        assert b.kind == "wordchars", f"{name} has no text chars ({b.kind})"
        return b.arr

    def get_param(self, p: Param):
        # runtime params are inputs of the staged program, not env literals
        return self.ctx.param(p)
