"""Aggregation: the three §3.2.2 strategies.

  scalar  — no group key: accumulators are scalar registers (optionally the
            fused filter+agg Pallas kernel);
  dense   — statically-known key domains: the hash map is a pre-allocated
            array indexed by a mixed-radix composite of the key codes;
  generic — sort-based grouping (the un-specialized hash map).
"""
from __future__ import annotations

import numpy as np

from repro.core import ir
from repro.core.expr import eval_expr
from repro.core.operators import fused as fu
from repro.core.operators.base import (Binding, F32BIG, Frame, StageCtx,
                                       and_masks, frame_nrows, ones_mask)


def _dense_domain(a: ir.Agg) -> int:
    D = 1
    for d in a.domains:
        D *= d
    return D


def _fusible(a: ir.Agg, ctx: StageCtx) -> bool:
    """Can this Agg absorb its child Select into the selective pipeline
    kernel?  Structure is checked BEFORE anything stages (the Select must
    never stage twice); operand shapes are re-checked after."""
    if not (ctx.settings.use_pallas and ctx.backend.name == "jax"):
        return False
    if not isinstance(a.child, ir.Select):
        return False
    if not (fu.elementwise_chain(a.child.child)
            and fu.kernel_safe(a.child.pred)):
        return False
    if not all(sp.fn in ("sum", "count", "avg") for sp in a.aggs):
        return False
    if not all(sp.expr is None or fu.kernel_safe(sp.expr) for sp in a.aggs):
        return False
    if a.strategy == "scalar" or not a.group_by:
        return True
    return (a.strategy == "dense" and not a.carry
            and _dense_domain(a) <= 4096)


def _fused_stage(a: ir.Agg, f: Frame, pred, ctx: StageCtx):
    """Stage the q6/q19-class selective pipeline: predicate + grouped
    aggregation in ONE kernel pass, no mask ever materialized in HBM.
    Returns None when operand collection fails (caller falls back)."""
    from repro.kernels import ops as kops

    xp = ctx.xp
    names = [sp.name for sp in a.aggs if sp.expr is not None]
    val_exprs = [sp.expr for sp in a.aggs if sp.expr is not None]
    operands = fu.collect_operands(f, [pred] + val_exprs,
                                   list(a.group_by), ctx)
    if operands is None:
        return None
    cols_d, scalars, pnames = operands
    pred_fn = fu.make_tile_fn(pred, pnames)
    value_fns = [fu.make_tile_fn(e, pnames) for e in val_exprs]
    gidx_fn = None
    n_groups = 1
    if a.group_by:                        # dense: mixed-radix in-kernel
        D = _dense_domain(a)
        strides = []
        st = 1
        for d in reversed(a.domains):
            strides.append(st)
            st *= d
        strides = list(reversed(strides))
        radix = list(zip(a.group_by, a.domains, strides))

        def gidx_fn(cols, _scalars):
            idx = None
            for g, _d, stg in radix:
                part = cols[g].astype(np.int32) * np.int32(stg)
                idx = part if idx is None else idx + part
            return xp.clip(idx, 0, D - 1)

        n_groups = D
    sums_m, cnt, _total = kops.selective_agg_query(
        cols_d, scalars, pred_fn, value_fns, gidx_fn, n_groups,
        interpret=ctx.settings.pallas_interpret)
    if f.part is not None:
        sums_m = ctx.backend.psum(sums_m, ctx.axis)
        cnt = ctx.backend.psum(cnt, ctx.axis)

    def agg_col(spec, row):
        if spec.fn == "sum":
            return sums_m[row, names.index(spec.name)]
        if spec.fn == "count":
            return cnt[row].astype(np.int32)
        return sums_m[row, names.index(spec.name)] / xp.maximum(cnt[row], 1.0)

    if not a.group_by:
        cols = {sp.name: Binding(agg_col(sp, slice(0, 1)), "num")
                for sp in a.aggs}
        return ctx.barrier(Frame(cols, None))
    cols: dict[str, Binding] = {}
    ar = xp.arange(n_groups, dtype=np.int32)
    for g, d, stg in radix:
        b = f.cols[g]
        keyvals = (ar // np.int32(stg)) % np.int32(d)
        cols[g] = Binding(keyvals, b.kind, b.table, b.col)
    for sp in a.aggs:
        cols[sp.name] = Binding(agg_col(sp, slice(None)), "num")
    return ctx.barrier(Frame(cols, cnt > 0))


def stage(a: ir.Agg, ctx: StageCtx, defer: bool = False) -> Frame:
    be, xp = ctx.backend, ctx.xp
    pred = None
    if _fusible(a, ctx):
        pred = a.child.pred
        f = ctx.stage(a.child.child)
        if f.mask is not None or f.pending:
            # the chain carried state the kernel can't see — evaluate the
            # intercepted predicate the ordinary way instead
            f.mask = and_masks(xp, f.mask, eval_expr(pred, ctx.env(f)))
            pred = None
    else:
        f = ctx.stage(a.child)
    if pred is not None:
        out = _fused_stage(a, f, pred, ctx)
        if out is not None:
            return out
        f.mask = and_masks(xp, f.mask, eval_expr(pred, ctx.env(f)))
    n = frame_nrows(f)
    env = ctx.env(f)
    mask = f.mask if f.mask is not None else ones_mask(xp, n)
    mi32 = mask.astype(np.int32)
    vals = {}
    for spec in a.aggs:
        if spec.expr is not None:
            vals[spec.name] = eval_expr(spec.expr, env)

    def _finalize(spec, sums, counts, mins, maxs):
        if spec.fn == "sum":
            return sums[spec.name]
        if spec.fn == "count":
            return counts[spec.name]
        if spec.fn == "avg":
            c = counts[spec.name]
            return sums[spec.name] / xp.maximum(c, 1).astype(np.float32)
        if spec.fn == "min":
            return mins[spec.name]
        if spec.fn == "max":
            return maxs[spec.name]
        raise ValueError(spec.fn)

    def _kernel_ok(D):
        return (ctx.settings.use_pallas and be.name == "jax" and D <= 4096
                and all(s_.fn in ("sum", "count", "avg") for s_ in a.aggs)
                and all(v.ndim == 1 for v in vals.values()))

    if a.strategy == "scalar" or not a.group_by:
        # (the 'scalar' annotation additionally enables kernel fusion;
        # functionally an empty group-by is always a single group)
        if _kernel_ok(1):
            from repro.kernels import ops as kops

            names = [s_.name for s_ in a.aggs if s_.expr is not None]
            sums_m, cnt = kops.filter_agg_query(
                mask, xp.zeros((n,), dtype=np.int32),
                [vals[nm].astype(np.float32) for nm in names], 1,
                interpret=ctx.settings.pallas_interpret)
            if f.part is not None:
                sums_m = be.psum(sums_m, ctx.axis)
                cnt = be.psum(cnt, ctx.axis)
            cols = {}
            for spec in a.aggs:
                if spec.fn == "sum":
                    v = sums_m[0:1, names.index(spec.name)]
                elif spec.fn == "count":
                    v = cnt[0:1].astype(np.int32)
                else:  # avg
                    v = (sums_m[0:1, names.index(spec.name)]
                         / xp.maximum(cnt[0:1], 1.0))
                cols[spec.name] = Binding(v, "num")
            return ctx.barrier(Frame(cols, None))
        # partitioned input: every reduction is computed over the local
        # shard and combined with the matching collective BEFORE any
        # finalization (avg divides psum(sum) by psum(count)), so the
        # output is bit-identical on every shard — replicated, no Exchange
        combine = f.part is not None
        cols = {}
        for spec in a.aggs:
            if spec.fn == "count":
                v = mi32.sum()
                if combine:
                    v = be.psum(v, ctx.axis)
                v = v[None]
            elif spec.fn == "sum":
                v = xp.where(mask, vals[spec.name], 0).sum()
                if combine:
                    v = be.psum(v, ctx.axis)
                v = v[None]
            elif spec.fn == "avg":
                sv = xp.where(mask, vals[spec.name], 0).sum()
                cv = mi32.sum()
                if combine:
                    sv = be.psum(sv, ctx.axis)
                    cv = be.psum(cv, ctx.axis)
                v = (sv / xp.maximum(cv, 1).astype(np.float32))[None]
            elif spec.fn == "min":
                v = xp.where(mask, vals[spec.name], F32BIG).min()
                if combine:
                    v = be.pmin(v, ctx.axis)
                v = v[None]
            elif spec.fn == "max":
                v = xp.where(mask, vals[spec.name], -F32BIG).max()
                if combine:
                    v = be.pmax(v, ctx.axis)
                v = v[None]
            cols[spec.name] = Binding(v, "num")
        return ctx.barrier(Frame(cols, None))

    if a.strategy == "dense":
        D = 1
        for d in a.domains:
            D *= d
        # mixed-radix composite index (strides baked at staging time)
        idx = None
        strides = []
        st = 1
        for d in reversed(a.domains):
            strides.append(st)
            st *= d
        strides = list(reversed(strides))
        for g, d, stg in zip(a.group_by, a.domains, strides):
            part = f.cols[g].arr.astype(np.int32) * np.int32(stg)
            idx = part if idx is None else idx + part
        idx = xp.clip(idx, 0, D - 1)
        kernel_sums = kernel_counts = None
        if _kernel_ok(D):
            from repro.kernels import ops as kops

            names = [s_.name for s_ in a.aggs if s_.expr is not None]
            sums_m, cnt = kops.filter_agg_query(
                mask, idx, [vals[nm].astype(np.float32) for nm in names], D,
                interpret=ctx.settings.pallas_interpret)
            if f.part is not None:
                sums_m = be.psum(sums_m, ctx.axis)
                cnt = be.psum(cnt, ctx.axis)
            kernel_sums = {nm: sums_m[:, i] for i, nm in enumerate(names)}
            kernel_counts = cnt
            present = (cnt > 0).astype(np.int32)
        else:
            present = be.segment_max(mi32, idx, D, 0)
            if f.part is not None:
                present = be.pmax(present, ctx.axis)
        cols: dict[str, Binding] = {}
        ar = xp.arange(D, dtype=np.int32)
        for g, d, stg in zip(a.group_by, a.domains, strides):
            b = f.cols[g]
            keyvals = (ar // np.int32(stg)) % np.int32(d)
            cols[g] = Binding(keyvals, b.kind, b.table, b.col)
        combine = f.part is not None
        for c in a.carry:
            b = f.cols[c]
            if b.arr.ndim == 2:
                data = xp.where(mask[:, None], b.arr, 0)
                carried = be.segment_max(data, idx, D, 0)
            else:
                if b.arr.dtype.kind == "f":
                    data = xp.where(mask, b.arr, -F32BIG)
                    # the cross-shard combine below is a pmax: the
                    # empty-slot fill must be max's identity, or a shard
                    # holding none of a group's rows would beat the real
                    # (negative) carry value with a 0
                    fill = np.float32(-F32BIG) if combine else np.float32(0)
                else:
                    data = xp.where(mask, b.arr, np.int32(-1)
                                    ).astype(b.arr.dtype)
                    fill = np.array(-1 if combine else 0, b.arr.dtype)
                carried = be.segment_max(data, idx, D, fill)
            if combine:
                # a group's rows may straddle shards; max-combining matches
                # the single-device carry-via-max semantics
                carried = be.pmax(carried, ctx.axis)
            cols[c] = Binding(carried, b.kind, b.table, b.col)
        sums, counts, mins, maxs = {}, {}, {}, {}
        for spec in a.aggs:
            if spec.fn in ("sum", "avg"):
                sums[spec.name] = (kernel_sums[spec.name]
                                   if kernel_sums is not None else
                                   be.segment_sum(
                                       xp.where(mask, vals[spec.name], 0),
                                       idx, D))
            if spec.fn in ("count", "avg"):
                counts[spec.name] = (kernel_counts.astype(np.int32)
                                     if kernel_counts is not None else
                                     be.segment_sum(mi32, idx, D))
            if spec.fn == "min":
                mins[spec.name] = be.segment_min(
                    xp.where(mask, vals[spec.name], F32BIG), idx, D, F32BIG)
            if spec.fn == "max":
                maxs[spec.name] = be.segment_max(
                    xp.where(mask, vals[spec.name], -F32BIG), idx, D,
                    -F32BIG)
        if f.part is not None and kernel_sums is None:
            # shard-local partials -> replicated totals, combined before
            # _finalize so avg divides global sum by global count
            sums = {k: be.psum(v, ctx.axis) for k, v in sums.items()}
            counts = {k: be.psum(v, ctx.axis) for k, v in counts.items()}
            mins = {k: be.pmin(v, ctx.axis) for k, v in mins.items()}
            maxs = {k: be.pmax(v, ctx.axis) for k, v in maxs.items()}
        for spec in a.aggs:
            cols[spec.name] = Binding(
                _finalize(spec, sums, counts, mins, maxs), "num")
        return ctx.barrier(Frame(cols, present > 0))

    # ---- generic sort-based grouping (the un-specialized hash map) ----
    if f.part is not None:
        from repro.core.analysis import PlanInvariantError

        raise PlanInvariantError(
            "shard-invariance",
            "generic (sort-based) aggregation over a partitioned frame "
            "would group each shard independently — needs a gather "
            "Exchange", node=a, pass_name="staging")
    sort_keys: list = []   # major..minor
    for g in a.group_by:
        b = f.cols[g]
        if b.arr.ndim == 2:
            sort_keys.extend([b.arr[:, k] for k in range(b.arr.shape[1])])
        else:
            sort_keys.append(b.arr)
    invalid = ~mask
    order = be.lexsort(list(reversed(sort_keys)) + [invalid])
    smask = be.take(mask, order)
    skeys = [be.take(k, order) for k in sort_keys]
    diff = None
    for k in skeys:
        d = xp.concatenate([xp.ones((1,), dtype=bool), k[1:] != k[:-1]])
        diff = d if diff is None else (diff | d)
    new_group = diff & smask
    flag2 = new_group | ~smask
    gid = xp.cumsum(flag2.astype(np.int32)) - 1
    n_groups = new_group.astype(np.int32).sum()
    ar = xp.arange(n, dtype=np.int32)
    starts = be.segment_min(ar, gid, n, np.int32(0))
    cols = {}
    for g in a.group_by + list(a.carry):
        b = f.cols[g]
        sorted_arr = be.take(b.arr, order)
        cols[g] = Binding(be.take(sorted_arr, starts), b.kind, b.table, b.col)
    sums, counts, mins, maxs = {}, {}, {}, {}
    smi32 = smask.astype(np.int32)
    for spec in a.aggs:
        sv = be.take(vals[spec.name], order) if spec.expr is not None else None
        if spec.fn in ("sum", "avg"):
            sums[spec.name] = be.segment_sum(xp.where(smask, sv, 0), gid, n)
        if spec.fn in ("count", "avg"):
            counts[spec.name] = be.segment_sum(smi32, gid, n)
        if spec.fn == "min":
            mins[spec.name] = be.segment_min(
                xp.where(smask, sv, F32BIG), gid, n, F32BIG)
        if spec.fn == "max":
            maxs[spec.name] = be.segment_max(
                xp.where(smask, sv, -F32BIG), gid, n, -F32BIG)
    for spec in a.aggs:
        cols[spec.name] = Binding(
            _finalize(spec, sums, counts, mins, maxs), "num")
    return ctx.barrier(Frame(cols, ar < n_groups))
