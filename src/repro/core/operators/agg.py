"""Aggregation: the three §3.2.2 strategies.

  scalar  — no group key: accumulators are scalar registers (optionally the
            fused filter+agg Pallas kernel);
  dense   — statically-known key domains: the hash map is a pre-allocated
            array indexed by a mixed-radix composite of the key codes;
  generic — sort-based grouping (the un-specialized hash map).
"""
from __future__ import annotations

import numpy as np

from repro.core import ir
from repro.core.expr import eval_expr
from repro.core.operators.base import (Binding, F32BIG, Frame, StageCtx,
                                       frame_nrows, ones_mask)


def stage(a: ir.Agg, ctx: StageCtx, defer: bool = False) -> Frame:
    be, xp = ctx.backend, ctx.xp
    f = ctx.stage(a.child)
    n = frame_nrows(f)
    env = ctx.env(f)
    mask = f.mask if f.mask is not None else ones_mask(xp, n)
    mi32 = mask.astype(np.int32)
    vals = {}
    for spec in a.aggs:
        if spec.expr is not None:
            vals[spec.name] = eval_expr(spec.expr, env)

    def _finalize(spec, sums, counts, mins, maxs):
        if spec.fn == "sum":
            return sums[spec.name]
        if spec.fn == "count":
            return counts[spec.name]
        if spec.fn == "avg":
            c = counts[spec.name]
            return sums[spec.name] / xp.maximum(c, 1).astype(np.float32)
        if spec.fn == "min":
            return mins[spec.name]
        if spec.fn == "max":
            return maxs[spec.name]
        raise ValueError(spec.fn)

    def _kernel_ok(D):
        return (ctx.settings.use_pallas and be.name == "jax" and D <= 4096
                and all(s_.fn in ("sum", "count", "avg") for s_ in a.aggs)
                and all(v.ndim == 1 for v in vals.values()))

    if a.strategy == "scalar" or not a.group_by:
        # (the 'scalar' annotation additionally enables kernel fusion;
        # functionally an empty group-by is always a single group)
        if _kernel_ok(1):
            from repro.kernels import ops as kops

            names = [s_.name for s_ in a.aggs if s_.expr is not None]
            sums_m, cnt = kops.filter_agg_query(
                mask, xp.zeros((n,), dtype=np.int32),
                [vals[nm].astype(np.float32) for nm in names], 1,
                interpret=ctx.settings.pallas_interpret)
            cols = {}
            for spec in a.aggs:
                if spec.fn == "sum":
                    v = sums_m[0:1, names.index(spec.name)]
                elif spec.fn == "count":
                    v = cnt[0:1].astype(np.int32)
                else:  # avg
                    v = (sums_m[0:1, names.index(spec.name)]
                         / xp.maximum(cnt[0:1], 1.0))
                cols[spec.name] = Binding(v, "num")
            return ctx.barrier(Frame(cols, None))
        cols = {}
        for spec in a.aggs:
            if spec.fn == "count":
                v = mi32.sum()[None]
            elif spec.fn == "sum":
                v = xp.where(mask, vals[spec.name], 0).sum()[None]
            elif spec.fn == "avg":
                sv = xp.where(mask, vals[spec.name], 0).sum()
                cv = mi32.sum()
                v = (sv / xp.maximum(cv, 1).astype(np.float32))[None]
            elif spec.fn == "min":
                v = xp.where(mask, vals[spec.name], F32BIG).min()[None]
            elif spec.fn == "max":
                v = xp.where(mask, vals[spec.name], -F32BIG).max()[None]
            cols[spec.name] = Binding(v, "num")
        return ctx.barrier(Frame(cols, None))

    if a.strategy == "dense":
        D = 1
        for d in a.domains:
            D *= d
        # mixed-radix composite index (strides baked at staging time)
        idx = None
        strides = []
        st = 1
        for d in reversed(a.domains):
            strides.append(st)
            st *= d
        strides = list(reversed(strides))
        for g, d, stg in zip(a.group_by, a.domains, strides):
            part = f.cols[g].arr.astype(np.int32) * np.int32(stg)
            idx = part if idx is None else idx + part
        idx = xp.clip(idx, 0, D - 1)
        kernel_sums = kernel_counts = None
        if _kernel_ok(D):
            from repro.kernels import ops as kops

            names = [s_.name for s_ in a.aggs if s_.expr is not None]
            sums_m, cnt = kops.filter_agg_query(
                mask, idx, [vals[nm].astype(np.float32) for nm in names], D,
                interpret=ctx.settings.pallas_interpret)
            kernel_sums = {nm: sums_m[:, i] for i, nm in enumerate(names)}
            kernel_counts = cnt
            present = (cnt > 0).astype(np.int32)
        else:
            present = be.segment_max(mi32, idx, D, 0)
        cols: dict[str, Binding] = {}
        ar = xp.arange(D, dtype=np.int32)
        for g, d, stg in zip(a.group_by, a.domains, strides):
            b = f.cols[g]
            keyvals = (ar // np.int32(stg)) % np.int32(d)
            cols[g] = Binding(keyvals, b.kind, b.table, b.col)
        for c in a.carry:
            b = f.cols[c]
            if b.arr.ndim == 2:
                data = xp.where(mask[:, None], b.arr, 0)
                cols[c] = Binding(be.segment_max(data, idx, D, 0),
                                  b.kind, b.table, b.col)
            else:
                if b.arr.dtype.kind == "f":
                    data = xp.where(mask, b.arr, -F32BIG)
                    fill = np.float32(0)
                else:
                    data = xp.where(mask, b.arr, np.int32(-1)
                                    ).astype(b.arr.dtype)
                    fill = np.array(0, b.arr.dtype)
                cols[c] = Binding(be.segment_max(data, idx, D, fill),
                                  b.kind, b.table, b.col)
        sums, counts, mins, maxs = {}, {}, {}, {}
        for spec in a.aggs:
            if spec.fn in ("sum", "avg"):
                sums[spec.name] = (kernel_sums[spec.name]
                                   if kernel_sums is not None else
                                   be.segment_sum(
                                       xp.where(mask, vals[spec.name], 0),
                                       idx, D))
            if spec.fn in ("count", "avg"):
                counts[spec.name] = (kernel_counts.astype(np.int32)
                                     if kernel_counts is not None else
                                     be.segment_sum(mi32, idx, D))
            if spec.fn == "min":
                mins[spec.name] = be.segment_min(
                    xp.where(mask, vals[spec.name], F32BIG), idx, D, F32BIG)
            if spec.fn == "max":
                maxs[spec.name] = be.segment_max(
                    xp.where(mask, vals[spec.name], -F32BIG), idx, D,
                    -F32BIG)
        for spec in a.aggs:
            cols[spec.name] = Binding(
                _finalize(spec, sums, counts, mins, maxs), "num")
        return ctx.barrier(Frame(cols, present > 0))

    # ---- generic sort-based grouping (the un-specialized hash map) ----
    sort_keys: list = []   # major..minor
    for g in a.group_by:
        b = f.cols[g]
        if b.arr.ndim == 2:
            sort_keys.extend([b.arr[:, k] for k in range(b.arr.shape[1])])
        else:
            sort_keys.append(b.arr)
    invalid = ~mask
    order = be.lexsort(list(reversed(sort_keys)) + [invalid])
    smask = be.take(mask, order)
    skeys = [be.take(k, order) for k in sort_keys]
    diff = None
    for k in skeys:
        d = xp.concatenate([xp.ones((1,), dtype=bool), k[1:] != k[:-1]])
        diff = d if diff is None else (diff | d)
    new_group = diff & smask
    flag2 = new_group | ~smask
    gid = xp.cumsum(flag2.astype(np.int32)) - 1
    n_groups = new_group.astype(np.int32).sum()
    ar = xp.arange(n, dtype=np.int32)
    starts = be.segment_min(ar, gid, n, np.int32(0))
    cols = {}
    for g in a.group_by + list(a.carry):
        b = f.cols[g]
        sorted_arr = be.take(b.arr, order)
        cols[g] = Binding(be.take(sorted_arr, starts), b.kind, b.table, b.col)
    sums, counts, mins, maxs = {}, {}, {}, {}
    smi32 = smask.astype(np.int32)
    for spec in a.aggs:
        sv = be.take(vals[spec.name], order) if spec.expr is not None else None
        if spec.fn in ("sum", "avg"):
            sums[spec.name] = be.segment_sum(xp.where(smask, sv, 0), gid, n)
        if spec.fn in ("count", "avg"):
            counts[spec.name] = be.segment_sum(smi32, gid, n)
        if spec.fn == "min":
            mins[spec.name] = be.segment_min(
                xp.where(smask, sv, F32BIG), gid, n, F32BIG)
        if spec.fn == "max":
            maxs[spec.name] = be.segment_max(
                xp.where(smask, sv, -F32BIG), gid, n, -F32BIG)
    for spec in a.aggs:
        cols[spec.name] = Binding(
            _finalize(spec, sums, counts, mins, maxs), "num")
    return ctx.barrier(Frame(cols, ar < n_groups))
