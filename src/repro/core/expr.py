"""Scalar expression IR + evaluator.

Expressions are immutable, structurally hashable dataclasses — structural
hashing gives us common-subexpression elimination (§3.6 / the motivating
example's shared ``1 - S.B``) for free: the staging evaluator memoizes on
the expression node within one evaluation context.

String operations exist in two families, mirroring the paper §3.4:

  high level  : StrEq / StrIn / StrStartsWith / StrContainsWord evaluate
                against fixed-width char matrices (strcmp-style byte loops —
                the *unoptimized* representation);
  lowered     : CodeEq / CodeIn / CodeRange / WordCode evaluate against
                int32 dictionary codes.  The StringDictionary pass rewrites
                the former into the latter using the (ordered) vocabularies.

The evaluator is backend-generic: `xp` is either numpy (Volcano baseline)
or jax.numpy (staged whole-query compilation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Union

Expr = Union[
    "Col", "Const", "Param", "Arith", "Cmp", "And", "Or", "Not",
    "StrEq", "StrIn", "StrStartsWith", "StrContainsWord",
    "CodeEq", "CodeIn", "CodeRange", "WordCode",
]


@dataclasses.dataclass(frozen=True)
class Col:
    name: str


@dataclasses.dataclass(frozen=True)
class Const:
    value: Any  # int | float | bool


@dataclasses.dataclass(frozen=True)
class Param:
    """A named query parameter (compile-once / bind-many execution).

    Numeric params (`dtype` in int32/int64/float32/float64/bool) are *runtime*
    parameters: the staged program receives them as scalar inputs, so a new
    binding re-executes the already-jitted XLA callable without re-staging.
    `dtype == "str"` params (and any Param used as `Limit.n`) are *compile
    time*: they must be substituted into the plan before optimization (the
    string-dictionary / top-k rewrites need the concrete value) and therefore
    participate in the plan-cache key.
    """
    name: str
    dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class Arith:
    op: str  # + - * /
    lhs: Expr
    rhs: Expr


@dataclasses.dataclass(frozen=True)
class Cmp:
    op: str  # < <= == != > >=
    lhs: Expr
    rhs: Expr


@dataclasses.dataclass(frozen=True)
class And:
    lhs: Expr
    rhs: Expr


@dataclasses.dataclass(frozen=True)
class Or:
    lhs: Expr
    rhs: Expr


@dataclasses.dataclass(frozen=True)
class Not:
    operand: Expr


@dataclasses.dataclass(frozen=True)
class Where:
    cond: Expr
    then: Expr
    other: Expr


@dataclasses.dataclass(frozen=True)
class Year:
    """Civil year from a days-since-epoch DATE column (vectorized
    Gregorian conversion, Hinnant's algorithm — pure integer ops)."""
    operand: Expr


# -- high-level string predicates (char-matrix evaluation) -------------------

@dataclasses.dataclass(frozen=True)
class StrEq:
    col: str
    value: "str | Param"   # Param here is compile-time (substituted pre-opt)
    negate: bool = False


@dataclasses.dataclass(frozen=True)
class StrIn:
    col: str
    values: "tuple[str | Param, ...]"


@dataclasses.dataclass(frozen=True)
class StrStartsWith:
    col: str
    prefix: "str | Param"


@dataclasses.dataclass(frozen=True)
class StrContainsWord:
    col: str
    word: "str | Param"
    negate: bool = False


# -- dictionary-lowered string predicates (§3.4, Table II) --------------------

@dataclasses.dataclass(frozen=True)
class CodeEq:
    col: str
    code: int
    negate: bool = False


@dataclasses.dataclass(frozen=True)
class CodeIn:
    col: str
    codes: tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class CodeRange:
    col: str
    lo: int
    hi: int


@dataclasses.dataclass(frozen=True)
class WordCode:
    col: str
    code: int
    negate: bool = False


# -- convenience builders -----------------------------------------------------

def col(name: str) -> Col:
    return Col(name)


def lit(v) -> Const:
    return Const(v)


_ARITH = {
    "+": lambda a, b: a + b,
    "-": lambda a, b: a - b,
    "*": lambda a, b: a * b,
    "/": lambda a, b: a / b,
}
_CMP = {
    "<": lambda a, b: a < b,
    "<=": lambda a, b: a <= b,
    "==": lambda a, b: a == b,
    "!=": lambda a, b: a != b,
    ">": lambda a, b: a > b,
    ">=": lambda a, b: a >= b,
}


class EvalEnv:
    """Column resolution + string metadata + optional CSE cache.

    `get_num(name)`   -> numeric array for a column
    `get_codes(name)` -> int32 dictionary codes
    `get_chars(name)` -> uint8[n, w] char matrix (CAT) for strcmp-style ops
    `get_words(name)` -> int32[n, W] word-code matrix (TEXT)
    `get_word_chars(name)` -> uint8[n, w] char matrix of the joined text
    `encode(name, s)`, `encode_word(name, s)`, `code_range(name, prefix)`
    """

    def __init__(self, xp, cse: bool = True, params: dict | None = None):
        self.xp = xp
        self.cache: dict | None = {} if cse else None
        self.params: dict = params or {}

    # subclasses implement the column accessors above.

    def get_param(self, p: "Param"):
        """Resolve a runtime parameter to a scalar (override to thread
        params through a staged program as traced inputs)."""
        if p.name not in self.params:
            raise KeyError(f"unbound query parameter {p.name!r}")
        import numpy as np

        v = self.params[p.name]
        if p.dtype == "str":
            raise TypeError(
                f"string parameter {p.name!r} must be bound at compile time")
        return np.asarray(v, dtype=p.dtype)


def eval_expr(e: Expr, env: EvalEnv):
    if env.cache is not None and e in env.cache:
        return env.cache[e]
    v = _eval(e, env)
    if env.cache is not None:
        env.cache[e] = v
    return v


def _bytes_const(s: str, width: int, xp):
    import numpy as np

    if isinstance(s, Param):
        raise TypeError(f"string parameter {s.name!r} must be bound "
                        "(substitute_params) before execution")
    b = np.zeros(width, dtype=np.uint8)
    raw = s.encode()[:width]
    b[: len(raw)] = np.frombuffer(raw, dtype=np.uint8)
    return b


def _eval(e: Expr, env: EvalEnv):
    xp = env.xp
    if isinstance(e, Col):
        return env.get_num(e.name)
    if isinstance(e, Const):
        return e.value
    if isinstance(e, Param):
        return env.get_param(e)
    if isinstance(e, Arith):
        return _ARITH[e.op](eval_expr(e.lhs, env), eval_expr(e.rhs, env))
    if isinstance(e, Cmp):
        return _CMP[e.op](eval_expr(e.lhs, env), eval_expr(e.rhs, env))
    if isinstance(e, And):
        return eval_expr(e.lhs, env) & eval_expr(e.rhs, env)
    if isinstance(e, Or):
        return eval_expr(e.lhs, env) | eval_expr(e.rhs, env)
    if isinstance(e, Not):
        return ~eval_expr(e.operand, env)
    if isinstance(e, Where):
        return xp.where(eval_expr(e.cond, env),
                        eval_expr(e.then, env), eval_expr(e.other, env))
    if isinstance(e, Year):
        z = eval_expr(e.operand, env) + 719468
        era = z // 146097
        doe = z - era * 146097
        yoe = (doe - doe // 1460 + doe // 36524 - doe // 146096) // 365
        y = yoe + era * 400
        doy = doe - (365 * yoe + yoe // 4 - yoe // 100)
        mp = (5 * doy + 2) // 153
        m = xp.where(mp < 10, mp + 3, mp - 9)
        return (y + (m <= 2)).astype("int32")

    # ---- char-matrix (unoptimized) string ops ------------------------------
    if isinstance(e, StrEq):
        chars = env.get_chars(e.col)
        const = _bytes_const(e.value, chars.shape[1], xp)
        eq = (chars == const[None, :]).all(axis=1)
        return ~eq if e.negate else eq
    if isinstance(e, StrIn):
        chars = env.get_chars(e.col)
        acc = None
        for v in e.values:
            const = _bytes_const(v, chars.shape[1], xp)
            eq = (chars == const[None, :]).all(axis=1)
            acc = eq if acc is None else (acc | eq)
        return acc
    if isinstance(e, StrStartsWith):
        chars = env.get_chars(e.col)
        k = len(e.prefix.encode())
        const = _bytes_const(e.prefix, k, xp)
        return (chars[:, :k] == const[None, :]).all(axis=1)
    if isinstance(e, StrContainsWord):
        # strstr: sliding-window byte comparison over the joined text —
        # deliberately the expensive path the paper attributes to strstr.
        chars = env.get_word_chars(e.col)
        pat = e.word.encode()
        k = len(pat)
        const = _bytes_const(e.word, k, xp)
        n, w = chars.shape
        hit = None
        for off in range(0, max(1, w - k + 1)):
            m = (chars[:, off:off + k] == const[None, :]).all(axis=1)
            hit = m if hit is None else (hit | m)
        return ~hit if e.negate else hit

    # ---- dictionary-lowered string ops (Table II) ---------------------------
    if isinstance(e, CodeEq):
        codes = env.get_codes(e.col)
        eq = codes == e.code
        return ~eq if e.negate else eq
    if isinstance(e, CodeIn):
        codes = env.get_codes(e.col)
        acc = None
        for c in e.codes:
            eq = codes == c
            acc = eq if acc is None else (acc | eq)
        return acc
    if isinstance(e, CodeRange):
        codes = env.get_codes(e.col)
        return (codes >= e.lo) & (codes < e.hi)
    if isinstance(e, WordCode):
        words = env.get_words(e.col)
        hit = (words == e.code).any(axis=1)
        return ~hit if e.negate else hit

    raise TypeError(f"unknown expr {type(e)}")


def expr_columns(e: Expr) -> set[str]:
    """All column names referenced by an expression."""
    out: set[str] = set()

    def rec(x):
        if isinstance(x, Col):
            out.add(x.name)
        elif isinstance(x, (Arith, Cmp, And, Or)):
            rec(x.lhs), rec(x.rhs)
        elif isinstance(x, (Not, Year)):
            rec(x.operand)
        elif isinstance(x, Where):
            rec(x.cond), rec(x.then), rec(x.other)
        elif isinstance(x, (StrEq, StrIn, StrStartsWith, StrContainsWord,
                            CodeEq, CodeIn, CodeRange, WordCode)):
            out.add(x.col)

    rec(e)
    return out


def fold_constants(e: Expr) -> Expr:
    """Partial evaluation (§3.6): fold Arith/Cmp/bool over Consts."""
    if isinstance(e, Arith):
        l, r = fold_constants(e.lhs), fold_constants(e.rhs)
        if isinstance(l, Const) and isinstance(r, Const):
            return Const(_ARITH[e.op](l.value, r.value))
        return Arith(e.op, l, r)
    if isinstance(e, Cmp):
        l, r = fold_constants(e.lhs), fold_constants(e.rhs)
        if isinstance(l, Const) and isinstance(r, Const):
            return Const(bool(_CMP[e.op](l.value, r.value)))
        return Cmp(e.op, l, r)
    if isinstance(e, And):
        l, r = fold_constants(e.lhs), fold_constants(e.rhs)
        if isinstance(l, Const):
            return r if l.value else Const(False)
        if isinstance(r, Const):
            return l if r.value else Const(False)
        return And(l, r)
    if isinstance(e, Or):
        l, r = fold_constants(e.lhs), fold_constants(e.rhs)
        if isinstance(l, Const):
            return Const(True) if l.value else r
        if isinstance(r, Const):
            return Const(True) if r.value else l
        return Or(l, r)
    if isinstance(e, Not):
        x = fold_constants(e.operand)
        if isinstance(x, Const):
            return Const(not x.value)
        return Not(x)
    if isinstance(e, Where):
        c = fold_constants(e.cond)
        t, o = fold_constants(e.then), fold_constants(e.other)
        if isinstance(c, Const):
            return t if c.value else o
        return Where(c, t, o)
    if isinstance(e, Year):
        return Year(fold_constants(e.operand))
    return e


def substitute_params(e: Expr, bindings: dict) -> Expr:
    """Replace Params named in `bindings` with Consts / literal strings.
    Params absent from `bindings` are left in place (param-residual)."""

    def val(p):
        return bindings[p.name] if isinstance(p, Param) and p.name in bindings \
            else p

    sub = lambda x: substitute_params(x, bindings)
    if isinstance(e, Param):
        return Const(bindings[e.name]) if e.name in bindings else e
    if isinstance(e, (Arith, Cmp)):
        return type(e)(e.op, sub(e.lhs), sub(e.rhs))
    if isinstance(e, (And, Or)):
        return type(e)(sub(e.lhs), sub(e.rhs))
    if isinstance(e, Not):
        return Not(sub(e.operand))
    if isinstance(e, Year):
        return Year(sub(e.operand))
    if isinstance(e, Where):
        return Where(sub(e.cond), sub(e.then), sub(e.other))
    if isinstance(e, StrEq):
        return StrEq(e.col, val(e.value), e.negate)
    if isinstance(e, StrIn):
        return StrIn(e.col, tuple(val(v) for v in e.values))
    if isinstance(e, StrStartsWith):
        return StrStartsWith(e.col, val(e.prefix))
    if isinstance(e, StrContainsWord):
        return StrContainsWord(e.col, val(e.word), e.negate)
    return e


def conjuncts(e: Expr) -> list[Expr]:
    if isinstance(e, And):
        return conjuncts(e.lhs) + conjuncts(e.rhs)
    return [e]


def conjoin(parts: list[Expr]) -> Expr:
    if not parts:
        return Const(True)
    out = parts[0]
    for p in parts[1:]:
        out = And(out, p)
    return out
