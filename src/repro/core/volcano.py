"""Interpreted, operator-at-a-time baseline engine (the "DBX" rung).

Executes the *logical* plan directly on numpy: every operator fully
materializes its (compacted) output before the next one runs, strings are
raw fixed-width char matrices compared strcmp-style, joins build generic
associative structures, aggregations group generically — no compilation, no
specialization, no query-specific knowledge.  Deliberately the world the
paper's Figure 1 puts at the productive-but-slow corner.

It is also the correctness oracle for the staged engine (independent code
path, compaction instead of masking), and — wrapped in `OracleQuery` —
the zero-compile-cost bottom rung of the execution-tier ladder
(`core/tiering.py`): a cold plan is servable the instant it exists, at
interpreter speed, while the compiled tiers build in the background.
"""
from __future__ import annotations

import threading
from typing import Optional

import numpy as np

from repro.core import ir
from repro.core.expr import EvalEnv, eval_expr
from repro.relational.loader import Database
from repro.relational.schema import ColKind

_BIG = np.float32(3.0e38)


def _decode_chars(mat: np.ndarray) -> np.ndarray:
    if mat.size == 0:
        return np.zeros((mat.shape[0],), dtype="U1")
    w = mat.shape[1]
    b = np.ascontiguousarray(mat).view(f"S{w}")[:, 0]
    return np.char.decode(np.char.rstrip(b, b"\x00"), "ascii").astype(str)


class _Env(EvalEnv):
    """Columns are numpy arrays; strings resolved through char matrices."""

    def __init__(self, cols: dict[str, np.ndarray],
                 chars: dict[str, np.ndarray],
                 params: dict | None = None):
        super().__init__(np, cse=False, params=params)  # baseline: no CSE
        self.cols = cols
        self.chars = chars

    def get_num(self, name):
        return self.cols[name]

    def get_chars(self, name):
        return self.chars[name]

    def get_word_chars(self, name):
        return self.chars[name]

    def get_codes(self, name):  # pragma: no cover - volcano never lowers
        raise RuntimeError("volcano engine has no dictionary codes")

    get_words = get_codes


class Relation:
    """Materialized intermediate: numeric columns + char matrices."""

    def __init__(self, cols: dict[str, np.ndarray],
                 chars: dict[str, np.ndarray]):
        self.cols = cols
        self.chars = chars

    @property
    def nrows(self) -> int:
        src = self.cols or self.chars
        return len(next(iter(src.values())))

    def take(self, idx) -> "Relation":
        return Relation({k: v[idx] for k, v in self.cols.items()},
                        {k: v[idx] for k, v in self.chars.items()})

    def env(self, params: dict | None = None) -> _Env:
        return _Env(self.cols, self.chars, params)

    def key_for_sort(self, name: str, asc: bool) -> np.ndarray:
        if name in self.cols:
            v = self.cols[name]
            return v if asc else -v
        s = _decode_chars(self.chars[name])
        if not asc:
            raise NotImplementedError("descending string sort")
        return s


class VolcanoEngine:
    def __init__(self, db: Database):
        self.db = db

    def execute(self, plan: ir.Plan,
                params: dict | None = None) -> dict[str, np.ndarray]:
        params = dict(params or {})
        if params:
            # compile-time params (string values, Limit.n) have no runtime
            # representation even in the oracle: substitute them up front.
            # Numeric params evaluate through the expression environment.
            # (params travel as an explicit argument so one engine stays
            # reentrant across concurrent execute calls.)
            from repro.core.passes.param_binding import bind_plan, plan_params

            import copy

            structural = {n: params[n]
                          for n, i in plan_params(plan).items()
                          if i.structural and n in params}
            if structural:
                plan = bind_plan(copy.deepcopy(plan), structural)
        rel = self._exec(plan, params)
        out = dict(rel.cols)
        for name, mat in rel.chars.items():
            out[name] = _decode_chars(mat)
        return out

    # ------------------------------------------------------------------
    def _exec(self, p: ir.Plan, params: dict) -> Relation:
        if isinstance(p, ir.Scan):
            t = self.db.table(p.table)
            cols, chars = {}, {}
            names = p.columns if p.columns is not None else t.schema.column_names
            for c in names:
                kind = t.schema.col(c).kind
                if kind in (ColKind.INT, ColKind.FLOAT, ColKind.DATE):
                    cols[c] = t.data[c]
                else:
                    chars[c] = t.char_matrix(c)
            return Relation(cols, chars)

        if isinstance(p, ir.Select):
            rel = self._exec(p.child, params)
            m = eval_expr(p.pred, rel.env(params))
            return rel.take(np.flatnonzero(m))

        if isinstance(p, ir.Project):
            rel = self._exec(p.child, params)
            cols = dict(rel.cols) if p.keep_input else {}
            chars = dict(rel.chars) if p.keep_input else {}
            env = rel.env(params)
            for name, e in p.outputs.items():
                from repro.core.expr import Col
                if isinstance(e, Col) and e.name in rel.chars:
                    chars[name] = rel.chars[e.name]
                else:
                    cols[name] = np.asarray(eval_expr(e, env))
            return Relation(cols, chars)

        if isinstance(p, ir.Join):
            stream = self._exec(p.stream, params)
            build = self._exec(p.build, params)
            skey = stream.cols[p.stream_key]
            bkey = build.cols[p.build_key]
            if p.stream_key2 is not None:   # composite key: pack into int64
                mul = np.int64(max(int(build.cols[p.build_key2].max(initial=0)),
                                   int(stream.cols[p.stream_key2].max(initial=0))
                                   ) + 1)
                skey = skey.astype(np.int64) * mul \
                    + stream.cols[p.stream_key2].astype(np.int64)
                bkey = bkey.astype(np.int64) * mul \
                    + build.cols[p.build_key2].astype(np.int64)
            if p.kind in ("semi", "anti"):
                hit = np.isin(skey, bkey)
                if p.kind == "anti":
                    hit = ~hit
                return stream.take(np.flatnonzero(hit))
            order = np.argsort(bkey, kind="stable")
            sk = bkey[order]
            pos = np.searchsorted(sk, skey)
            pos = np.clip(pos, 0, max(len(sk) - 1, 0))
            hit = (sk[pos] == skey) if len(sk) else np.zeros(len(skey), bool)
            if p.kind == "left":
                out = stream.take(np.arange(stream.nrows))
                bidx = order[pos] if len(sk) else np.zeros(len(skey), int)
                for name, v in build.cols.items():
                    if name not in out.cols:
                        out.cols[name] = np.where(hit, v[bidx], 0)
                return out
            sel = np.flatnonzero(hit)
            bidx = order[pos[sel]]
            out = stream.take(sel)
            for name, v in build.cols.items():
                if name not in out.cols:
                    out.cols[name] = v[bidx]
            for name, v in build.chars.items():
                if name not in out.chars:
                    out.chars[name] = v[bidx]
            return out

        if isinstance(p, ir.Agg):
            rel = self._exec(p.child, params)
            env = rel.env(params)
            n = rel.nrows
            if not p.group_by:
                cols = {}
                for spec in p.aggs:
                    v = (np.asarray(eval_expr(spec.expr, env))
                         if spec.expr is not None else None)
                    cols[spec.name] = np.array([_scalar_agg(spec.fn, v, n)],
                                               dtype=np.float32
                                               if spec.fn != "count"
                                               else np.int32)
                return Relation(cols, {})
            # generic grouping via lexsort over the (decoded) key columns
            keyarrs = []
            for g in p.group_by:
                if g in rel.cols:
                    keyarrs.append(rel.cols[g])
                else:
                    keyarrs.append(_decode_chars(rel.chars[g]))
            order = np.lexsort(tuple(reversed(keyarrs)))
            skeys = [k[order] for k in keyarrs]
            if n == 0:
                newg = np.zeros(0, dtype=bool)
            else:
                newg = np.ones(n, dtype=bool)
                acc = np.zeros(n - 1, dtype=bool)
                for k in skeys:
                    acc |= k[1:] != k[:-1]
                newg[1:] = acc
            starts = np.flatnonzero(newg)
            gid = np.cumsum(newg) - 1
            ngroups = len(starts)
            out_cols, out_chars = {}, {}
            for g in p.group_by + list(p.carry):
                if g in rel.cols:
                    out_cols[g] = rel.cols[g][order][starts]
                else:
                    out_chars[g] = rel.chars[g][order][starts]
            for spec in p.aggs:
                if spec.expr is not None:
                    v = np.asarray(eval_expr(spec.expr, env))[order]
                if spec.fn == "count":
                    out_cols[spec.name] = np.bincount(
                        gid, minlength=ngroups).astype(np.int32)
                elif spec.fn == "sum":
                    out_cols[spec.name] = np.add.reduceat(v, starts).astype(
                        v.dtype) if n else np.zeros(0, np.float32)
                elif spec.fn == "avg":
                    s = np.add.reduceat(v, starts)
                    c = np.bincount(gid, minlength=ngroups)
                    out_cols[spec.name] = (s / np.maximum(c, 1)).astype(np.float32)
                elif spec.fn == "min":
                    out_cols[spec.name] = np.minimum.reduceat(v, starts)
                elif spec.fn == "max":
                    out_cols[spec.name] = np.maximum.reduceat(v, starts)
            return Relation(out_cols, out_chars)

        if isinstance(p, ir.Compact):
            # the Volcano engine materializes compacted intermediates at
            # every operator already: a planned compaction point is a no-op
            # (capacity is a staged-engine static-shape concern)
            return self._exec(p.child, params)

        if isinstance(p, ir.Exchange):
            # single-interpreter execution holds the whole frame: a shard
            # boundary is a no-op, same reasoning as Compact above
            return self._exec(p.child, params)

        if isinstance(p, ir.Sort):
            rel = self._exec(p.child, params)
            keys = [rel.key_for_sort(name, asc) for name, asc in p.keys]
            order = np.lexsort(tuple(reversed(keys)))
            return rel.take(order)

        if isinstance(p, ir.Limit):
            rel = self._exec(p.child, params)
            n = p.n
            if not isinstance(n, (int, np.integer)):   # residual Param limit
                n = int(params[n.name])
            return rel.take(np.arange(min(n, rel.nrows)))

        raise TypeError(type(p))


class OracleQuery:
    """The Volcano engine behind the `CompiledQuery` contract (a
    `tiering.Runnable`): `run`/`run_many` with identical binding
    validation, plus the staged-outputs observation surface (all empty —
    the interpreter compacts by materializing, so it has no capacity
    points, overflows, or traces to report).  Construction performs no
    staging and no compilation: this is the tier ladder's always-ready
    bottom rung, built once per cold plan shape by the tiered PlanCache.

    The plan must have compile-time (structural) parameters already
    substituted, exactly like CompiledQuery — `PlanCache._prepare` does
    that for both."""

    tier_name = "oracle"
    # PlanCache.run_many accounting: this tier executes slot-at-a-time,
    # so power-of-two bucket padding never happens and pad slots must not
    # be counted against it.
    pads_batches = False

    def __init__(self, plan: ir.Plan, db: Database,
                 params: Optional[dict] = None):
        from repro.core.passes.param_binding import plan_params

        self.db = db
        self.plan = plan
        spec = plan_params(plan)
        structural = sorted(n for n, i in spec.items() if i.structural)
        if structural:
            raise TypeError(
                f"compile-time parameters {structural} are unresolved; "
                "bind them via PlanCache or bind_plan before OracleQuery")
        self.param_spec: dict[str, str] = {n: i.dtype
                                           for n, i in spec.items()}
        self.param_defaults = {n: (params or {})[n] for n in self.param_spec
                               if n in (params or {})}
        missing = sorted(set(self.param_spec) - set(self.param_defaults))
        if missing:
            raise KeyError(f"no binding supplied for parameters {missing}")
        self._engine = VolcanoEngine(db)
        # staged-outputs contract, vacuously satisfied: zero compaction /
        # measure points, nothing to overflow, no traces.  PlanCache's
        # compaction accounting and feedback harvesting read these and
        # skip the tier naturally (no isinstance checks anywhere).
        self.compaction_points = 0
        self.measure_points = 0
        self.capacities: tuple = ()
        self.point_caps: dict[str, int] = {}
        self.translate_points: set[str] = set()
        self.n_overflows = 0
        self.n_traces = 0
        self.n_batch_traces = 0
        self.n_executions = 0
        self.pass_time = 0.0
        self.stage_time = 0.0
        self._obs_lock = threading.Lock()
        self.observed_max: dict[str, int] = {}
        self.observed_shard: dict[str, np.ndarray] = {}
        self.under_streak = 0
        self.streak_max: dict[str, int] = {}
        self._cache_key: Optional[tuple] = None

    def _check_bindings(self, params: Optional[dict]) -> dict:
        """Same semantics as CompiledQuery._check_bindings: None means the
        construction-time defaults; a dict must name every runtime
        parameter (a partial dict would silently mix two requests)."""
        if params is None:
            return self.param_defaults
        unknown = sorted(set(params) - set(self.param_spec))
        if unknown:
            raise KeyError(f"unknown parameters {unknown}; this plan "
                           f"takes {sorted(self.param_spec)}")
        missing = sorted(set(self.param_spec) - set(params))
        if missing:
            raise KeyError(f"no binding supplied for parameters "
                           f"{missing}")
        return params

    def run(self, params: Optional[dict] = None) -> dict[str, np.ndarray]:
        bound = self._check_bindings(params)
        self.n_executions += 1
        return self._engine.execute(self.plan, bound)

    def run_many(self, bindings_list) -> list[dict[str, np.ndarray]]:
        """One interpreted execution per binding (no vmap at this tier);
        validates every binding up front so a bad one fails the call
        before any slot executes, like the batched staged program."""
        bound = [self._check_bindings(b) for b in bindings_list]
        return [self.run(b if b is not self.param_defaults else None)
                for b in bound]


def _scalar_agg(fn: str, v, n: int):
    if fn == "count":
        return n
    if n == 0:
        return 0.0
    if fn == "sum":
        return v.sum()
    if fn == "avg":
        return v.mean()
    if fn == "min":
        return v.min()
    if fn == "max":
        return v.max()
    raise ValueError(fn)
