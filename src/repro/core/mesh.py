"""Small 1-D data mesh for sharded query execution.

`launch/mesh.py` builds the production 2-D (data, model) meshes and
insists on 256/512-device slices; query sharding needs the opposite — a
tiny 1-D mesh over however many devices this host actually has (CPU CI
simulates them with `XLA_FLAGS=--xla_force_host_platform_device_count=N`,
which must be set before the first jax import — see tests/conftest.py).

`Settings.shards` semantics: 1 = single-device (no mesh, no shard_map),
0 = auto (every local device), n>1 = exactly n devices (error when the
host has fewer — silently running a different mesh shape would silently
change the plan-cache key and the per-shard capacities).
"""
from __future__ import annotations

import numpy as np

_MESHES: dict[int, object] = {}

AXIS = "data"


def resolve_shards(settings) -> int:
    """Concrete shard count for `settings` (0 = all local devices)."""
    n = int(getattr(settings, "shards", 1) or 0)
    if n == 1:
        return 1
    import jax

    avail = len(jax.devices())
    if n == 0:
        return avail
    if n > avail:
        raise ValueError(
            f"settings.shards={n} but only {avail} devices are visible "
            f"(set XLA_FLAGS=--xla_force_host_platform_device_count=… "
            f"before importing jax to simulate more on CPU)")
    return n


def data_mesh(n: int):
    """1-D mesh over the first `n` local devices, axis name 'data'."""
    got = _MESHES.get(n)
    if got is not None:
        return got
    import jax
    from jax.sharding import Mesh

    devs = jax.devices()
    if n > len(devs):
        raise ValueError(f"need {n} devices, have {len(devs)}")
    mesh = Mesh(np.array(devs[:n]), (AXIS,))
    _MESHES[n] = mesh
    return mesh


def shard_map_fn(fn, mesh, in_specs, out_specs, check_rep=False):
    """Version-tolerant shard_map wrapper (jax.shard_map moved out of
    experimental after 0.4.x)."""
    try:
        from jax import shard_map as _sm  # jax >= 0.5
    except ImportError:
        from jax.experimental.shard_map import shard_map as _sm
    try:
        return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=check_rep)
    except TypeError:  # newer jax dropped/renamed check_rep
        return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
