"""Parameterized plan cache: compile once, bind many (runtime layer).

`CompiledQuery` pays pass-pipeline + staging + XLA JIT on every
construction; for a query server that cost must be amortized across
executions the way Dashti et al. amortize PL/SQL compilation.  The cache
key is

    (canonicalized plan structure, engine settings, database identity,
     planned compaction capacities)

where "canonicalized plan structure" is the repr of the *logical* plan
after compile-time parameters (string values, Limit.n) have been
substituted — so two requests for the same plan shape share one staged
program, while requests differing in a compile-time value are distinct
entries.  Runtime (numeric) parameters never enter the key: the hit path
re-binds them into the already-jitted XLA callable (`CompiledQuery.run`),
dropping repeated-query latency from full-JIT cost to bind+execute cost.

Two modes:

  residual   (default) — numeric params stay runtime inputs; one cache
             entry serves every binding.
  specialize — all params are baked in as literals (the paper's fully
             specialized program); each distinct binding is its own entry.

Tiered mode (`PlanCache(..., tiered=True)`, docs §11) changes what a
cold request costs: `get_tiered` returns the best *ready* rung of the
execution-tier ladder immediately — on a stone-cold shape that is the
Volcano oracle, constructed in microseconds — while a bounded background
thread compiles the target tier and hot-swaps the entry.  Promotion is
deduplicated per key, a failed target compile falls back (typed, sticky)
to the ready tier, and `CacheStats.tier_hits/promotions` expose the
climb.  `save`/`load` persist the feedback store + warm metadata
(`core/persist.py`) so a restarted process re-plans nothing.
"""
from __future__ import annotations

import copy
import dataclasses
import threading
import weakref
from collections import OrderedDict
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Optional

import numpy as np

from repro.core import compile as compile_mod
from repro.core import ir
from repro.core import persist as persist_mod
from repro.core import tiering
from repro.core.compile import CompiledQuery
from repro.core.passes.compaction import observed_bucket
from repro.core.passes.param_binding import bind_plan, plan_params
from repro.core.passes.pipeline import Settings, optimize
from repro.core.volcano import OracleQuery


def _mesh_size(settings: Settings) -> int:
    """Resolved data-mesh size for the cache key.  `astuple(settings)`
    already carries the raw `shards` field, but `shards=0` means "all
    visible devices" — two processes (or one process whose device
    visibility changed) must not share an entry staged for a different
    mesh, so the key carries the *resolved* count.  `resolve_shards`
    returns 1 without importing jax when sharding is off, keeping the
    unsharded path jax-free at keying time."""
    from repro.core.mesh import resolve_shards
    return resolve_shards(settings)


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    compiles: int = 0     # CompiledQuery constructions (stagings + JITs)
    evictions: int = 0
    # batched execution (`execute_many`): each cache entry carries the
    # scalar AND the vmapped callable; the vmapped one retraces once per
    # power-of-two bucket size, and padding fills the bucket by repeating
    # the last binding.
    batch_traces: int = 0   # vmapped retraces across all entries
    padded_slots: int = 0   # pad slots executed (bucket size - batch size)
    # selection-vector compaction (passes/compaction.py): executions that
    # ran through a compacted plan, and those whose capacity bucket
    # overflowed at runtime (re-executed via the uncompacted twin).
    compactions: int = 0
    overflows: int = 0
    # adaptive capacity feedback: entries re-planned with capacities
    # derived from observed max counts (after `compact_replan_after`
    # overflows) and entries shrunk to the measured bucket (after
    # `compact_shrink_after` consecutive large underuses).
    replans: int = 0
    shrinks: int = 0
    # serving degradation (serve/query_server.py's ladder): requests
    # prepared against degraded (mask-only, `pipeline.degrade`) settings.
    # Degraded settings key distinct cache entries, so a degraded rung
    # never evicts or pollutes the full-fidelity entry for the same plan.
    degraded: int = 0
    # execution tiering (core/tiering.py, tiered mode only): requests
    # served per ladder rung, background hot-swaps to a higher tier, and
    # promotions that failed (the entry stayed on its ready tier).
    tier_hits: dict = dataclasses.field(default_factory=dict)
    promotions: int = 0
    promote_failures: int = 0
    # feedback records restored from a persisted warm state (persist.py)
    restored: int = 0


@dataclasses.dataclass
class _Feedback:
    """Per-plan-shape runtime observations (keyed by the cache key's base
    — canonical plan + settings + db fingerprint — so every capacity
    generation of one shape shares a single history)."""
    est_params: dict                       # first-seen runtime bindings
    observed: dict = dataclasses.field(default_factory=dict)  # pid -> max
    overrides: Optional[dict] = None       # pid -> count fed to the pass
    overflows: int = 0                     # since the last re-plan
    replans: int = 0
    shrinks: int = 0
    # pid -> per-shard max-count vector (np.ndarray of len n_shards),
    # harvested from sharded entries.  Reporting surface only (benchmarks
    # read it to chart skew); capacity planning keys on the scalar
    # `observed` max, which bounds every shard by construction.
    observed_shard: dict = dataclasses.field(default_factory=dict)
    # capacity generation: bumped by every re-plan/shrink transition so a
    # signature computed against pre-transition overrides (optimize runs
    # outside the lock) can never be memoized after the transition
    gen: int = 0


@dataclasses.dataclass
class _LadderState:
    """Per-cold-plan-key promotion state (tiered mode).  `ready` maps
    tier name -> Runnable, always containing at least the oracle; `plan`
    is a pristine structurally-bound logical plan the promoter compiles
    from (each compile deep-copies it — passes mutate plans)."""
    plan: ir.Plan
    runtime: dict
    ladder: tiering.TierLadder
    ready: dict = dataclasses.field(default_factory=dict)
    promoting: bool = False
    failure: Optional[BaseException] = None     # sticky: promotion gave up
    done: threading.Event = dataclasses.field(
        default_factory=threading.Event)

    def best(self) -> tiering.Runnable:
        return self.ready[max(self.ready,
                              key=lambda n: tiering.tier(n).rank)]


class PlanCache:
    def __init__(self, db, max_entries: int = 128, *,
                 tiered: bool = False, promote_through: bool = False,
                 promote_workers: int = 1):
        self.db = db
        self.max_entries = max_entries
        self.stats = CacheStats()
        # execution tiering (docs §11): serve the best ready rung, climb
        # in the background.  `promote_through` climbs rung-by-rung (an
        # interpret-tier program lands before the full compile) at the
        # cost of one extra compile; default is straight to the target.
        self.tiered = tiered
        self.promote_through = promote_through
        self._promote_workers = max(1, promote_workers)
        self._promoter: Optional[ThreadPoolExecutor] = None
        self._ladders: dict[tuple, _LadderState] = {}
        # persisted warm metadata (persist.load_warm_state): key bases
        # that had a compiled entry when the state was saved.  `is_warm`
        # lets a restarted server prioritize known-hot shapes.
        self._warm_hints: set[tuple] = set()
        self._entries: "OrderedDict[tuple, CompiledQuery]" = OrderedDict()
        # last-observed n_batch_traces / n_overflows per live entry (weak:
        # evicted entries must not pin their compiled programs in memory)
        self._batch_trace_seen: "weakref.WeakKeyDictionary[CompiledQuery, int]" \
            = weakref.WeakKeyDictionary()
        self._overflow_seen: "weakref.WeakKeyDictionary[CompiledQuery, int]" \
            = weakref.WeakKeyDictionary()
        self._caps_memo: dict[tuple, tuple] = {}
        # per-plan-shape feedback: observed counts, override state, and
        # the initial-estimate bindings.  Keyed by the key base, which
        # includes db.fingerprint — a reloaded database starts fresh.
        self._feedback: dict[tuple, _Feedback] = {}
        self._lock = threading.RLock()

    # -- keying ----------------------------------------------------------------
    def _prepare(self, plan: ir.Plan, settings: Settings,
                 bindings: Optional[dict], mode: str):
        """(key, plan, runtime bindings, plan_owned) for a request.

        Bindings are validated here so cache hits and misses behave
        identically: every request must name exactly the plan's parameters
        — a missing or misspelled binding raises whether or not the entry
        is already warm (a warm entry must never silently fall back to the
        first request's values).  `plan_owned` is True when `plan` is a
        private copy safe to hand to CompiledQuery (whose passes mutate it).
        """
        if mode not in ("residual", "specialize"):
            raise ValueError(f"unknown mode {mode!r}")
        bindings = dict(bindings or {})
        spec = plan_params(plan)
        unknown = sorted(set(bindings) - set(spec))
        if unknown:
            raise KeyError(f"unknown parameters {unknown}; this plan takes "
                           f"{sorted(spec)}")
        missing = sorted(set(spec) - set(bindings))
        if missing:
            raise KeyError(f"no binding supplied for parameters {missing}")
        baked = set(spec) if mode == "specialize" else \
            {n for n, i in spec.items() if i.structural}
        owned = False
        if baked:
            # substitution mutates expression slots: work on a copy
            plan = bind_plan(copy.deepcopy(plan),
                             {n: bindings[n] for n in baked})
            owned = True
        runtime = {n: v for n, v in bindings.items() if n not in baked}
        # dataclass reprs are recursive and deterministic: they canonicalize
        # the full plan structure including substituted literals.  The db
        # component is the Database's monotonic fingerprint, NOT id(db):
        # ids are reused after GC, and a reused address would hand a new
        # database a stale entry compiled against dead data.  The final
        # component is the capacity vector the Compaction pass plants for
        # this plan — the entry's static shapes, made explicit so capacity
        # planning can never alias two entries compiled under different
        # buckets and each bucket retraces at most once (mirroring PR 3's
        # batch buckets).  Computing it runs the pass pipeline on a throw-
        # away copy; the memo keys it on the other components, so only the
        # first request for a plan shape pays and warm hits stay walk-free.
        base = (repr(plan), dataclasses.astuple(settings),
                self.db.fingerprint, _mesh_size(settings))
        caps = self._capacity_signature(base, plan, settings, runtime)
        return base + (caps,), plan, runtime, owned

    def _feedback_for(self, base: tuple, runtime: dict) -> _Feedback:
        """The plan shape's feedback record, created on first sight with
        that request's runtime bindings as the initial-estimate values.
        The base includes db.fingerprint, so a reloaded database can
        never inherit another's observations or estimates."""
        with self._lock:
            fb = self._feedback.get(base)
            if fb is None:
                if len(self._feedback) >= 4 * self.max_entries:
                    # the memoized signatures were computed under the
                    # records being dropped: clear them in tandem, or a
                    # surviving memo would key learned capacities while
                    # compiles see a fresh (override-free) record
                    self._feedback.clear()
                    self._caps_memo.clear()
                fb = self._feedback[base] = _Feedback(
                    est_params=dict(runtime))
            return fb

    def _capacity_signature(self, base: tuple, plan: ir.Plan,
                            settings: Settings, runtime: dict) -> tuple:
        """The capacity vector keyed into the plan key, memoized per base
        as `(caps, est_params, overrides)` — the estimation snapshot the
        vector was computed under, which `_get_prepared` reuses so the
        compiled entry's capacities always equal its key's signature.
        The pass pipeline runs outside the lock; the generation check
        prevents a computation that raced a re-plan/shrink transition
        from memoizing a stale vector over the transition's pop."""
        if not settings.compaction:
            return ()
        # warm path: one lock round-trip, no feedback-record touch
        with self._lock:
            memo = self._caps_memo.get(base)
        if memo is not None:
            return memo[0]
        while True:
            # re-fetched every iteration: the feedback store's wholesale
            # eviction can drop (and a later request re-create) this
            # base's record while optimize() runs outside the lock — a
            # stale `fb` would fail the identity check below forever
            fb = self._feedback_for(base, runtime)
            with self._lock:
                memo = self._caps_memo.get(base)
                if memo is not None:
                    return memo[0]
                gen = fb.gen
                est = dict(fb.est_params)
                overrides = None if fb.overrides is None \
                    else dict(fb.overrides)
            try:
                lowered = optimize(copy.deepcopy(plan), self.db, settings,
                                   est_params=est, observed=overrides)
                caps = tuple(n.capacity for n in ir.walk(lowered)
                             if isinstance(n, ir.Compact))
            except KeyError:
                # keyed against a database missing the plan's tables (can
                # never compile); () keeps key_for usable for identity
                # checks
                caps = ()
            with self._lock:
                if self._feedback.get(base) is not fb or fb.gen != gen:
                    continue    # transition raced us: recompute
                if len(self._caps_memo) >= 4 * self.max_entries:
                    self._caps_memo.clear()
                self._caps_memo[base] = (caps, est, overrides)
                return caps

    def key_for(self, plan: ir.Plan, settings: Settings,
                bindings: Optional[dict] = None,
                mode: str = "residual") -> tuple:
        return self._prepare(plan, settings, bindings, mode)[0]

    def contains(self, key: tuple) -> bool:
        with self._lock:
            return key in self._entries

    def note_degraded(self, n: int = 1) -> None:
        """Count `n` requests served against degraded (mask-only) settings
        — called by QueryServer's shed-to-degraded-plan rung so cache
        stats expose how much traffic ran below full fidelity."""
        with self._lock:
            self.stats.degraded += n

    # -- the cache -------------------------------------------------------------
    def _get_prepared(self, key: tuple, plan: ir.Plan, runtime: dict,
                      owned: bool, settings: Settings,
                      _quiet: bool = False) -> CompiledQuery:
        # `_quiet` suppresses hit/miss accounting (NOT the compile
        # counter): the tiered promoter compiles through here after the
        # ladder already counted the request, and double-counting would
        # desync hits+misses from the request count.
        with self._lock:
            cq = self._entries.get(key)
            if cq is not None:
                self._entries.move_to_end(key)
                if not _quiet:
                    self.stats.hits += 1
                return cq
            if not _quiet:
                self.stats.misses += 1
        # compile outside the lock (long); concurrent duplicate compiles are
        # prevented one level up by QueryServer's in-flight dedup.  Passes
        # mutate the plan, so compile from a private copy.  Estimation
        # inputs come from the memoized snapshot the key's capacity
        # signature was computed under — NOT from this request's bindings
        # — so the compiled capacities always equal the signature inside
        # `key` (falling back to the live feedback record in the rare
        # window where a transition popped the memo after keying: the
        # entry then belongs to the superseded key and is simply retired
        # by LRU once the re-keyed requests stop hitting it).
        est, observed = runtime, None
        if settings.compaction:
            with self._lock:
                memo = self._caps_memo.get(key[:-1])
            if memo is not None:
                _, est, observed = memo
            else:
                fb = self._feedback_for(key[:-1], runtime)
                est, observed = fb.est_params, fb.overrides
        cq = CompiledQuery(plan if owned else copy.deepcopy(plan),
                           self.db, settings, params=runtime,
                           est_params=est, observed=observed)
        cq._cache_key = key
        with self._lock:
            self.stats.compiles += 1
            self._entries[key] = cq
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)
                self.stats.evictions += 1
        return cq

    def get(self, plan: ir.Plan, settings: Settings,
            bindings: Optional[dict] = None, mode: str = "residual"
            ) -> tuple[CompiledQuery, dict]:
        """(compiled query, runtime bindings for this request); compiles on
        miss.  The hit path performs no staging and no JIT."""
        key, prepared, runtime, owned = self._prepare(plan, settings,
                                                      bindings, mode)
        return self._get_prepared(key, prepared, runtime, owned,
                                  settings), runtime

    def execute(self, plan: ir.Plan, settings: Settings,
                bindings: Optional[dict] = None, mode: str = "residual"):
        cq, runtime = self.get(plan, settings, bindings, mode)
        res = cq.run(runtime)
        self._note_compaction(cq, 1)
        return res

    # -- execution tiers (core/tiering.py; docs §11) ---------------------------
    def get_tiered(self, plan: ir.Plan, settings: Settings,
                   bindings: Optional[dict] = None, mode: str = "residual"
                   ) -> tuple[tiering.Runnable, dict, str]:
        """(runnable, runtime bindings, tier name): the best READY tier
        for this request, immediately.  A warm target entry behaves
        exactly like `get`; a cold shape is served by the ladder's bottom
        rung (the Volcano oracle — no staging, no JIT) while a background
        thread compiles the target tier and hot-swaps the entry.  Any
        tier satisfies the same Runnable contract, so callers execute the
        result identically regardless of rung."""
        key, prepared, runtime, owned = self._prepare(plan, settings,
                                                      bindings, mode)
        return self._get_tiered_prepared(key, prepared, runtime, owned,
                                         settings)

    def _get_tiered_prepared(self, key: tuple, plan: ir.Plan,
                             runtime: dict, owned: bool, settings: Settings,
                             compile_hook: Optional[Callable] = None
                             ) -> tuple[tiering.Runnable, dict, str]:
        ladder = tiering.TierLadder(settings)
        with self._lock:
            cq = self._entries.get(key)
            if cq is not None:
                # target tier ready: the classic warm hit
                self._entries.move_to_end(key)
                self.stats.hits += 1
                self._tier_hit(ladder.target.name)
                return cq, runtime, ladder.target.name
            st = self._ladders.get(key)
            if st is None:
                if len(self._ladders) >= 4 * self.max_entries:
                    # bound the cold-state table; in-flight promotions
                    # keep their state (the job holds its own reference)
                    self._ladders = {k: s for k, s in self._ladders.items()
                                     if s.promoting}
                st = _LadderState(plan if owned else copy.deepcopy(plan),
                                  dict(runtime), ladder)
                self._ladders[key] = st
                self.stats.misses += 1
            else:
                self.stats.hits += 1
        if ladder.target is tiering.ORACLE:
            # volcano-engine settings: the ladder is one rung, nothing to
            # promote toward
            run = self._ensure_oracle(st)
            st.done.set()
            self._tier_hit(run.tier_name)
            return run, runtime, run.tier_name
        self._ensure_oracle(st)
        self._maybe_promote(key, st, settings, compile_hook)
        with self._lock:
            best = st.best()
        self._tier_hit(best.tier_name)
        return best, runtime, best.tier_name

    def _tier_hit(self, name: str) -> None:
        with self._lock:
            self.stats.tier_hits[name] = self.stats.tier_hits.get(name, 0) + 1

    def _ensure_oracle(self, st: _LadderState) -> tiering.Runnable:
        """The ladder's always-ready bottom rung, built at most once per
        state.  Construction is microseconds (no staging), so racing
        builders waste nothing; the first to publish wins."""
        with self._lock:
            got = st.ready.get(tiering.ORACLE.name)
            if got is not None:
                return got
        oq = OracleQuery(st.plan, self.db, params=st.runtime)
        with self._lock:
            return st.ready.setdefault(tiering.ORACLE.name, oq)

    def _maybe_promote(self, key: tuple, st: _LadderState,
                       settings: Settings,
                       compile_hook: Optional[Callable]) -> None:
        """Schedule one background promotion toward the target tier.
        Deduplicated per key (`st.promoting`); a sticky failure stops the
        climb for this state — the ready tier keeps serving, and a later
        eviction/re-key starts a fresh ladder."""
        with self._lock:
            if st.promoting or st.failure is not None or st.done.is_set():
                return
            st.promoting = True
            if self._promoter is None:
                self._promoter = ThreadPoolExecutor(
                    max_workers=self._promote_workers,
                    thread_name_prefix="plan-cache-promote")
            pool = self._promoter
        try:
            pool.submit(self._promote, key, st, settings, compile_hook)
        except RuntimeError as e:      # pool shut down (cache closed)
            with self._lock:
                st.promoting = False
                st.failure = e
                st.done.set()

    def _promote(self, key: tuple, st: _LadderState, settings: Settings,
                 compile_hook: Optional[Callable]) -> None:
        """Background promotion job: compile the rung(s) above the best
        ready tier and hot-swap each into the ladder as it lands.  The
        target tier also becomes the canonical `_entries[key]` entry, so
        every later request takes the plain warm-hit path."""
        ladder = st.ladder
        try:
            with self._lock:
                ready = tiering.tier(st.best().tier_name)
            for t in ladder.promotion_path(ready, self.promote_through):
                if compile_hook is not None:
                    compile_hook(key)
                if t is ladder.target:
                    cq = self._get_prepared(key, copy.deepcopy(st.plan),
                                            st.runtime, True, settings,
                                            _quiet=True)
                else:
                    # intermediate rung (interpret): a cheaper program
                    # under the tier's settings.  It lives only in the
                    # ladder — its settings differ from the request's, so
                    # it must never be keyed as the target entry.
                    cq = CompiledQuery(copy.deepcopy(st.plan), self.db,
                                       ladder.settings_for(t),
                                       params=st.runtime)
                    cq.tier_name = t.name
                    with self._lock:
                        self.stats.compiles += 1
                with self._lock:
                    st.ready[t.name] = cq
                    self.stats.promotions += 1
            with self._lock:
                st.promoting = False
                st.done.set()
                # fully promoted: requests now hit _entries directly and
                # the cold-state record has done its job
                if self._ladders.get(key) is st:
                    del self._ladders[key]
        except BaseException as e:
            with self._lock:
                st.promoting = False
                st.failure = e
                st.done.set()
                self.stats.promote_failures += 1

    def await_promotion(self, plan: ir.Plan, settings: Settings,
                        bindings: Optional[dict] = None,
                        mode: str = "residual",
                        timeout: Optional[float] = None) -> bool:
        """Block until the background promotion for this request's key
        settles (hot-swap complete or failed); True when the target tier
        is ready.  Deterministic handle for tests and benchmarks — the
        serving path never needs it."""
        key = self.key_for(plan, settings, bindings, mode)
        with self._lock:
            if key in self._entries:
                return True
            st = self._ladders.get(key)
        if st is None:
            return self.contains(key)
        st.done.wait(timeout)
        return self.contains(key)

    def execute_tiered(self, plan: ir.Plan, settings: Settings,
                       bindings: Optional[dict] = None,
                       mode: str = "residual"):
        """(result, tier name): `execute` through the tier ladder."""
        run, runtime, tier_name = self.get_tiered(plan, settings, bindings,
                                                  mode)
        res = run.run(runtime)
        self._note_compaction(run, 1)
        return res, tier_name

    def is_warm(self, plan: ir.Plan, settings: Settings,
                bindings: Optional[dict] = None,
                mode: str = "residual") -> bool:
        """True when this request's shape had a compiled entry in a
        previously persisted warm state (or has one live right now) — a
        restarted server's signal for which shapes to promote eagerly."""
        key = self.key_for(plan, settings, bindings, mode)
        with self._lock:
            return key in self._entries or key[:-1] in self._warm_hints

    # -- persistence (core/persist.py; docs §11) -------------------------------
    def save(self, path: str) -> int:
        """Persist the feedback store + warm metadata; returns records
        written.  Pair with the JAX persistent compilation cache
        (`persist.enable_compilation_cache`) so the XLA executables
        survive too."""
        return persist_mod.save_warm_state(self, path)

    def load(self, path: str) -> int:
        """Restore a persisted warm state; returns records restored (0 =
        cold start: missing/corrupt/version-skewed/different-data files
        are silently ignored).  Restored capacity overrides flow into the
        first compile of each shape, so request 1 runs at the
        pre-restart converged capacities — no re-convergence overflows."""
        return persist_mod.load_warm_state(self, path)

    def close(self) -> None:
        """Stop the background promoter (if any).  In-flight compiles are
        abandoned to finish on their own thread; no new promotions start.
        Idempotent, and a no-op for never-tiered caches."""
        with self._lock:
            pool, self._promoter = self._promoter, None
        if pool is not None:
            pool.shutdown(wait=False)

    def _note_compaction(self, cq: CompiledQuery, n_execs: int) -> None:
        """Compaction accounting for `n_execs` executions just performed on
        `cq`: compacted executions and overflow fallbacks (watermarked like
        batch traces, so concurrent callers never double-count), then the
        adaptive-feedback step."""
        if not cq.compaction_points:
            return
        with self._lock:
            self.stats.compactions += n_execs
            seen = self._overflow_seen.get(cq, 0)
            delta = max(cq.n_overflows - seen, 0)
            if delta:
                self.stats.overflows += delta
                self._overflow_seen[cq] = cq.n_overflows
        self._feedback_step(cq, delta)

    def _feedback_step(self, cq: CompiledQuery, overflow_delta: int) -> None:
        """Close the loop between runtime and planner: merge the entry's
        measured counts into the plan shape's feedback record, then —

          * after `compact_replan_after` overflows, re-plan the shape with
            capacities derived from the observed max counts (the stale
            entry is evicted; the next request compiles against measured
            headroom);
          * after `compact_shrink_after` consecutive large underuses
            (every point < capacity/4), shrink to the bucket over the
            streak's window max (a historical spike must not pin
            capacity up forever).

        Each transition costs at most one retrace per direction: the new
        capacity vector is a new plan key, compiled once."""
        s = cq.settings
        if not (s.compaction and s.compact_feedback) \
                or cq._cache_key is None:
            return
        base = cq._cache_key[:-1]
        with cq._obs_lock:
            observed = dict(cq.observed_max)
            under = cq.under_streak
            streak_max = dict(cq.streak_max)
            shard_obs = {pid: v.copy()
                         for pid, v in cq.observed_shard.items()}
        # translate points are exempt from shrink decay: a translate
        # overflow silently drops build rows the probe then misses (wrong
        # answers, not just a fallback re-execution), so their capacity
        # floors at the all-time max (`translate_bucket` in the pass) and
        # the window-max decay below must never touch them
        streak_max = {pid: c for pid, c in streak_max.items()
                      if pid not in cq.translate_points}
        with self._lock:
            fb = self._feedback.get(base)
            if fb is None:
                return
            for pid, c in observed.items():
                if c > fb.observed.get(pid, -1):
                    fb.observed[pid] = c
            for pid, v in shard_obs.items():
                old = fb.observed_shard.get(pid)
                fb.observed_shard[pid] = v if (
                    old is None or old.shape != v.shape
                ) else np.maximum(old, v)
            fb.overflows += overflow_delta
            if fb.overflows >= s.compact_replan_after:
                fb.overrides = {**(fb.overrides or {}), **fb.observed}
                fb.overflows = 0
                fb.replans += 1
                self.stats.replans += 1
                self._retire(cq, base, fb)
            elif under >= s.compact_shrink_after and streak_max \
                    and any(observed_bucket(c) < cq.point_caps.get(pid, 0)
                            for pid, c in streak_max.items()
                            if pid in cq.point_caps):
                fb.overrides = {**(fb.overrides or {}), **streak_max}
                # the shrink is evidence the old maxima are stale: decay
                # fb.observed to the window max too, or a later re-plan
                # would resurrect a historical spike and ping-pong the
                # capacity back up (docs §6: "a historical spike cannot
                # pin capacity up")
                fb.observed.update(streak_max)
                fb.shrinks += 1
                self.stats.shrinks += 1
                self._retire(cq, base, fb)

    def _retire(self, cq: CompiledQuery, base: tuple,
                fb: _Feedback) -> None:
        """Drop a re-planned entry's stale state (caller holds the lock):
        the memoized capacity signature (the next `_prepare` recomputes it
        under the new overrides, producing a new key) and the compiled
        entry itself.  `fb.gen` advances so a signature computed against
        the pre-transition overrides can never be memoized afterwards.
        The entry is *detached* (`_cache_key = None`): a caller still
        holding `cq` can keep executing it, but its observations are no
        longer harvested — they were consumed by this transition, and
        re-merging them would resurrect deliberately decayed maxima."""
        fb.gen += 1
        self._caps_memo.pop(base, None)
        if self._entries.get(cq._cache_key) is cq:
            del self._entries[cq._cache_key]
        cq._cache_key = None
        with cq._obs_lock:
            cq.under_streak = 0
            cq.streak_max = {}

    # -- batched execution -----------------------------------------------------
    def run_many(self, cq: CompiledQuery, runtime_list) -> list:
        """`cq.run_many` with batch accounting: retraces of the vmapped
        program and pad slots (power-of-two bucket minus batch size) land
        in `stats.batch_traces` / `stats.padded_slots`.

        Trace accounting uses a per-entry *watermark* (last observed
        `n_batch_traces`), not a before/after delta: two server threads
        executing the same entry concurrently would otherwise attribute
        one retrace to both calls (or neither)."""
        runtime_list = list(runtime_list)
        results = cq.run_many(runtime_list)
        with self._lock:
            seen = self._batch_trace_seen.get(cq, 0)
            if cq.n_batch_traces > seen:
                self.stats.batch_traces += cq.n_batch_traces - seen
                self._batch_trace_seen[cq] = cq.n_batch_traces
            if cq.param_spec and runtime_list \
                    and getattr(cq, "pads_batches", True):
                self.stats.padded_slots += \
                    compile_mod.bucket_size(len(runtime_list)) \
                    - len(runtime_list)
        self._note_compaction(cq, len(runtime_list))
        return results

    def execute_many(self, plan: ir.Plan, settings: Settings,
                     bindings_list, mode: str = "residual") -> list:
        """Execute N bindings of one logical plan, batching every group of
        bindings that shares a plan key into a single vmapped dispatch.

        Compile-time (string / LIMIT) parameters partition the batch
        first: bindings that substitute to different plan structures can
        never share a staged program, so each structural group compiles
        (or hits) its own entry and runs as its own batch.  Results are
        returned positionally, matching `bindings_list`."""
        prepared = [self._prepare(plan, settings, b, mode)
                    for b in bindings_list]
        groups: "OrderedDict[tuple, list[int]]" = OrderedDict()
        for i, (key, _, _, _) in enumerate(prepared):
            groups.setdefault(key, []).append(i)
        results: list = [None] * len(prepared)
        for key, idxs in groups.items():
            _, plan_i, runtime_i, owned_i = prepared[idxs[0]]
            cq = self._get_prepared(key, plan_i, runtime_i, owned_i,
                                    settings)
            # _get_prepared counted one hit/miss per *group*; the other
            # members are hits on the same entry.
            with self._lock:
                self.stats.hits += len(idxs) - 1
            if len(idxs) == 1:
                # singleton group: the warm scalar program beats tracing
                # a fresh bucket-1 vmapped one
                results[idxs[0]] = cq.run(runtime_i)
                self._note_compaction(cq, 1)
                continue
            for i, res in zip(idxs, self.run_many(
                    cq, [prepared[i][2] for i in idxs])):
                results[i] = res
        return results

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    @staticmethod
    def stagings() -> int:
        """Global CompiledQuery construction count (for compile-counter
        assertions independent of cache bookkeeping)."""
        return compile_mod.STAGINGS
