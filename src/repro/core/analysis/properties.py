"""Derived static properties per plan node: cardinality upper bounds,
sortedness, date clustering, and positional parent-table alignment.

`analyze(plan, db)` runs one bottom-up dataflow pass and memoizes a
`NodeInfo` per node, so every consumer (the verifier's rules, the
compaction estimator, hash-map lowering) shares a single traversal instead
of re-walking the plan per query.  Plans are mutable, so an `Analysis` is
valid only for the plan shape it was computed against — passes re-run
`analyze` after rewriting (nodes first seen through `info()` after
construction are derived on demand).

Property semantics:

  card        — static upper bound on the node's *valid* output rows: table /
                date-slice sizes at Scans, `Compact` capacities, dense-agg
                domain products, `Limit` cutoffs.  Filters keep the bound
                (a Select can only remove rows).
  sorted_by   — ((col, ascending), ...) ordering the output is known to
                carry: Sort keys, group keys after grouping aggregation,
                the sliced date column after a date slice.
  clustered_by— date column the rows are physically clustered on
                (post-date-slice), the property `date_slice` planning and
                range-residual elision rely on.
  aligned     — parent table T when the node's physical rows are (a masked
                view of) T's rows in order, i.e. row id == T's dense PK.
                This is the soundness condition behind `pk_gather` /
                `bucket_gather` build sides: those strategies address the
                build frame positionally, so anything that re-packs rows
                (a gathering `Compact`, a date slice, a sort) destroys it.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import ir
from repro.core.analysis.schema import ColInfo, Schema, node_schema


@dataclasses.dataclass


class NodeInfo:
    schema: Schema
    card: int
    sorted_by: tuple = ()
    clustered_by: Optional[str] = None
    aligned: Optional[str] = None
    # parent table T when the node is a compacted view of T's rows THAT
    # CARRIES the CSR key→slot translation vector (ir.Compact.translate):
    # positional addressing is gone, but a pk_gather probe can recover the
    # compacted slot of any parent row id through slot_of, so the verifier
    # accepts a translated frame where it would demand alignment.  Dropped
    # by anything that loses the staged slot_of (joins, aggs, sorts).
    translated: Optional[str] = None
    # partition root table when the node's frame is partitioned over the
    # mesh's data axis (mirrors the staged Frame.part threading exactly);
    # None = replicated.  `card` is then the PER-SHARD bound — the frame
    # height inside shard_map, which is what Compact capacities and the
    # dense-agg planner must size against.
    part: Optional[str] = None
    # mesh size of the subtree (max over partitioned scans below; 1 when
    # unsharded) — what an Exchange multiplies card by when gathering.
    shards: int = 1


class Analysis:
    """Memoized per-node static properties of one plan against one db."""

    def __init__(self, plan: ir.Plan, db):
        self.plan = plan
        self.db = db
        # keyed by node identity; `_nodes` pins the nodes so a reclaimed
        # id can never alias a stale entry (same hazard PlanCache documents
        # for id(db))
        self._info: dict[int, NodeInfo] = {}
        self._nodes: dict[int, ir.Plan] = {}
        self._visit(plan)

    def info(self, node: ir.Plan) -> NodeInfo:
        got = self._info.get(id(node))
        if got is None:
            got = self._visit(node)
        return got

    def schema(self, node: ir.Plan) -> Schema:
        return self.info(node).schema

    def col(self, node: ir.Plan, name: str) -> Optional[ColInfo]:
        return self.info(node).schema.get(name)

    def _visit(self, p: ir.Plan) -> NodeInfo:
        got = self._info.get(id(p))
        if got is not None:
            return got
        kids = [self._visit(c) for c in ir.children(p)]
        info = _derive(p, self.db, kids)
        self._info[id(p)] = info
        self._nodes[id(p)] = p
        return info


def analyze(plan: ir.Plan, db) -> Analysis:
    """One-pass schema + property inference over `plan` (memoized)."""
    return Analysis(plan, db)


def _keep_order(order: tuple, schema: Schema) -> tuple:
    """Longest sort-key prefix that survives a projection."""
    out = []
    for key in order:
        if key[0] not in schema:
            break
        out.append(key)
    return tuple(out)


def _derive_scan(p: ir.Scan, sch, db, kids) -> NodeInfo:
    t = db.table(p.table)
    n = t.nrows
    if p.shard is not None:
        # partitioned scan: the staged frame is the shard-local block —
        # per-shard card, and positional alignment only for the root
        # (padded position == global row id modulo the pk_gather rebase);
        # a routed child's rows are owner-permuted, alignment is gone.
        aligned = p.table if p.shard.part == p.table else None
        return NodeInfo(sch, p.shard.per_shard_rows, aligned=aligned,
                        part=p.shard.part, shards=p.shard.n_shards)
    if p.date_slice is None:
        return NodeInfo(sch, n, aligned=p.table)
    ds = p.date_slice
    _, start, end = db.date_slice(p.table, ds.col, ds.lo, ds.hi)
    n = max(end - start, 0)
    return NodeInfo(sch, n, sorted_by=((ds.col, True),),
                    clustered_by=ds.col)


def _derive_select(p, sch, db, kids) -> NodeInfo:
    c = kids[0]
    return NodeInfo(sch, c.card, c.sorted_by, c.clustered_by, c.aligned,
                    c.translated, c.part, c.shards)


def _derive_project(p, sch, db, kids) -> NodeInfo:
    c = kids[0]
    clustered = c.clustered_by if c.clustered_by in sch else None
    return NodeInfo(sch, c.card, _keep_order(c.sorted_by, sch),
                    clustered, c.aligned, c.translated, c.part, c.shards)


def _derive_compact(p: ir.Compact, sch, db, kids) -> NodeInfo:
    c = kids[0]
    if p.capacity <= 0:
        # measure-only point: the frame passes through untouched
        return NodeInfo(sch, c.card, c.sorted_by, c.clustered_by, c.aligned,
                        c.translated, c.part, c.shards)
    # a gathering compact keeps relative order but re-packs physical
    # rows, so positional alignment is gone; with `translate` the CSR
    # slot_of vector re-establishes key addressability over what WAS a
    # positionally-aligned frame
    translated = c.aligned if p.translate else None
    return NodeInfo(sch, min(int(p.capacity), c.card), c.sorted_by,
                    c.clustered_by, None, translated, c.part, c.shards)


def _derive_exchange(p: ir.Exchange, sch, db, kids) -> NodeInfo:
    c = kids[0]
    # tiled all-gather: every shard ends up with the full frame — card
    # multiplies by the mesh size and the partition is gone.  Positional
    # alignment survives ONLY for the root's padded row-range layout
    # (position == global row id); a routed child's gathered rows stay
    # owner-permuted.  Per-shard sort order does not concatenate into a
    # global order, so sortedness/clustering are dropped.
    aligned = c.aligned if c.aligned is not None and c.part == c.aligned \
        else None
    return NodeInfo(sch, c.card * max(c.shards, 1), aligned=aligned,
                    shards=c.shards)


def _derive_join(p, sch, db, kids) -> NodeInfo:
    # every strategy emits the stream's physical frame (build columns
    # are gathered into it), so stream properties carry through
    s, b = kids
    return NodeInfo(sch, s.card, s.sorted_by, s.clustered_by, s.aligned,
                    part=s.part, shards=max(s.shards, b.shards))


def _derive_agg(p: ir.Agg, sch, db, kids) -> NodeInfo:
    # every strategy's output is replicated: scalar/dense combine
    # shard-local partials in-operator (psum/pmin/pmax), and generic
    # requires a gathered input (verifier's shard-invariance rule)
    c = kids[0]
    if p.strategy == "scalar" or not p.group_by:
        return NodeInfo(sch, 1, shards=c.shards)
    order = tuple((g, True) for g in p.group_by)
    if p.strategy == "dense":
        card = 1
        for d in p.domains or [c.card]:
            card *= int(d)
        aligned = None
        if len(p.group_by) == 1:
            ci = c.schema.get(p.group_by[0])
            if (ci is not None and ci.parent is not None
                    and p.domains == [db.table(ci.parent).nrows]):
                # dense agg keyed on a full PK domain: output row id
                # IS the key value (Q18's agg-as-build side)
                aligned = ci.parent
        return NodeInfo(sch, card, order, aligned=aligned, shards=c.shards)
    return NodeInfo(sch, c.card, order, shards=c.shards)


def _derive_sort(p: ir.Sort, sch, db, kids) -> NodeInfo:
    c = kids[0]
    return NodeInfo(sch, c.card, tuple(p.keys), part=c.part, shards=c.shards)


def _derive_limit(p: ir.Limit, sch, db, kids) -> NodeInfo:
    c = kids[0]
    n = p.n if isinstance(p.n, int) else c.card
    return NodeInfo(sch, min(int(n), c.card), c.sorted_by, c.clustered_by,
                    part=c.part, shards=c.shards)


# type dispatch, mirroring schema._SCHEMA_FNS: analyze() runs once per
# pass per optimize, so the per-node constant factor matters
_DERIVE_FNS = {
    ir.Scan: _derive_scan,
    ir.Select: _derive_select,
    ir.Project: _derive_project,
    ir.Compact: _derive_compact,
    ir.Exchange: _derive_exchange,
    ir.Join: _derive_join,
    ir.Agg: _derive_agg,
    ir.Sort: _derive_sort,
    ir.Limit: _derive_limit,
}


def _derive(p: ir.Plan, db, kids: list[NodeInfo]) -> NodeInfo:
    fn = _DERIVE_FNS.get(type(p))
    if fn is None:
        raise TypeError(type(p))
    sch = node_schema(p, db, [k.schema for k in kids])
    return fn(p, sch, db, kids)


def composite_pack_bound(
    k1_max: Optional[int], k2_maxes: list[int]
) -> tuple[int, Optional[int]]:
    """(K2, packed_max) for the generic composite-key uint32 pack
    `k1 * K2 + k2`.  K2 must exceed both sides' k2 values or distinct
    pairs collide; `packed_max` (None when k1 is unbounded) must stay
    below 2**32 or the pack wraps and matches garbage.  Shared by the
    staging-time check in `operators/join.py` and the verifier's
    `key-pack` rule, so both report the same bound.
    """
    K2 = int(max(k2_maxes)) + 1 if k2_maxes else 1 << 20
    packed = int(k1_max) * K2 + (K2 - 1) if k1_max is not None else None
    return K2, packed
