"""Static analysis over the plan IR.

Three parts (PR 6):

  * schema & property inference (`schema.py`, `properties.py`): one
    bottom-up dataflow pass computing per-node output schemas (dtype
    families + base-column provenance + domain bounds) and derived
    properties (cardinality upper bounds, sortedness, date clustering,
    positional parent alignment), memoized behind `analyze(plan, db)`;
  * the inter-pass verifier (`verify.py`): a rule registry over the
    analysis results, run after every pass when `Settings.verify_passes`
    is on — violations raise `PlanInvariantError` naming the pass;
  * the plan fuzzer (`fuzz.py`, imported on demand — it pulls in the
    compile stack): seeded random TPC-H plans driven through every preset
    ladder rung against the Volcano oracle.
"""
from repro.core.analysis.properties import (Analysis, NodeInfo, analyze,
                                            composite_pack_bound)
from repro.core.analysis.schema import (ColInfo, SchemaError, base_colinfo,
                                        expr_dtype, schema_of)
from repro.core.analysis.verify import (RULES, PlanInvariantError, Violation,
                                        check_plan, rule, verify_plan)

__all__ = [
    "Analysis",
    "NodeInfo",
    "analyze",
    "composite_pack_bound",
    "ColInfo",
    "SchemaError",
    "base_colinfo",
    "expr_dtype",
    "schema_of",
    "RULES",
    "PlanInvariantError",
    "Violation",
    "check_plan",
    "rule",
    "verify_plan",
]
