"""Inter-pass plan verifier: well-formedness rules over the analysis layer.

The pipeline's safety story (paper §2.2: independent black-box plan→plan
passes) only holds if every pass preserves plan well-formedness — a pass
that emits a dangling column reference, a dtype-mismatched join key, or a
`Compact` under a positional build side otherwise surfaces as a cryptic
XLA staging error or a silently wrong answer.  `verify_plan` checks the
rules below; `passes/pipeline.py` calls it after **each** pass when
`Settings.verify_passes` is on, so a violation is attributed to the pass
that introduced it (pass bisection for free).

Adding a rule: write a generator taking `(plan, db, settings, analysis)`
and yielding `Violation`s, and decorate it with `@rule("name")`.  Rules
must describe *soundness* conditions (what the staged operators require),
not planner policy — a rule that merely mirrors one pass's current
decisions will false-positive the moment another pass makes a different
legal choice.  Rules whose condition only holds for fully lowered plans
(e.g. the uint32 key-pack bound, which Partitioning may obviate by
choosing `bucket_gather`) register with `final_only=True` and run only
after the last pass.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.core import expr as E
from repro.core import ir
from repro.core.analysis.properties import (Analysis, analyze,
                                            composite_pack_bound)
from repro.core.analysis.schema import SchemaError
from repro.relational.schema import ColKind


class PlanInvariantError(Exception):
    """A plan violates an inter-pass invariant.  Carries the rule name,
    the pass the plan came out of, and a `plan_repr` excerpt of the
    offending node."""

    def __init__(
        self,
        rule: str,
        message: str,
        node: Optional[ir.Plan] = None,
        pass_name: Optional[str] = None,
    ):
        self.rule = rule
        self.message = message
        self.node = node
        self.pass_name = pass_name
        where = f"after pass {pass_name!r}" if pass_name else "verify"
        excerpt = ""
        if node is not None:
            lines = ir.plan_repr(node).splitlines()
            if len(lines) > 8:
                lines = lines[:8] + ["  ..."]
            excerpt = "\n" + "\n".join("    " + ln for ln in lines)
        super().__init__(f"[{where}] rule {rule!r}: {message}{excerpt}")


@dataclasses.dataclass(frozen=True)


class Violation:
    rule: str
    message: str
    node: Optional[ir.Plan] = None


@dataclasses.dataclass(frozen=True)


class Rule:
    name: str
    fn: Callable
    final_only: bool
    doc: str


RULES: list[Rule] = []


def rule(name: str, final_only: bool = False):
    """Register a verifier rule: a generator of `Violation`s."""

    def deco(fn):
        RULES.append(Rule(name, fn, final_only, (fn.__doc__ or "").strip()))
        return fn

    return deco


def check_plan(
    plan: ir.Plan, db, settings=None, final: bool = True
) -> list[Violation]:
    """All violations in `plan` (empty list = well-formed).  Schema
    inference failures short-circuit: the rules need schemas to run."""
    try:
        a = analyze(plan, db)
    except SchemaError as err:
        return [Violation("schema", str(err), err.node)]
    out: list[Violation] = []
    for r in RULES:
        if r.final_only and not final:
            continue
        out.extend(r.fn(plan, db, settings, a))
    return out


def verify_plan(plan: ir.Plan, db, settings=None,
                pass_name: Optional[str] = None, final: bool = True) -> None:
    """Raise `PlanInvariantError` (attributed to `pass_name`) on the first
    violation found in `plan`."""
    violations = check_plan(plan, db, settings, final)
    if violations:
        v = violations[0]
        raise PlanInvariantError(v.rule, v.message, v.node, pass_name)


# ---------------------------------------------------------------------------
# rule helpers
# ---------------------------------------------------------------------------


def _node_exprs(node: ir.Plan):
    """Expression positions of a node, evaluated against its child schema."""
    if isinstance(node, ir.Select):
        yield node.pred
    elif isinstance(node, ir.Project):
        yield from node.outputs.values()
    elif isinstance(node, ir.Agg):
        for spec in node.aggs:
            if spec.expr is not None:
                yield spec.expr


_POSITIONAL = ("pk_gather", "bucket_gather")
_KEYABLE = {"int", "code", "date", "bool"}


# ---------------------------------------------------------------------------
# rules
# ---------------------------------------------------------------------------


@rule("column-resolution")


def _columns_resolve(plan, db, settings, a: Analysis):
    """Every Col / join key / sort key resolves in the child schema.
    (Scan columns, Project renames and Agg group/carry keys are already
    enforced during schema inference.)"""
    for node in ir.walk(plan):
        if isinstance(node, ir.Join):
            s, b = a.schema(node.stream), a.schema(node.build)
            pairs = [
                (node.stream_key, s, "stream"),
                (node.build_key, b, "build"),
                (node.stream_key2, s, "stream"),
                (node.build_key2, b, "build"),
            ]
            for key, sch, side in pairs:
                if key is not None and key not in sch:
                    yield Violation(
                        "column-resolution",
                        f"join {side} key {key!r} is not produced by the "
                        f"{side} side", node)
            continue
        kids = ir.children(node)
        child = a.schema(kids[0]) if kids else {}
        for e in _node_exprs(node):
            for name in E.expr_columns(e):
                if name not in child:
                    yield Violation(
                        "column-resolution",
                        f"column {name!r} referenced by "
                        f"{type(node).__name__} is not produced by its "
                        "input", node)
        if isinstance(node, ir.Sort):
            for name, _asc in node.keys:
                if name not in child:
                    yield Violation(
                        "column-resolution",
                        f"sort key {name!r} is not produced by the input",
                        node)


@rule("expr-dtypes")


def _expr_dtypes(plan, db, settings, a: Analysis):
    """String-family columns only appear under string operators: a TEXT
    column in arithmetic/comparison position, a code predicate on a
    non-CAT column, or a word predicate on a non-TEXT column is a
    miscompile in waiting."""
    code_ops = (E.StrEq, E.StrIn, E.StrStartsWith, E.CodeEq, E.CodeIn,
                E.CodeRange)
    word_ops = (E.StrContainsWord, E.WordCode)

    def walk_expr(e, schema, node):
        if isinstance(e, E.Col):
            ci = schema.get(e.name)
            if ci is not None and ci.dtype == "string":
                yield Violation(
                    "expr-dtypes",
                    f"TEXT column {e.name!r} used in scalar expression "
                    "position", node)
            return
        if isinstance(e, code_ops):
            ci = schema.get(e.col)
            if ci is not None and ci.dtype != "code":
                yield Violation(
                    "expr-dtypes",
                    f"string predicate {type(e).__name__} on non-CAT "
                    f"column {e.col!r} ({ci.dtype})", node)
            return
        if isinstance(e, word_ops):
            ci = schema.get(e.col)
            if ci is not None and ci.dtype != "string":
                yield Violation(
                    "expr-dtypes",
                    f"word predicate {type(e).__name__} on non-TEXT "
                    f"column {e.col!r} ({ci.dtype})", node)
            return
        if isinstance(e, (E.Arith, E.Cmp, E.And, E.Or)):
            yield from walk_expr(e.lhs, schema, node)
            yield from walk_expr(e.rhs, schema, node)
        elif isinstance(e, (E.Not, E.Year)):
            yield from walk_expr(e.operand, schema, node)
        elif isinstance(e, E.Where):
            yield from walk_expr(e.cond, schema, node)
            yield from walk_expr(e.then, schema, node)
            yield from walk_expr(e.other, schema, node)

    for node in ir.walk(plan):
        kids = ir.children(node)
        if not kids:
            continue
        child = a.schema(kids[0])
        for e in _node_exprs(node):
            if isinstance(node, ir.Project) and isinstance(e, E.Col):
                continue  # a bare rename may carry any dtype, TEXT included
            yield from walk_expr(e, child, node)


@rule("join-keys")


def _join_keys(plan, db, settings, a: Analysis):
    """Join key pairs carry the same integer-class dtype family (float
    keys don't equi-join exactly; string keys never lower)."""
    for node in ir.walk(plan):
        if not isinstance(node, ir.Join):
            continue
        pairs = [(node.stream_key, node.build_key)]
        if node.stream_key2 is not None or node.build_key2 is not None:
            pairs.append((node.stream_key2, node.build_key2))
        for skey, bkey in pairs:
            if skey is None or bkey is None:
                yield Violation(
                    "join-keys",
                    "composite join carries only one side's second key",
                    node)
                continue
            sci = a.col(node.stream, skey)
            bci = a.col(node.build, bkey)
            if sci is None or bci is None:
                continue  # column-resolution reports the dangling key
            if sci.dtype != bci.dtype:
                yield Violation(
                    "join-keys",
                    f"key dtype mismatch: {skey!r} is {sci.dtype}, "
                    f"{bkey!r} is {bci.dtype}", node)
            elif sci.dtype not in _KEYABLE:
                yield Violation(
                    "join-keys",
                    f"join on non-integer key {skey!r} ({sci.dtype})", node)
        if node.strategy == "exists_flag" and node.domain is not None:
            sci = a.col(node.stream, node.stream_key)
            if (sci is not None and sci.domain is not None
                    and sci.domain > node.domain):
                yield Violation(
                    "join-keys",
                    f"exists_flag domain {node.domain} is smaller than the "
                    f"stream key domain {sci.domain} — probes past the "
                    "flag array", node)


@rule("positional-build-alignment")


def _build_alignment(plan, db, settings, a: Analysis):
    """`pk_gather`/`bucket_gather` address the build frame positionally
    (a key value is a row id), so the build subtree must stay aligned to
    the parent table: no gathering Compact, date slice, or sort below it,
    and the stream key must range over exactly that table's PK domain."""
    for node in ir.walk(plan):
        if not isinstance(node, ir.Join) or node.strategy not in _POSITIONAL:
            continue
        if node.build_table is None:
            yield Violation(
                "positional-build-alignment",
                f"{node.strategy} join without build_table", node)
            continue
        info = a.info(node.build)
        got = info.aligned
        # a pk_gather build may instead be a *translated* compact of the
        # parent (ir.Compact.translate): the CSR slot_of vector recovers
        # the compacted slot of any parent row id, so key addressing
        # survives re-packing.  bucket_gather probes a 2-D bucket matrix
        # whose entries are parent row ids — translation does not apply.
        translated_ok = (node.strategy == "pk_gather"
                         and info.translated == node.build_table)
        if got != node.build_table and not translated_ok:
            yield Violation(
                "positional-build-alignment",
                f"build side is not aligned to {node.build_table!r} "
                f"(aligned={got!r}) — a Compact/date-slice/sort below a "
                "positional build destroys row addressing", node)
        if node.strategy == "pk_gather":
            sci = a.col(node.stream, node.stream_key)
            if sci is not None and sci.parent != node.build_table:
                yield Violation(
                    "positional-build-alignment",
                    f"stream key {node.stream_key!r} does not range over "
                    f"{node.build_table!r}'s primary key "
                    f"(parent={sci.parent!r})", node)


@rule("dense-agg-domain")


def _dense_domains(plan, db, settings, a: Analysis):
    """`dense` aggregation scatters into a statically allocated array, so
    every group key needs a static domain bound covered by the planned
    `domains` (and `scalar` means *no* group keys at all)."""
    for node in ir.walk(plan):
        if not isinstance(node, ir.Agg):
            continue
        if node.strategy == "scalar" and node.group_by:
            yield Violation(
                "dense-agg-domain",
                "scalar Agg with group keys drops the grouping", node)
        if node.strategy != "dense":
            continue
        if not node.domains or len(node.domains) != len(node.group_by):
            yield Violation(
                "dense-agg-domain",
                f"dense Agg needs one domain per group key, got "
                f"domains={node.domains} for keys {node.group_by}", node)
            continue
        if settings is not None:
            total = 1
            for d in node.domains:
                total *= int(d)
            if total > settings.dense_agg_cap:
                yield Violation(
                    "dense-agg-domain",
                    f"dense domain product {total} exceeds dense_agg_cap "
                    f"{settings.dense_agg_cap}", node)
        child = a.schema(node.child)
        for g, d in zip(node.group_by, node.domains):
            bound = node.domain_hints.get(g)
            if bound is None:
                ci = child.get(g)
                bound = ci.domain if ci is not None else None
            if bound is None:
                yield Violation(
                    "dense-agg-domain",
                    f"dense Agg key {g!r} has no statically bounded "
                    "domain", node)
            elif int(d) < int(bound):
                yield Violation(
                    "dense-agg-domain",
                    f"planned domain {d} for key {g!r} is below its "
                    f"static bound {bound} — keys would scatter out of "
                    "range", node)


@rule("date-slice")


def _date_slice(plan, db, settings, a: Analysis):
    """`date_slice` only on DATE columns of the scanned table, with a
    sane [lo, hi) window."""
    for node in ir.walk(plan):
        if not isinstance(node, ir.Scan) or node.date_slice is None:
            continue
        ds = node.date_slice
        sch = db.table(node.table).schema
        if not sch.has_col(ds.col):
            yield Violation(
                "date-slice",
                f"date_slice on unknown column {node.table}.{ds.col}", node)
        elif sch.col(ds.col).kind != ColKind.DATE:
            yield Violation(
                "date-slice",
                f"date_slice on non-DATE column {node.table}.{ds.col} "
                f"({sch.col(ds.col).kind.value})", node)
        if ds.lo is not None and ds.hi is not None and ds.lo > ds.hi:
            yield Violation(
                "date-slice",
                f"empty date_slice window lo={ds.lo} > hi={ds.hi}", node)


@rule("limit-above-sort")


def _limit_above_sort(plan, db, settings, a: Analysis):
    """`Limit` only directly above `Sort` (or another Limit): the staged
    operator takes the first n *physical* rows, which is only meaningful
    once a sort has packed valid rows to the front in order."""
    for node in ir.walk(plan):
        if isinstance(node, ir.Limit) and not isinstance(
                node.child, (ir.Sort, ir.Limit)):
            yield Violation(
                "limit-above-sort",
                f"Limit over {type(node.child).__name__} — the cutoff "
                "needs sorted, front-packed input", node)


@rule("compact-capacity")


def _compact_capacity(plan, db, settings, a: Analysis):
    """Compact capacities are non-negative static shapes (0 = measure-only
    point)."""
    for node in ir.walk(plan):
        if isinstance(node, ir.Compact) and int(node.capacity) < 0:
            yield Violation(
                "compact-capacity",
                f"negative Compact capacity {node.capacity}", node)


@rule("param-dtypes")


def _param_dtypes(plan, db, settings, a: Analysis):
    """Param dtypes are consistent plan-wide and agree with
    `param_binding`'s runtime/compile-time classification: string params
    (and `Limit.n`) must be substituted before staging, numeric params
    must not appear where a string is expected."""
    from repro.core.passes.param_binding import plan_params

    try:
        plan_params(plan)
    except TypeError as err:
        yield Violation("param-dtypes", str(err), plan)
        return

    def numeric_params(e):
        # Params reachable in *scalar expression* position; the structural
        # positions (Str* values, Limit.n) are handled separately
        if isinstance(e, E.Param):
            yield e
        elif isinstance(e, (E.Arith, E.Cmp, E.And, E.Or)):
            yield from numeric_params(e.lhs)
            yield from numeric_params(e.rhs)
        elif isinstance(e, (E.Not, E.Year)):
            yield from numeric_params(e.operand)
        elif isinstance(e, E.Where):
            yield from numeric_params(e.cond)
            yield from numeric_params(e.then)
            yield from numeric_params(e.other)

    for node in ir.walk(plan):
        for e in _node_exprs(node):
            for param in numeric_params(e):
                if param.dtype == "str":
                    yield Violation(
                        "param-dtypes",
                        f"string parameter {param.name!r} in scalar "
                        "expression position", node)
        if isinstance(node, ir.Limit) and isinstance(node.n, E.Param):
            if not node.n.dtype.startswith("int"):
                yield Violation(
                    "param-dtypes",
                    f"Limit.n parameter {node.n.name!r} must be integer, "
                    f"got dtype {node.n.dtype!r}", node)


def _strip_transparent(node: ir.Plan) -> ir.Plan:
    """Descend through frame-transparent wrappers (Compact re-packs rows,
    Project adds columns — neither changes the partition state), so the
    Exchange rules see the node a consumer physically reads."""
    while isinstance(node, (ir.Compact, ir.Project)):
        node = node.child
    return node


def _co_partitioned(j: ir.Join, build_info, stream_info) -> bool:
    """pk_gather crosses no shard boundary iff the probe side is
    partitioned on the build table's own range partition."""
    return (build_info.part is not None
            and stream_info.part == build_info.part
            and build_info.part == j.build_table)


@rule("shard-invariance")


def _shard_invariance(plan, db, settings, a: Analysis):
    """No partitioned frame reaches an operator whose lowering would see
    only a shard-local slice: global Sort/Limit, generic (sort-based)
    aggregation, generic/bucket_gather join builds, pk_gather builds not
    co-partitioned with their probe side, and the plan output itself.
    The Sharding pass plants a gather Exchange at each of these sites —
    this rule turns a missing one into a verify failure instead of a
    silently partial answer.  exists_flag builds and scalar/dense Agg
    inputs may stay partitioned: their operators combine shard-local
    partials in place (pmax flag union resp. psum/pmin/pmax)."""
    for node in ir.walk(plan):
        if isinstance(node, (ir.Sort, ir.Limit)):
            ci = a.info(node.child)
            if ci.part is not None:
                yield Violation(
                    "shard-invariance",
                    f"{type(node).__name__} over a frame partitioned on "
                    f"{ci.part!r} — a per-shard order is not a global "
                    "order", node)
        elif isinstance(node, ir.Agg):
            if node.strategy in ("scalar", "dense") or not node.group_by:
                continue
            ci = a.info(node.child)
            if ci.part is not None:
                yield Violation(
                    "shard-invariance",
                    "generic (sort-based) Agg over a frame partitioned on "
                    f"{ci.part!r} — shard-local groups would not merge",
                    node)
        elif isinstance(node, ir.Join):
            bi = a.info(node.build)
            if bi.part is None or node.strategy == "exists_flag":
                continue
            if node.strategy == "pk_gather":
                if not _co_partitioned(node, bi, a.info(node.stream)):
                    yield Violation(
                        "shard-invariance",
                        f"pk_gather build partitioned on {bi.part!r} is "
                        "not co-partitioned with its probe side "
                        f"(stream part={a.info(node.stream).part!r}, "
                        f"build_table={node.build_table!r})", node)
            else:
                yield Violation(
                    "shard-invariance",
                    f"{node.strategy} join build partitioned on "
                    f"{bi.part!r} — the strategy reads the whole build "
                    "frame", node)
    if a.info(plan).part is not None:
        yield Violation(
            "shard-invariance",
            f"plan output is partitioned on {a.info(plan).part!r} — the "
            "caller sees one shard's block", plan)


@rule("exchange-placement")


def _exchange_placement(plan, db, settings, a: Analysis):
    """Every Exchange is load-bearing: a known kind, a partitioned child,
    and a position directly below an eligible consumer (join build,
    Sort/Limit, generic Agg, or the plan root — modulo frame-transparent
    Compact/Project wrappers).  A co-partitioned pk_gather build must NOT
    be gathered: the gather would materialize the full parent on every
    shard and defeat the partitioning it verifies against."""
    parents: dict[int, ir.Plan] = {}
    for node in ir.walk(plan):
        for c in ir.children(node):
            parents[id(c)] = node
    for node in ir.walk(plan):
        if isinstance(node, ir.Join) and node.strategy == "pk_gather":
            below = _strip_transparent(node.build)
            if isinstance(below, ir.Exchange):
                if _co_partitioned(node, a.info(below.child),
                                   a.info(node.stream)):
                    yield Violation(
                        "exchange-placement",
                        "gather Exchange on a co-partitioned pk_gather "
                        "build — the probe is already shard-local", node)
        if not isinstance(node, ir.Exchange):
            continue
        if node.kind != "gather":
            yield Violation(
                "exchange-placement",
                f"unknown Exchange kind {node.kind!r}", node)
        if a.info(node.child).part is None:
            yield Violation(
                "exchange-placement",
                "Exchange over a replicated frame — nothing to gather",
                node)
        cur, par = node, parents.get(id(node))
        while par is not None and isinstance(par, (ir.Compact, ir.Project)):
            cur, par = par, parents.get(id(par))
        ok = (par is None
              or isinstance(par, (ir.Sort, ir.Limit))
              or (isinstance(par, ir.Join) and par.build is cur
                  and par.strategy != "exists_flag")
              or (isinstance(par, ir.Agg)
                  and par.strategy not in ("scalar", "dense")
                  and bool(par.group_by)))
        if not ok:
            yield Violation(
                "exchange-placement",
                f"Exchange below {type(par).__name__} — not an eligible "
                "consumer (join build, Sort/Limit, generic Agg, or plan "
                "root)", node)


@rule("exchange-count", final_only=True)


def _exchange_count(plan, db, settings, a: Analysis):
    """Per-query Exchange budget: at most one per co-partitioning
    violation — non-co-partitioned join builds, global Sort/Limit and
    generic Agg inputs that are partitioned, and a partitioned plan
    output.  With exchange-placement pinning each Exchange directly
    below such a site, a pass that starts spraying gathers fails
    verification instead of silently serializing the query."""
    n_exchange = sum(isinstance(n, ir.Exchange) for n in ir.walk(plan))
    if n_exchange == 0:
        return
    sites = 0
    for node in ir.walk(plan):
        if isinstance(node, ir.Join):
            if node.strategy == "exists_flag":
                continue
            below = _strip_transparent(node.build)
            inner = below.child if isinstance(below, ir.Exchange) else below
            ii = a.info(inner)
            if ii.part is None:
                continue
            if node.strategy == "pk_gather" and _co_partitioned(
                    node, ii, a.info(node.stream)):
                continue
            sites += 1
        elif isinstance(node, (ir.Sort, ir.Limit)) or (
                isinstance(node, ir.Agg)
                and node.strategy not in ("scalar", "dense")
                and node.group_by):
            below = _strip_transparent(node.child)
            inner = below.child if isinstance(below, ir.Exchange) else below
            if a.info(inner).part is not None:
                sites += 1
    top = _strip_transparent(plan)
    top_in = top.child if isinstance(top, ir.Exchange) else top
    if a.info(top_in).part is not None:
        sites += 1
    if n_exchange > sites:
        yield Violation(
            "exchange-count",
            f"{n_exchange} Exchange nodes for {sites} co-partitioning "
            "violations — at least one gather is gratuitous", plan)


@rule("key-pack", final_only=True)


def _key_pack(plan, db, settings, a: Analysis):
    """A fully lowered generic composite join packs `k1 * K2 + k2` into
    uint32; the bound derived from load-time stats must fit or the pack
    wraps and matches garbage.  Final-only: Partitioning may still lower
    the join to `bucket_gather`, which never packs."""
    for node in ir.walk(plan):
        if (not isinstance(node, ir.Join) or node.strategy != "generic"
                or node.stream_key2 is None or node.build_key2 is None):
            continue
        sci = a.col(node.stream, node.stream_key)
        bci = a.col(node.build, node.build_key)
        s2 = a.col(node.stream, node.stream_key2)
        b2 = a.col(node.build, node.build_key2)
        k2_maxes = [int(ci.hi) for ci in (s2, b2)
                    if ci is not None and ci.hi is not None]
        k1_maxes = [int(ci.hi) for ci in (sci, bci)
                    if ci is not None and ci.hi is not None]
        k1_max = max(k1_maxes) if k1_maxes else None
        K2, packed = composite_pack_bound(k1_max, k2_maxes)
        if packed is not None and packed >= 2**32:
            yield Violation(
                "key-pack",
                f"composite join key ({node.stream_key},"
                f"{node.stream_key2}) cannot pack into uint32: "
                f"max_k1={k1_max} * K2={K2} + {K2 - 1} = {packed} "
                ">= 2**32", node)
