"""Plan fuzzer: seeded random TPC-H plans through every preset rung.

Generates schema-valid plans by construction (columns drawn from the
live table schemas, join keys from declared FKs, constants from load-time
stats), then checks two properties on each:

  * **verifier-clean** — `optimize()` with `verify_passes` on must accept
    the plan at every preset rung: the generator and the verifier agree
    on what a well-formed plan is, and no pass miscompiles it into an
    ill-formed one.
  * **oracle equivalence** — compiled execution must match the
    interpreted Volcano engine row-for-row (sort-insensitive, float
    tolerance), so the pass pipeline preserves semantics on plan shapes
    nobody hand-wrote.

The generator deliberately covers the shapes the passes specialize on:
FK join chains (pk_gather), the composite lineitem->partsupp join
(bucket_gather / uint32 packing), semi/anti joins (exists_flag), date
range predicates (DateIndex), CAT predicates and group keys
(StringDictionary / dense lowering), selective conjunctions (Compaction),
group-key Sorts with Limit (top-k rewrite).

CLI (nightly CI):  python -m repro.core.analysis.fuzz --n 200
writes BENCH_fuzz.json and exits nonzero on any violation or drift.
"""
from __future__ import annotations

import copy
import dataclasses
from typing import Optional

import numpy as np

from repro.core import expr as E
from repro.core import ir
from repro.relational.loader import Database
from repro.relational.schema import ColKind

# stream table -> (stream fk col, build table, build pk col); chains are
# discovered dynamically: an inner join exposes the parent's own FKs.
FK_JOINS: dict[str, list[tuple[str, str, str]]] = {
    "lineitem": [
        ("l_orderkey", "orders", "o_orderkey"),
        ("l_partkey", "part", "p_partkey"),
        ("l_suppkey", "supplier", "s_suppkey"),
    ],
    "orders": [("o_custkey", "customer", "c_custkey")],
    "customer": [("c_nationkey", "nation", "n_nationkey")],
    "partsupp": [
        ("ps_partkey", "part", "p_partkey"),
        ("ps_suppkey", "supplier", "s_suppkey"),
    ],
    "supplier": [("s_nationkey", "nation", "n_nationkey")],
    "part": [],
    "nation": [],
    "region": [],
}

BASE_TABLES = [
    "lineitem",
    "lineitem",
    "orders",
    "orders",
    "partsupp",
    "customer",
    "supplier",
]

# lineitem col-vs-col date compares (the correlated-conjunct shapes the
# compaction clamp measures)
_DATE_PAIRS = [
    ("l_shipdate", "l_commitdate"),
    ("l_commitdate", "l_receiptdate"),
    ("l_shipdate", "l_receiptdate"),
]


def _is_key(schema, name: str) -> bool:
    return name in schema.primary_key or schema.fk_for(name) is not None


def _pred_for(
    rng: np.random.Generator, db: Database, table: str, name: str
) -> Optional[E.Expr]:
    """One random predicate over a single column, bounds from stats."""
    t = db.table(table)
    kind = t.schema.col(name).kind
    st = t.stats.get(name)
    if kind in (ColKind.FLOAT, ColKind.INT, ColKind.DATE):
        if st is None or st.max <= st.min:
            return None
        lo = float(rng.uniform(st.min, st.max))
        hi = float(rng.uniform(lo, st.max))
        if kind != ColKind.FLOAT:
            lo, hi = float(int(lo)), float(int(hi) + 1)
        def mk(v):
            return E.lit(int(v)) if kind != ColKind.FLOAT else E.lit(v)

        form = rng.integers(3)
        if form == 0:
            return E.Cmp("<", E.col(name), mk(hi))
        if form == 1:
            return E.Cmp(">=", E.col(name), mk(lo))
        return E.And(
            E.Cmp(">=", E.col(name), mk(lo)), E.Cmp("<", E.col(name), mk(hi))
        )
    if kind == ColKind.CAT:
        vocab = t.vocabs.get(name)
        if vocab is None or len(vocab) == 0:
            return None
        if len(vocab) > 1 and rng.integers(2):
            k = int(rng.integers(1, min(3, len(vocab)) + 1))
            picks = rng.choice(len(vocab), size=k, replace=False)
            return E.StrIn(name, tuple(str(vocab[i]) for i in sorted(picks)))
        v = str(vocab[rng.integers(len(vocab))])
        return E.StrEq(name, v, negate=bool(len(vocab) > 1 and rng.integers(4) == 0))
    return None  # TEXT: word predicates need curated words; skip


def _random_conjunction(rng, db, table: str, n: int) -> Optional[E.Expr]:
    schema = db.table(table).schema
    cands = [
        c.name
        for c in schema.columns
        if c.kind != ColKind.TEXT and not _is_key(schema, c.name)
    ]
    parts: list[E.Expr] = []
    if table == "lineitem" and rng.integers(3) == 0:
        a, b = _DATE_PAIRS[rng.integers(len(_DATE_PAIRS))]
        parts.append(E.Cmp("<", E.col(a), E.col(b)))
    while len(parts) < n and cands:
        name = cands.pop(int(rng.integers(len(cands))))
        p = _pred_for(rng, db, table, name)
        if p is not None:
            parts.append(p)
    if not parts:
        return None
    out = parts[0]
    for p in parts[1:]:
        out = E.And(out, p)
    return out


def random_plan(rng: np.random.Generator, db: Database) -> ir.Plan:
    """One schema-valid random plan (deterministic in `rng`'s state)."""
    base = BASE_TABLES[rng.integers(len(BASE_TABLES))]
    plan: ir.Plan = ir.Scan(base)
    # columns available on the stream frame, per source table
    avail_tables = [base]

    pred = _random_conjunction(rng, db, base, int(rng.integers(1, 4)))
    if pred is not None:
        plan = ir.Select(plan, pred)

    # composite lineitem->partsupp join (bucket_gather / uint32 pack paths)
    if base == "lineitem" and rng.integers(3) == 0:
        plan = ir.Join(
            plan,
            ir.Scan("partsupp"),
            "l_partkey",
            "ps_partkey",
            stream_key2="l_suppkey",
            build_key2="ps_suppkey",
        )
        avail_tables.append("partsupp")

    # FK join chain: each inner join exposes the parent's own FKs
    fks = list(FK_JOINS[base])
    for _ in range(int(rng.integers(3))):
        if not fks:
            break
        skey, btable, bkey = fks.pop(int(rng.integers(len(fks))))
        if btable in avail_tables:
            continue
        build: ir.Plan = ir.Scan(btable)
        if rng.integers(2):
            bpred = _random_conjunction(rng, db, btable, int(rng.integers(1, 3)))
            if bpred is not None:
                build = ir.Select(build, bpred)
        kind = ["inner", "inner", "inner", "semi", "anti"][rng.integers(5)]
        plan = ir.Join(plan, build, skey, bkey, kind=kind)
        if kind == "inner":
            avail_tables.append(btable)
            fks.extend(FK_JOINS[btable])

    def cols_of(kinds) -> list[tuple[str, str]]:
        out = []
        for tn in avail_tables:
            for c in db.table(tn).schema.columns:
                if c.kind in kinds:
                    out.append((tn, c.name))
        return out

    if rng.integers(3):  # 2/3 of plans aggregate
        floats = cols_of((ColKind.FLOAT,))
        cats = cols_of((ColKind.CAT,))
        grouped = bool(cats) and rng.integers(4) > 0
        aggs: list[ir.AggSpec] = []
        fns = ["sum", "avg", "min", "max"] if grouped else ["sum"]
        for i in range(int(rng.integers(1, 4))):
            if not floats or rng.integers(4) == 0:
                aggs.append(ir.AggSpec(f"a{i}", "count"))
            else:
                _, fname = floats[rng.integers(len(floats))]
                aggs.append(
                    ir.AggSpec(f"a{i}", fns[rng.integers(len(fns))], E.col(fname))
                )
        if not grouped:
            return ir.Agg(plan, [], aggs)
        nkeys = int(rng.integers(1, min(2, len(cats)) + 1))
        picks = rng.choice(len(cats), size=nkeys, replace=False)
        keys = [cats[i][1] for i in picks]
        plan = ir.Agg(plan, keys, aggs)
        plan = ir.Sort(plan, [(k, True) for k in keys])
        if rng.integers(5) < 2:
            # group keys are unique above the Agg -> deterministic top-k
            plan = ir.Limit(plan, int(rng.integers(1, 21)))
        return plan

    # non-aggregating plan: cap the output with a narrowing Project
    scalars = cols_of((ColKind.INT, ColKind.FLOAT, ColKind.DATE, ColKind.CAT))
    n = int(rng.integers(2, min(5, len(scalars)) + 1))
    picks = rng.choice(len(scalars), size=n, replace=False)
    rename = rng.integers(3) == 0
    outputs = {}
    for j, i in enumerate(picks):
        _, cname = scalars[i]
        outputs[f"x{j}" if rename else cname] = E.col(cname)
    return ir.Project(plan, outputs, keep_input=False)


# ---------------------------------------------------------------------------
# oracle-equivalence checking (mirrors tests/test_queries.py's canon)
# ---------------------------------------------------------------------------


def _canon(res: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
    names = sorted(res)
    keys = []
    for k in names:
        v = np.asarray(res[k])
        keys.append(np.round(v.astype(np.float64), 2) if v.dtype.kind == "f" else v)
    order = np.lexsort(tuple(reversed(keys)))
    return {k: np.asarray(res[k])[order] for k in names}


def results_match(a: dict, b: dict) -> Optional[str]:
    """None when equivalent, else a one-line description of the drift."""
    if set(a) != set(b):
        return f"columns differ: {sorted(a)} vs {sorted(b)}"
    if not a:
        return None
    na = {len(np.asarray(v)) for v in a.values()}
    nb = {len(np.asarray(v)) for v in b.values()}
    if na != nb:
        return f"row counts differ: {na} vs {nb}"
    ca, cb = _canon(a), _canon(b)
    for k in ca:
        va, vb = ca[k], cb[k]
        if va.dtype.kind == "f" or vb.dtype.kind == "f":
            if not np.allclose(
                va.astype(np.float64),
                vb.astype(np.float64),
                rtol=2e-3,
                atol=1e-2,
                equal_nan=True,
            ):
                return f"column {k}: values drift"
        elif not np.array_equal(va, vb):
            return f"column {k}: values differ"
    return None


@dataclasses.dataclass


class FuzzReport:
    n_plans: int = 0
    n_optimized: int = 0
    n_compiled: int = 0
    failures: list = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.failures


def run_fuzz(
    db: Database,
    n: int,
    presets: Optional[list[str]] = None,
    seed0: int = 0,
    compile_presets: Optional[list[str]] = None,
    compile_every: int = 1,
    verbose: bool = False,
) -> FuzzReport:
    """Fuzz `n` seeded plans.

    Every plan runs through `optimize()` (verifier on) at each rung in
    `presets`; every `compile_every`-th plan additionally compiles at each
    rung in `compile_presets` and is compared against the Volcano oracle.
    """
    # imported here, not at module top: the compile stack (JAX) is heavy
    # and analysis/__init__ must stay importable from the passes alone
    from repro.core.compile import CompiledQuery
    from repro.core.passes.pipeline import LADDER, preset
    from repro.core.volcano import VolcanoEngine

    # the opt-pallas and opt-shard rungs ride along by default: same plans,
    # same oracle, exercising the fused kernel paths (interpret mode on
    # CPU) and the Exchange-planting pass + its verifier rules.  opt-shard
    # stays out of compile_presets: CompiledQueryBatch and single-device
    # CI hosts don't compose with a >1 mesh, and the optimize rung is
    # where the sharding invariants live.
    presets = (presets if presets is not None
               else list(LADDER) + ["opt-pallas", "opt-shard"])
    compile_presets = (
        compile_presets if compile_presets is not None
        else ["naive", "opt", "opt-pallas"]
    )
    oracle = VolcanoEngine(db)
    rep = FuzzReport()
    for i in range(n):
        seed = seed0 + i
        rng = np.random.default_rng(seed)
        plan = random_plan(rng, db)
        rep.n_plans += 1
        for pname in presets:
            try:
                from repro.core.passes.pipeline import optimize

                optimize(copy.deepcopy(plan), db, preset(pname))
                rep.n_optimized += 1
            except Exception as err:
                rep.failures.append(
                    {
                        "seed": seed,
                        "preset": pname,
                        "stage": "optimize",
                        "error": f"{type(err).__name__}: {err}",
                        "plan": ir.plan_repr(plan),
                    }
                )
        if compile_every <= 0 or i % compile_every:
            continue
        try:
            want = oracle.execute(copy.deepcopy(plan))
        except Exception as err:
            rep.failures.append(
                {
                    "seed": seed,
                    "preset": "volcano",
                    "stage": "oracle",
                    "error": f"{type(err).__name__}: {err}",
                    "plan": ir.plan_repr(plan),
                }
            )
            continue
        for pname in compile_presets:
            try:
                got = CompiledQuery(copy.deepcopy(plan), db, preset(pname)).run()
                drift = results_match(got, want)
                rep.n_compiled += 1
            except Exception as err:
                drift = f"{type(err).__name__}: {err}"
            if drift is not None:
                rep.failures.append(
                    {
                        "seed": seed,
                        "preset": pname,
                        "stage": "execute",
                        "error": drift,
                        "plan": ir.plan_repr(plan),
                    }
                )
        if verbose and (i + 1) % 25 == 0:
            print(f"  fuzz: {i + 1}/{n} plans, {len(rep.failures)} failures")
    return rep


def main(argv: Optional[list[str]] = None) -> int:
    import argparse
    import json
    import time

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--n", type=int, default=200)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sf", type=float, default=0.01)
    ap.add_argument(
        "--compile-every",
        type=int,
        default=1,
        help="compile+execute every k-th plan (0 = never)",
    )
    ap.add_argument("--out", default="BENCH_fuzz.json")
    args = ap.parse_args(argv)

    db = Database.tpch(sf=args.sf, seed=0)
    t0 = time.time()
    rep = run_fuzz(
        db, args.n, seed0=args.seed, compile_every=args.compile_every, verbose=True
    )
    wall = time.time() - t0
    out = {
        "n_plans": rep.n_plans,
        "n_optimized": rep.n_optimized,
        "n_compiled": rep.n_compiled,
        "wall_s": round(wall, 2),
        "failures": rep.failures[:20],
        "n_failures": len(rep.failures),
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(
        f"fuzz: {rep.n_plans} plans, {rep.n_optimized} optimizes, "
        f"{rep.n_compiled} compiles, {len(rep.failures)} failures "
        f"({wall:.1f}s) -> {args.out}"
    )
    for fail in rep.failures[:5]:
        print(
            f"  seed={fail['seed']} preset={fail['preset']} "
            f"[{fail['stage']}] {fail['error']}"
        )
    return 0 if rep.ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
