"""Bottom-up schema and provenance inference over the plan IR.

The paper's specialization passes all rest on *schema + statistics
knowledge*: which base column a plan column descends from, what its dtype
family is, and how large its value domain can get.  Before this module each
pass re-derived that knowledge with its own recursive plan walk
(`passes/provenance.py`, `compaction._base_column`, join's `_stats_max`);
here it is computed once, bottom-up, as a `{name: ColInfo}` schema per plan
node, and the passes (plus the inter-pass verifier in `analysis/verify.py`)
consume the shared result.

Dtype families (`ColInfo.dtype`) collapse the physical kinds of
`relational/schema.py` into what plan-level reasoning needs:

  'int'    — int32 scalars (keys, quantities, counts, Year() results)
  'float'  — float32 scalars
  'date'   — int32 days-since-epoch
  'code'   — CAT dictionary codes (int32 at runtime, but joining or
             arithmetic against plain ints is almost always a plan bug)
  'string' — TEXT word matrices (never scalar-comparable)
  'bool'   — predicate results materialized through Project outputs

Inference failures (a dangling `Col`, a `Scan` naming an unknown column)
raise `SchemaError`; the verifier converts that into a
`PlanInvariantError` attributed to the pass that produced the plan.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import expr as E
from repro.core import ir
from repro.relational.schema import ColKind

_KIND_DTYPE = {
    ColKind.INT: "int",
    ColKind.FLOAT: "float",
    ColKind.DATE: "date",
    ColKind.CAT: "code",
    ColKind.TEXT: "string",
}

# integer stats-derived domains beyond this are treated as unbounded
# (mirrors the historical provenance.col_domain cutoff)
_DOMAIN_CUTOFF = 1 << 20


class SchemaError(Exception):
    """A plan node references a column its input does not produce."""

    def __init__(self, node: Optional[ir.Plan], message: str):
        super().__init__(message)
        self.node = node


@dataclasses.dataclass(frozen=True)


class ColInfo:
    """Static knowledge about one output column of a plan node.

    `table`/`col` name the base column this one descends from (None for
    computed expressions — they have a dtype but no provenance).  `parent`
    is the table whose dense primary key this column's values index (its
    own table for a PK, the referenced table for a FK) — the fact the
    partitioning pass keys on.  `domain` is a static exclusive upper bound
    on non-negative values (vocabulary size for CAT, parent row count for
    key columns, stats-derived for small ints); `lo`/`hi` are the
    load-time min/max stats where available.
    """

    dtype: str
    table: Optional[str] = None
    col: Optional[str] = None
    parent: Optional[str] = None
    domain: Optional[int] = None
    lo: Optional[float] = None
    hi: Optional[float] = None


Schema = dict[str, ColInfo]

# expression nodes that consume string/code columns by name
_STRING_EXPRS = (
    E.StrEq,
    E.StrIn,
    E.StrStartsWith,
    E.StrContainsWord,
    E.CodeEq,
    E.CodeIn,
    E.CodeRange,
    E.WordCode,
)


def expr_dtype(e: E.Expr, schema: Schema, node: Optional[ir.Plan] = None) -> str:
    """Dtype family of an expression over `schema` (raises SchemaError on a
    dangling Col so Project/Agg inference surfaces bad references)."""
    if isinstance(e, E.Col):
        ci = schema.get(e.name)
        if ci is None:
            raise SchemaError(
                node, f"column {e.name!r} is not produced by the input")
        return ci.dtype
    if isinstance(e, E.Const):
        if isinstance(e.value, bool):
            return "bool"
        return "int" if isinstance(e.value, int) else "float"
    if isinstance(e, E.Param):
        if e.dtype == "str":
            return "string"
        if e.dtype == "bool":
            return "bool"
        return "int" if e.dtype.startswith("int") else "float"
    if isinstance(e, E.Arith):
        a = expr_dtype(e.lhs, schema, node)
        b = expr_dtype(e.rhs, schema, node)
        if e.op == "/" or "float" in (a, b):
            return "float"
        return "int"
    if isinstance(e, E.Where):
        expr_dtype(e.cond, schema, node)
        a = expr_dtype(e.then, schema, node)
        b = expr_dtype(e.other, schema, node)
        if a == b:
            return a
        return "float" if "float" in (a, b) else a
    if isinstance(e, E.Year):
        expr_dtype(e.operand, schema, node)
        return "int"
    if isinstance(e, (E.Cmp, E.And, E.Or, E.Not)):
        for sub in _expr_operands(e):
            expr_dtype(sub, schema, node)
        return "bool"
    if isinstance(e, _STRING_EXPRS):
        if e.col not in schema:
            raise SchemaError(node, f"column {e.col!r} is not produced by the input")
        return "bool"
    raise SchemaError(node, f"unknown expression node {type(e).__name__}")


def _expr_operands(e: E.Expr):
    if isinstance(e, (E.Arith, E.Cmp, E.And, E.Or)):
        return (e.lhs, e.rhs)
    if isinstance(e, (E.Not, E.Year)):
        return (e.operand,)
    if isinstance(e, E.Where):
        return (e.cond, e.then, e.other)
    return ()


def base_colinfo(table_name: str, name: str, db) -> ColInfo:
    """ColInfo of a base table column, from schema declarations + stats.

    Cached on the Table (analysis re-derives base schemas on every
    optimize); the stats signature revalidates each hit because tests and
    reload paths mutate `Table.stats` in place."""
    t = db.table(table_name)
    st = t.stats.get(name)
    sig = (st.min, st.max, st.n_distinct) if st is not None else None
    hit = t._colinfo_cache.get(name)
    if hit is not None and hit[0] == sig:
        return hit[1]
    sch = t.schema
    cdef = sch.col(name)
    dtype = _KIND_DTYPE[cdef.kind]
    parent: Optional[str] = None
    if sch.primary_key == (name,):
        parent = table_name
    else:
        fk = sch.fk_for(name)
        if fk is not None:
            parent = fk.ref_table
    lo = hi = None
    if st is not None and cdef.kind in (ColKind.INT, ColKind.FLOAT,
                                        ColKind.DATE, ColKind.CAT):
        lo, hi = float(st.min), float(st.max)
    domain: Optional[int] = None
    if cdef.kind == ColKind.CAT:
        domain = len(t.vocabs[name])
    elif cdef.kind == ColKind.INT:
        if parent is not None:
            domain = db.table(parent).nrows
        elif st is not None and st.min >= 0 and st.max < _DOMAIN_CUTOFF:
            domain = int(st.max) + 1
    ci = ColInfo(dtype, table_name, name, parent, domain, lo, hi)
    t._colinfo_cache[name] = (sig, ci)
    return ci


def _scan_schema(p: ir.Scan, db, kids: list[Schema]) -> Schema:
    sch = db.table(p.table).schema
    names = p.columns if p.columns is not None else sch.column_names
    out: Schema = {}
    for name in names:
        if not sch.has_col(name):
            raise SchemaError(
                p, f"scan of {p.table!r} names unknown column {name!r}")
        out[name] = base_colinfo(p.table, name, db)
    return out


def _passthrough_schema(p, db, kids: list[Schema]) -> Schema:
    return kids[0]


def _project_schema(p: ir.Project, db, kids: list[Schema]) -> Schema:
    child = kids[0]
    out = dict(child) if p.keep_input else {}
    for name, e in p.outputs.items():
        if isinstance(e, E.Col):
            ci = child.get(e.name)
            if ci is None:
                raise SchemaError(
                    p,
                    f"project output {name!r} renames {e.name!r}, "
                    "which the input does not produce",
                )
            out[name] = ci
        else:
            out[name] = ColInfo(expr_dtype(e, child, p))
    return out


def _join_schema(p: ir.Join, db, kids: list[Schema]) -> Schema:
    stream, build = kids
    if p.kind in ("semi", "anti"):
        return stream
    out = dict(stream)
    for name, ci in build.items():
        out.setdefault(name, ci)
    return out


def _agg_schema(p: ir.Agg, db, kids: list[Schema]) -> Schema:
    child = kids[0]
    out = {}
    for name in list(p.group_by) + list(p.carry):
        ci = child.get(name)
        if ci is None:
            raise SchemaError(
                p, f"group/carry column {name!r} is not produced by the input"
            )
        out[name] = ci
    for spec in p.aggs:
        if spec.fn == "count":
            dt = "int"
        elif spec.fn == "avg":
            dt = "float"
        elif spec.expr is not None:
            dt = expr_dtype(spec.expr, child, p)
        else:
            dt = "int"
        out[spec.name] = ColInfo(dt)
    return out


# analyze() runs per pass per optimize: dispatch on type, not an
# isinstance chain (measurably cheaper on the ~10-node TPC-H plans)
_SCHEMA_FNS = {
    ir.Scan: _scan_schema,
    ir.Select: _passthrough_schema,
    ir.Compact: _passthrough_schema,
    ir.Exchange: _passthrough_schema,
    ir.Sort: _passthrough_schema,
    ir.Limit: _passthrough_schema,
    ir.Project: _project_schema,
    ir.Join: _join_schema,
    ir.Agg: _agg_schema,
}


def node_schema(p: ir.Plan, db, kids: list[Schema]) -> Schema:
    """Output schema of `p` given its children's schemas (one dataflow
    step; `schema_of` / `analysis.properties.analyze` run the fixpoint)."""
    fn = _SCHEMA_FNS.get(type(p))
    if fn is None:
        raise TypeError(type(p))
    return fn(p, db, kids)


def schema_of(p: ir.Plan, db) -> Schema:
    """Output schema of a plan subtree (un-memoized convenience wrapper —
    use `analysis.properties.analyze` when querying many nodes)."""
    return node_schema(p, db, [schema_of(c, db) for c in ir.children(p)])
