"""Jitted public wrappers around the Pallas kernels.

`interpret=True` (default on CPU) executes the kernel bodies in the Pallas
interpreter for validation; on TPU pass interpret=False to run the compiled
Mosaic kernels.  `filter_agg_query` is the integration point used by
`repro.core.compile` when `Settings.use_pallas` is on.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.filter_agg import filter_agg
from repro.kernels.gather_join import gather_join
from repro.kernels.topk import masked_topk

__all__ = ["filter_agg", "gather_join", "masked_topk", "filter_agg_query"]


def filter_agg_query(mask, gidx, value_cols, n_groups, *, interpret=True):
    """Aggregate a list of 1-D value columns (plus an implicit count column)
    in one fused kernel pass.  Returns (sums (G, A), counts (G,))."""
    ones = jnp.ones_like(mask, dtype=jnp.float32)
    vals = jnp.stack(list(value_cols) + [ones], axis=1).astype(jnp.float32)
    out = filter_agg(mask, gidx.astype(jnp.int32), vals, n_groups,
                     interpret=interpret)
    return out[:, :-1], out[:, -1]
