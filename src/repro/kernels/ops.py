"""Jitted public wrappers around the Pallas kernels.

`interpret` selects the Pallas execution mode: `None` (the default)
auto-detects — compiled Mosaic/Triton kernels when a TPU or GPU backend is
present, the (slow, validation-only) Pallas interpreter on CPU.  Pass an
explicit bool to force either mode (`Settings.pallas_interpret` threads the
engine-level override through).  `filter_agg_query` is the integration
point used by `repro.core.operators.agg` when `Settings.use_pallas` is on;
`compact_query` / `compact_pred_query` / `selective_agg_query` are the
corresponding single-pass entry points for `operators.compact` and the
fused selective pipeline.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.compact import compact, compact_pred, compact_translate
from repro.kernels.filter_agg import filter_agg, selective_filter_agg
from repro.kernels.gather_join import gather_join
from repro.kernels.topk import masked_topk

__all__ = ["filter_agg", "gather_join", "masked_topk", "filter_agg_query",
           "compact", "compact_translate", "compact_pred", "compact_query",
           "compact_pred_query", "selective_filter_agg",
           "selective_agg_query", "resolve_interpret"]


def resolve_interpret(interpret: "bool | None") -> bool:
    """Resolve an interpret override: None = interpret only when no
    accelerator backend (TPU/GPU) is available to compile the kernels."""
    if interpret is not None:
        return bool(interpret)
    import jax

    return jax.default_backend() not in ("tpu", "gpu", "cuda", "rocm")


def filter_agg_query(mask, gidx, value_cols, n_groups, *, interpret=None):
    """Aggregate a list of 1-D value columns (plus an implicit count column)
    in one fused kernel pass.  Returns (sums (G, A), counts (G,))."""
    ones = jnp.ones_like(mask, dtype=jnp.float32)
    vals = jnp.stack(list(value_cols) + [ones], axis=1).astype(jnp.float32)
    out = filter_agg(mask, gidx.astype(jnp.int32), vals, n_groups,
                     interpret=resolve_interpret(interpret))
    return out[:, :-1], out[:, -1]


def compact_query(mask, capacity, *, translate=False, interpret=None):
    """Single-HBM-pass drop-in for `backend.compact`: (idx, count), plus
    the key→slot translation vector when `translate`."""
    return compact(mask, int(capacity), translate=translate,
                   interpret=resolve_interpret(interpret))


def compact_pred_query(cols, scalars, pred_fn, capacity, *, translate=False,
                       interpret=None):
    """Fused filter → compact: predicate evaluated in-kernel."""
    return compact_pred(cols, scalars, pred_fn, int(capacity),
                        translate=translate,
                        interpret=resolve_interpret(interpret))


def selective_agg_query(cols, scalars, pred_fn, value_fns, gidx_fn,
                        n_groups, *, interpret=None):
    """The q19-class pipeline: in-kernel predicate + grouped aggregation
    (an implicit count column is appended, mirroring `filter_agg_query`).
    Returns (sums (G, A), counts (G,), total_count)."""
    a = len(value_fns)

    def vals_fn(c, s):
        return [f(c, s) for f in value_fns] + [jnp.float32(1.0)]

    sums, total = selective_filter_agg(
        cols, scalars, pred_fn, vals_fn, gidx_fn, a + 1, n_groups,
        interpret=resolve_interpret(interpret))
    return sums[:, :-1], sums[:, -1], total
