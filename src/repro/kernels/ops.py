"""Jitted public wrappers around the Pallas kernels.

`interpret` selects the Pallas execution mode: `None` (the default)
auto-detects — compiled Mosaic/Triton kernels when a TPU or GPU backend is
present, the (slow, validation-only) Pallas interpreter on CPU.  Pass an
explicit bool to force either mode (`Settings.pallas_interpret` threads the
engine-level override through).  `filter_agg_query` is the integration
point used by `repro.core.operators.agg` when `Settings.use_pallas` is on.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.filter_agg import filter_agg
from repro.kernels.gather_join import gather_join
from repro.kernels.topk import masked_topk

__all__ = ["filter_agg", "gather_join", "masked_topk", "filter_agg_query",
           "resolve_interpret"]


def resolve_interpret(interpret: "bool | None") -> bool:
    """Resolve an interpret override: None = interpret only when no
    accelerator backend (TPU/GPU) is available to compile the kernels."""
    if interpret is not None:
        return bool(interpret)
    import jax

    return jax.default_backend() not in ("tpu", "gpu", "cuda", "rocm")


def filter_agg_query(mask, gidx, value_cols, n_groups, *, interpret=None):
    """Aggregate a list of 1-D value columns (plus an implicit count column)
    in one fused kernel pass.  Returns (sums (G, A), counts (G,))."""
    ones = jnp.ones_like(mask, dtype=jnp.float32)
    vals = jnp.stack(list(value_cols) + [ones], axis=1).astype(jnp.float32)
    out = filter_agg(mask, gidx.astype(jnp.int32), vals, n_groups,
                     interpret=resolve_interpret(interpret))
    return out[:, :-1], out[:, -1]
