from repro.kernels import ops, ref
from repro.kernels.ops import (compact, compact_pred, compact_translate,
                               filter_agg, gather_join, masked_topk,
                               selective_filter_agg)

__all__ = ["ops", "ref", "filter_agg", "gather_join", "masked_topk",
           "compact", "compact_translate", "compact_pred",
           "selective_filter_agg"]
