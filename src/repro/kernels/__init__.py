from repro.kernels import ops, ref
from repro.kernels.ops import filter_agg, gather_join, masked_topk

__all__ = ["ops", "ref", "filter_agg", "gather_join", "masked_topk"]
