"""Fused filter + grouped-aggregation Pallas TPU kernel.

The TPU-native form of the paper's specialized query loop (Fig 4b / Q1/Q6
after all optimizations): one pass over the fact table computing the
selection mask and all aggregates with **no intermediate materialization**.

Hardware adaptation: the generated-C version accumulates into a hash map
with branch-predicted `if`s; on TPU we tile rows HBM→VMEM and accumulate
every aggregate for every group with a *one-hot × values matmul on the
MXU*:

    partial[G, A] += onehot(group_idx)[T, G]^T  @  (mask * values)[T, A]

The (G, A) accumulator lives in VMEM across all grid steps (the TPU grid is
sequential, so `out_ref` accumulation is safe), i.e. the paper's
"pre-allocated, initialization-hoisted aggregation array" (§3.2.2/§3.5.2)
becomes a VMEM-resident scratch that never touches HBM until the end.

Scalar aggregation (Q6) is the G=1 special case.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(mask_ref, gidx_ref, vals_ref, out_ref, *, n_groups: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    m = mask_ref[...]                     # (T, 1) bool
    g = gidx_ref[...]                     # (T, 1) int32
    v = vals_ref[...]                     # (T, A) float32
    tile = v.shape[0]
    groups = jax.lax.broadcasted_iota(jnp.int32, (tile, n_groups), 1)
    onehot = ((g == groups) & m).astype(jnp.float32)        # (T, G)
    # MXU contraction: (G, T) @ (T, A) -> (G, A)
    out_ref[...] += jnp.dot(onehot.T, v * m.astype(jnp.float32),
                            preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("n_groups", "tile", "interpret"))
def filter_agg(mask: jax.Array, gidx: jax.Array, vals: jax.Array,
               n_groups: int, *, tile: int = 2048,
               interpret: bool = True) -> jax.Array:
    """sum of `vals[i, a]` into group `gidx[i]` where `mask[i]`.

    mask: (n,) bool; gidx: (n,) int32; vals: (n, A) float32.
    Returns (n_groups, A) float32.
    """
    n, a = vals.shape
    # --- padding to hardware-friendly tiles -------------------------------
    n_pad = (-n) % tile
    a_pad = (-a) % 128 if not interpret else 0
    g_eff = n_groups if interpret else max(8, n_groups)
    if n_pad:
        mask = jnp.pad(mask, (0, n_pad))          # padded rows masked out
        gidx = jnp.pad(gidx, (0, n_pad))
        vals = jnp.pad(vals, ((0, n_pad), (0, 0)))
    if a_pad:
        vals = jnp.pad(vals, ((0, 0), (0, a_pad)))
    n_t, a_t = vals.shape
    grid = (n_t // tile,)

    out = pl.pallas_call(
        functools.partial(_kernel, n_groups=g_eff),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile, a_t), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((g_eff, a_t), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((g_eff, a_t), jnp.float32),
        interpret=interpret,
    )(mask[:, None], gidx[:, None], vals)
    return out[:n_groups, :a]
