"""Fused filter + grouped-aggregation Pallas TPU kernel.

The TPU-native form of the paper's specialized query loop (Fig 4b / Q1/Q6
after all optimizations): one pass over the fact table computing the
selection mask and all aggregates with **no intermediate materialization**.

Hardware adaptation: the generated-C version accumulates into a hash map
with branch-predicted `if`s; on TPU we tile rows HBM→VMEM and accumulate
every aggregate for every group with a *one-hot × values matmul on the
MXU*:

    partial[G, A] += onehot(group_idx)[T, G]^T  @  (mask * values)[T, A]

The (G, A) accumulator lives in VMEM across all grid steps (the TPU grid is
sequential, so `out_ref` accumulation is safe), i.e. the paper's
"pre-allocated, initialization-hoisted aggregation array" (§3.2.2/§3.5.2)
becomes a VMEM-resident scratch that never touches HBM until the end.

Scalar aggregation (Q6) is the G=1 special case.

`selective_filter_agg` extends the same kernel into the full selective
pipeline: the predicate itself is evaluated in-kernel from named column
blocks (+ parameter scalars), and the pass optionally emits the compacted
row-id vector / key→slot translation alongside the aggregates — filter →
compact → segment-reduce in ONE pass over HBM, against ≥3 passes for the
unfused mask-then-cumsum-then-gather path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compact import _compact_body


def _kernel(mask_ref, gidx_ref, vals_ref, out_ref, *, n_groups: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    m = mask_ref[...]                     # (T, 1) bool
    g = gidx_ref[...]                     # (T, 1) int32
    v = vals_ref[...]                     # (T, A) float32
    tile = v.shape[0]
    groups = jax.lax.broadcasted_iota(jnp.int32, (tile, n_groups), 1)
    onehot = ((g == groups) & m).astype(jnp.float32)        # (T, G)
    # MXU contraction: (G, T) @ (T, A) -> (G, A)
    out_ref[...] += jnp.dot(onehot.T, v * m.astype(jnp.float32),
                            preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("n_groups", "tile", "interpret"))
def filter_agg(mask: jax.Array, gidx: jax.Array, vals: jax.Array,
               n_groups: int, *, tile: int = 2048,
               interpret: bool = True) -> jax.Array:
    """sum of `vals[i, a]` into group `gidx[i]` where `mask[i]`.

    mask: (n,) bool; gidx: (n,) int32; vals: (n, A) float32.
    Returns (n_groups, A) float32.
    """
    n, a = vals.shape
    # --- padding to hardware-friendly tiles -------------------------------
    n_pad = (-n) % tile
    a_pad = (-a) % 128 if not interpret else 0
    g_eff = _group_pad(n_groups, interpret)
    if n_pad:
        mask = jnp.pad(mask, (0, n_pad))          # padded rows masked out
        gidx = jnp.pad(gidx, (0, n_pad))
        vals = jnp.pad(vals, ((0, n_pad), (0, 0)))
    if a_pad:
        vals = jnp.pad(vals, ((0, 0), (0, a_pad)))
    n_t, a_t = vals.shape
    grid = (n_t // tile,)

    out = pl.pallas_call(
        functools.partial(_kernel, n_groups=g_eff),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile, a_t), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((g_eff, a_t), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((g_eff, a_t), jnp.float32),
        interpret=interpret,
    )(mask[:, None], gidx[:, None], vals)
    return out[:n_groups, :a]


def _group_pad(n_groups: int, interpret: bool) -> int:
    """Group-axis padding for the (G, A) VMEM accumulator: compiled TPU
    kernels need the sublane axis in multiples of 8 (f32 min tile); the
    interpreter takes any shape.  The pad tail is sliced off before the
    caller ever sees it — slicing is centralized HERE, not at call sites."""
    return n_groups if interpret else max(8, -(-n_groups // 8) * 8)


# ---------------------------------------------------------------------------
# the fused selective pipeline: predicate -> (compaction) -> segment-reduce
# ---------------------------------------------------------------------------

def _pipeline_kernel(*refs, names, n_scalars: int, pred_fn, vals_fn,
                     gidx_fn, n_rows: int, tile: int, n_vals: int,
                     g_eff: int, a_eff: int, capacity: int, translate: bool):
    """refs = [col_0..col_{C-1}, scalar_0..scalar_{S-1},
               sums, cnt, (idx), (slot)]"""
    step = pl.program_id(0)
    ncols = len(names)
    cols = {nm: refs[i][...][:, 0] for i, nm in enumerate(names)}
    scalars = [refs[ncols + i][0, 0] for i in range(n_scalars)]
    o = ncols + n_scalars
    sums_ref, cnt_ref = refs[o], refs[o + 1]
    idx_ref = refs[o + 2] if capacity > 0 else None
    slot_ref = refs[o + 3] if translate else None

    @pl.when(step == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)

    # --- predicate, masked past the padded tail ---------------------------
    m = jnp.broadcast_to(jnp.asarray(pred_fn(cols, scalars)), (tile,))
    gids = step * tile + jax.lax.broadcasted_iota(jnp.int32, (tile, 1), 0)
    m = m.astype(bool).reshape(tile, 1) & (gids < n_rows)

    # --- segment-reduce: one-hot x values on the MXU ----------------------
    vs = [jnp.broadcast_to(jnp.asarray(v, jnp.float32), (tile,))
          for v in vals_fn(cols, scalars)]
    v = jnp.stack(vs, axis=1) if vs else jnp.zeros((tile, 0), jnp.float32)
    if a_eff > n_vals:
        v = jnp.pad(v, ((0, 0), (0, a_eff - n_vals)))
    g = jnp.zeros((tile,), jnp.int32) if gidx_fn is None \
        else jnp.broadcast_to(jnp.asarray(gidx_fn(cols, scalars),
                                          dtype=jnp.int32), (tile,))
    groups = jax.lax.broadcasted_iota(jnp.int32, (tile, g_eff), 1)
    onehot = ((g.reshape(tile, 1) == groups) & m).astype(jnp.float32)
    sums_ref[...] += jnp.dot(onehot.T, v * m.astype(jnp.float32),
                             preferred_element_type=jnp.float32)

    # --- compaction: scan + pack in the same VMEM residency ---------------
    if idx_ref is not None:
        _compact_body(step, jnp.int32(capacity), m, n_rows, tile,
                      idx_ref, cnt_ref, slot_ref)
    else:
        # still report the exact valid total (the caller's count signal)
        @pl.when(step == 0)
        def _init_cnt():
            cnt_ref[0, 0] = 0
        cnt_ref[0, 0] += jnp.sum(m.astype(jnp.int32))


def selective_filter_agg(cols: dict, scalars: list, pred_fn, vals_fn,
                         gidx_fn, n_vals: int, n_groups: int,
                         capacity: int = 0, translate: bool = False, *,
                         tile: int = 1024, interpret: bool = True):
    """The whole selective pipeline in one kernel pass.

    cols: {name: (n,) array} — every column any tile function reads;
    scalars: list of () arrays (runtime parameters);
    pred_fn(cols, scalars)  -> (tile,) bool       selection predicate
    vals_fn(cols, scalars)  -> list of n_vals (tile,) f32 aggregate inputs
    gidx_fn(cols, scalars)  -> (tile,) int32 group index, or None (G=1)

    Returns (sums (n_groups, n_vals) f32, count int32[, idx int32[capacity]
    [, slot_of int32[n]]]): `count` is the exact number of predicate-true
    rows (> capacity = overflow); with `capacity > 0` the compacted row-id
    vector is emitted from the same pass, and `translate` adds the CSR
    key→slot vector over the input domain.
    """
    arrs = list(cols.values())
    n = arrs[0].shape[0]
    tile = min(tile, max(8, 1 << (max(n, 1) - 1).bit_length()))
    n_pad = (-n) % tile
    names = list(cols)
    padded = {nm: jnp.pad(a, (0, n_pad)) if n_pad else a
              for nm, a in cols.items()}
    n_t = n + n_pad
    g_eff = _group_pad(n_groups, interpret)
    a_eff = n_vals if interpret else max(128, -(-n_vals // 128) * 128)
    cap_pad = capacity + tile

    in_specs = [pl.BlockSpec((tile, 1), lambda i: (i, 0)) for _ in names]
    in_specs += [pl.BlockSpec((1, 1), lambda i: (0, 0)) for _ in scalars]
    out_shape = [jax.ShapeDtypeStruct((g_eff, a_eff), jnp.float32),
                 jax.ShapeDtypeStruct((1, 1), jnp.int32)]
    out_specs = [pl.BlockSpec((g_eff, a_eff), lambda i: (0, 0)),
                 pl.BlockSpec((1, 1), lambda i: (0, 0))]
    if capacity > 0:
        out_shape.append(jax.ShapeDtypeStruct((cap_pad, 1), jnp.int32))
        out_specs.append(pl.BlockSpec((cap_pad, 1), lambda i: (0, 0)))
    if translate:
        assert capacity > 0, "translate requires a compaction capacity"
        out_shape.append(jax.ShapeDtypeStruct((n_t, 1), jnp.int32))
        out_specs.append(pl.BlockSpec((tile, 1), lambda i: (i, 0)))

    ins = [padded[nm][:, None] for nm in names]
    ins += [jnp.asarray(s).reshape(1, 1) for s in scalars]
    res = pl.pallas_call(
        functools.partial(
            _pipeline_kernel, names=names, n_scalars=len(scalars),
            pred_fn=pred_fn, vals_fn=vals_fn, gidx_fn=gidx_fn, n_rows=n,
            tile=tile, n_vals=n_vals, g_eff=g_eff, a_eff=a_eff,
            capacity=capacity, translate=translate),
        grid=(n_t // tile,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*ins)
    out = [res[0][:n_groups, :n_vals], res[1][0, 0]]
    if capacity > 0:
        out.append(res[2][:capacity, 0])
    if translate:
        out.append(res[3][:n, 0])
    return tuple(out)
