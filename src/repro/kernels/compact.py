"""One-HBM-pass stream compaction Pallas TPU kernel.

The engine's XLA compaction path (`backend.compact`) is three unfused ops
— `cumsum(mask)`, a batched `searchsorted` over the capacity slots, and a
`clip` — i.e. three full passes over HBM for an operation the paper's
generated C performs inside the same loop that computed the mask (§3.2.2,
Fig 4b).  This kernel is the single-pass form:

  * **block-local scan in VMEM** — each grid step loads one (tile, 1) mask
    block and ranks its valid rows with an inclusive `cumsum` that never
    leaves VMEM;
  * **hierarchical block offsets across the sequential grid** — the TPU
    grid executes steps in order, so the running global offset is carried
    in the count output ref itself: step i reads the total of steps
     0..i-1, adds its block count, writes it back.  No second pass, no
    scratch;
  * **within-tile pack on the MXU** — valid rows scatter to their local
    rank via a one-hot × iota matmul (`onehot[T, T]^T @ row_ids[T, 1]`),
    the same idiom `filter_agg` uses for grouped accumulation.  Exact in
    f32 for any tile < 2**24;
  * **capacity as a prefetched scalar** (`PrefetchScalarGridSpec`) — the
    output allocation is static (JAX shapes must be), but the *store
    clamp* reads the capacity from SMEM before the grid starts, so one
    compiled kernel serves every call at a given shape;
  * **overflow semantics unchanged** — the returned count is the exact
    mask total (it may exceed `capacity`: the caller's overflow signal);
    rows past the capacity land in a `tile`-row pad region of the output
    allocation and are sliced off, never written out of bounds.

Contract (identical to `backend.compact`): `(idx int32[capacity], count
int32)` — the first `min(count, capacity)` slots hold the valid row ids in
order; pad slots are zero (in `[0, n)`, safe for clamping gathers).

`compact_translate` additionally emits the CSR-style key→slot translation
over the *parent domain*: `slot_of[row] = rank(row)` when `mask[row]` else
-1 — the structure a compact-aware `pk_gather` probes through
(`operators/join.py`), computed in the same single pass.

`compact_pred` fuses the predicate itself: instead of a precomputed mask
it takes named column blocks plus parameter scalars and evaluates a
caller-supplied tile function in-kernel, so filter → compact is one HBM
pass over the columns (the selective-pipeline building block; see
`filter_agg.selective_filter_agg` for the version that also aggregates).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _compact_body(step, cap, m, n_rows, tile, idx_ref, cnt_ref, slot_ref):
    """Shared per-tile body: rank, pack, store.  `m` is the (tile, 1) bool
    mask block for grid step `step`; `cap` the clamp capacity."""
    @pl.when(step == 0)
    def _init():
        idx_ref[...] = jnp.zeros_like(idx_ref)
        cnt_ref[0, 0] = 0
        # (slot_ref blocks are per-step: every block is fully written below)

    # mask off the padded tail rows (global row id >= n_rows)
    gids = step * tile + jax.lax.broadcasted_iota(jnp.int32, (tile, 1), 0)
    m = m & (gids < n_rows)

    off = cnt_ref[0, 0]                     # total of steps 0..step-1
    lc = jnp.cumsum(m.astype(jnp.int32), axis=0)    # VMEM-local scan
    k = lc[-1, 0]                           # this block's valid count
    rank = lc - 1                           # local rank of each valid row
    # pack: one-hot(rank) scatters row ids to the front (MXU contraction)
    u = jax.lax.broadcasted_iota(jnp.int32, (tile, tile), 1)
    onehot = (m & (rank == u)).astype(jnp.float32)
    packed = jnp.dot(onehot.T, gids.astype(jnp.float32),
                     preferred_element_type=jnp.float32).astype(jnp.int32)
    filled = jax.lax.broadcasted_iota(jnp.int32, (tile, 1), 0) < k
    packed = jnp.where(filled, packed, 0)
    # dynamic-slice store at the running offset, clamped to the capacity:
    # an overflowing block writes into the idx allocation's tile-row pad
    # region (sliced off by the wrapper) — never out of bounds
    idx_ref[pl.ds(jnp.minimum(off, cap), tile), :] = packed
    if slot_ref is not None:
        slot_ref[...] = jnp.where(m, off + rank, -1)
    cnt_ref[0, 0] = off + k


def _mask_kernel(cap_ref, mask_ref, idx_ref, cnt_ref, *rest, n_rows: int,
                 tile: int):
    slot_ref = rest[0] if rest else None
    _compact_body(pl.program_id(0), cap_ref[0], mask_ref[...], n_rows, tile,
                  idx_ref, cnt_ref, slot_ref)


@functools.partial(jax.jit,
                   static_argnames=("capacity", "tile", "interpret",
                                    "translate"))
def compact(mask: jax.Array, capacity: int, *, tile: int = 1024,
            interpret: bool = True, translate: bool = False):
    """Single-pass `(idx int32[capacity], count int32)` over a boolean
    mask; with `translate=True` also returns `slot_of int32[n]` (-1 on
    invalid rows, else the row's compacted slot)."""
    n = mask.shape[0]
    tile = min(tile, max(8, 1 << (max(n, 1) - 1).bit_length()))
    n_pad = (-n) % tile
    if n_pad:
        mask = jnp.pad(mask, (0, n_pad))
    n_t = n + n_pad
    cap_pad = capacity + tile     # overflow spill region (sliced off)

    out_shape = [jax.ShapeDtypeStruct((cap_pad, 1), jnp.int32),
                 jax.ShapeDtypeStruct((1, 1), jnp.int32)]
    out_specs = [pl.BlockSpec((cap_pad, 1), lambda i, c: (0, 0)),
                 pl.BlockSpec((1, 1), lambda i, c: (0, 0))]
    if translate:
        out_shape.append(jax.ShapeDtypeStruct((n_t, 1), jnp.int32))
        out_specs.append(pl.BlockSpec((tile, 1), lambda i, c: (i, 0)))

    res = pl.pallas_call(
        functools.partial(_mask_kernel, n_rows=n, tile=tile),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n_t // tile,),
            in_specs=[pl.BlockSpec((tile, 1), lambda i, c: (i, 0))],
            out_specs=out_specs,
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(jnp.asarray([capacity], jnp.int32), mask[:, None])
    idx, cnt = res[0][:capacity, 0], res[1][0, 0]
    if translate:
        return idx, cnt, res[2][:n, 0]
    return idx, cnt


def compact_translate(mask: jax.Array, capacity: int, *, tile: int = 1024,
                      interpret: bool = True):
    """`compact` + the CSR key→slot translation vector, one pass."""
    return compact(mask, capacity, tile=tile, interpret=interpret,
                   translate=True)


def _pred_kernel(*refs, names, n_scalars: int, pred_fn, n_rows: int,
                 tile: int, translate: bool):
    """Fused predicate + compaction: refs are
    [col_0..col_{C-1}, scalar_0..scalar_{S-1}, idx, cnt, (slot)]."""
    ncols = len(names)
    cols = {nm: refs[i][...][:, 0] for i, nm in enumerate(names)}
    scalars = [refs[ncols + i][0, 0] for i in range(n_scalars)]
    idx_ref, cnt_ref = refs[ncols + n_scalars], refs[ncols + n_scalars + 1]
    slot_ref = refs[ncols + n_scalars + 2] if translate else None
    m = jnp.asarray(pred_fn(cols, scalars))
    m = jnp.broadcast_to(m, (tile,)).astype(bool).reshape(tile, 1)
    _compact_body(pl.program_id(0), jnp.int32(idx_ref.shape[0] - tile),
                  m, n_rows, tile, idx_ref, cnt_ref, slot_ref)


def compact_pred(cols: dict, scalars: list, pred_fn, capacity: int, *,
                 tile: int = 1024, interpret: bool = True,
                 translate: bool = False):
    """Filter → compact fused into one HBM pass: the predicate is
    evaluated in-kernel on (tile,) column blocks.

    cols: {name: (n,) array} — every column the predicate reads;
    scalars: list of () arrays — runtime parameters, positionally
    matching what `pred_fn` expects;
    pred_fn(cols_tile, scalars) -> (tile,) bool, pure jnp elementwise.
    Returns the `compact` contract (+ `slot_of` when `translate`).
    """
    arrs = list(cols.values())
    n = arrs[0].shape[0]
    tile = min(tile, max(8, 1 << (max(n, 1) - 1).bit_length()))
    n_pad = (-n) % tile
    names = list(cols)
    padded = {nm: jnp.pad(a, (0, n_pad)) if n_pad else a
              for nm, a in cols.items()}
    n_t = n + n_pad
    cap_pad = capacity + tile

    in_specs = [pl.BlockSpec((tile, 1), lambda i: (i, 0)) for _ in names]
    in_specs += [pl.BlockSpec((1, 1), lambda i: (0, 0)) for _ in scalars]
    out_shape = [jax.ShapeDtypeStruct((cap_pad, 1), jnp.int32),
                 jax.ShapeDtypeStruct((1, 1), jnp.int32)]
    out_specs = [pl.BlockSpec((cap_pad, 1), lambda i: (0, 0)),
                 pl.BlockSpec((1, 1), lambda i: (0, 0))]
    if translate:
        out_shape.append(jax.ShapeDtypeStruct((n_t, 1), jnp.int32))
        out_specs.append(pl.BlockSpec((tile, 1), lambda i: (i, 0)))

    ins = [padded[nm][:, None] for nm in names]
    ins += [jnp.asarray(s).reshape(1, 1) for s in scalars]
    res = pl.pallas_call(
        functools.partial(_pred_kernel, names=names,
                          n_scalars=len(scalars), pred_fn=pred_fn,
                          n_rows=n, tile=tile, translate=translate),
        grid=(n_t // tile,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*ins)
    idx, cnt = res[0][:capacity, 0], res[1][0, 0]
    if translate:
        return idx, cnt, res[2][:n, 0]
    return idx, cnt
