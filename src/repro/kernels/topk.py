"""Masked top-k Pallas TPU kernel (ORDER BY <metric> DESC LIMIT k).

TPU adaptation of the paper's sort operator for limit queries (Q3/Q10/Q18):
a global sort is wasteful when only k rows survive.  Each grid step reduces
a VMEM tile to its local top-k by iterative max-extraction (k is small and
static, so the loop unrolls into straight-line vector code — the staged
specialization the paper applies to, e.g., statically-sized aggregate
arrays).  The (num_tiles, k) partials are then reduced by `jax.lax.top_k`
host-side of the kernel, which is O(num_tiles·k) — negligible.
"""
from __future__ import annotations

import functools

import jax
import numpy as np
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG = np.float32(-3.0e38)  # python-level constant: not a captured tracer


def _kernel(vals_ref, mask_ref, outv_ref, outi_ref, *, k: int, tile: int):
    step = pl.program_id(0)
    v = jnp.where(mask_ref[...], vals_ref[...], _NEG)[:, 0]   # (T,)
    base = step * tile
    idx = jax.lax.broadcasted_iota(jnp.int32, (tile,), 0) + base
    for j in range(k):                    # unrolled: k is static
        m = jnp.max(v)
        am = jnp.argmax(v)
        outv_ref[0, j] = m
        outi_ref[0, j] = (idx[am]).astype(jnp.int32)
        v = jnp.where(jax.lax.broadcasted_iota(jnp.int32, (tile,), 0) == am,
                      _NEG, v)


@functools.partial(jax.jit, static_argnames=("k", "tile", "interpret"))
def masked_topk(vals: jax.Array, mask: jax.Array, k: int, *,
                tile: int = 4096, interpret: bool = True
                ) -> tuple[jax.Array, jax.Array]:
    """Top-k values of `vals` where `mask`, with their row indices.

    Returns (values (k,), indices (k,)); if fewer than k rows are valid the
    tail carries -inf sentinels and index -1.
    """
    n = vals.shape[0]
    n_pad = (-n) % tile
    if n_pad:
        vals = jnp.pad(vals, (0, n_pad))
        mask = jnp.pad(mask, (0, n_pad))
    n_t = vals.shape[0]
    grid = (n_t // tile,)

    pv, pi = pl.pallas_call(
        functools.partial(_kernel, k=k, tile=tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((grid[0], k), jnp.float32),
            jax.ShapeDtypeStruct((grid[0], k), jnp.int32),
        ],
        interpret=interpret,
    )(vals[:, None], mask[:, None])

    flatv, flati = pv.reshape(-1), pi.reshape(-1)
    topv, pos = jax.lax.top_k(flatv, k)
    topi = jnp.where(topv <= _NEG, -1, flati[pos])
    return topv, topi
