"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def filter_agg_ref(mask, gidx, vals, n_groups):
    data = jnp.where(mask[:, None], vals, 0.0)
    return jax.ops.segment_sum(data, gidx, num_segments=n_groups)


def gather_join_ref(fk, table):
    k = table.shape[0]
    ok = (fk >= 0) & (fk < k)
    out = table[jnp.clip(fk, 0, k - 1)]
    return jnp.where(ok[:, None], out, 0.0)


def compact_ref(mask, capacity):
    """(idx, count) oracle matching `backend.compact`'s contract: the
    first min(count, capacity) slots are the valid row ids in order, pad
    slots zero, count exact (may exceed capacity)."""
    n = mask.shape[0]
    c = jnp.cumsum(mask.astype(jnp.int32))
    count = c[-1] if n else jnp.int32(0)
    pos = jnp.searchsorted(c, jnp.arange(1, capacity + 1, dtype=jnp.int32))
    valid = jnp.arange(capacity) < jnp.minimum(count, capacity)
    return jnp.where(valid, jnp.clip(pos, 0, max(n - 1, 0)), 0) \
        .astype(jnp.int32), count


def slot_of_ref(mask):
    """CSR key→slot translation oracle: the compacted slot of every valid
    row (its rank among valid rows), -1 elsewhere."""
    c = jnp.cumsum(mask.astype(jnp.int32))
    return jnp.where(mask, c - 1, -1).astype(jnp.int32)


def selective_filter_agg_ref(cols, scalars, pred_fn, vals_fn, gidx_fn,
                             n_vals, n_groups, capacity=0, translate=False):
    """Unfused oracle of the selective pipeline: evaluate the same tile
    functions over the full arrays, then mask-aggregate / compact."""
    m = jnp.asarray(pred_fn(cols, scalars))
    n = next(iter(cols.values())).shape[0]
    m = jnp.broadcast_to(m, (n,)).astype(bool)
    vs = [jnp.broadcast_to(jnp.asarray(v, jnp.float32), (n,))
          for v in vals_fn(cols, scalars)]
    vals = jnp.stack(vs, axis=1) if vs else jnp.zeros((n, 0), jnp.float32)
    gidx = jnp.zeros((n,), jnp.int32) if gidx_fn is None \
        else jnp.broadcast_to(jnp.asarray(gidx_fn(cols, scalars),
                                          dtype=jnp.int32), (n,))
    sums = filter_agg_ref(m, gidx, vals, n_groups)
    out = [sums, m.astype(jnp.int32).sum()]
    if capacity > 0:
        out.append(compact_ref(m, capacity)[0])
    if translate:
        out.append(slot_of_ref(m))
    return tuple(out)


def masked_topk_ref(vals, mask, k):
    neg = jnp.float32(-3.0e38)
    v = jnp.where(mask, vals, neg)
    if k > v.shape[0]:
        v = jnp.pad(v, (0, k - v.shape[0]), constant_values=neg)
    topv, topi = jax.lax.top_k(v, k)
    topi = jnp.where(topv <= neg, -1, topi)
    return topv, topi
