"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def filter_agg_ref(mask, gidx, vals, n_groups):
    data = jnp.where(mask[:, None], vals, 0.0)
    return jax.ops.segment_sum(data, gidx, num_segments=n_groups)


def gather_join_ref(fk, table):
    k = table.shape[0]
    ok = (fk >= 0) & (fk < k)
    out = table[jnp.clip(fk, 0, k - 1)]
    return jnp.where(ok[:, None], out, 0.0)


def masked_topk_ref(vals, mask, k):
    neg = jnp.float32(-3.0e38)
    v = jnp.where(mask, vals, neg)
    if k > v.shape[0]:
        v = jnp.pad(v, (0, k - v.shape[0]), constant_values=neg)
    topv, topi = jax.lax.top_k(v, k)
    topi = jnp.where(topv <= neg, -1, topi)
    return topv, topi
