"""Partitioned PK/FK join probe as a Pallas TPU kernel (dimension tables).

The paper's §3.2.1 partitioned join — `MR[s->id]` direct array access — is a
gather.  For *dimension-table* builds that fit VMEM (region/nation/part-
class tables; K ≤ a few thousand), the TPU-native probe keeps the whole
parent table VMEM-resident across all grid steps and performs the gather as
a one-hot × table matmul on the MXU:

    out[T, C] = onehot(fk)[T, K] @ table[K, C]

This is deliberately *not* a scalar hash probe: the MXU contraction is the
idiomatic TPU spelling of K-way selection, and it fuses with downstream
arithmetic in the same VMEM tile.  Large parents use XLA's native gather
outside the kernel (`compile.py` pk_gather path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(fk_ref, table_ref, out_ref):
    fk = fk_ref[...]                      # (T, 1) int32
    tbl = table_ref[...]                  # (K, C) float32 — VMEM resident
    k = tbl.shape[0]
    tile = fk.shape[0]
    keys = jax.lax.broadcasted_iota(jnp.int32, (tile, k), 1)
    onehot = (fk == keys).astype(jnp.float32)         # (T, K)
    out_ref[...] = jnp.dot(onehot, tbl, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def gather_join(fk: jax.Array, table: jax.Array, *, tile: int = 1024,
                interpret: bool = True) -> jax.Array:
    """out[i, :] = table[fk[i], :] (out-of-range fk rows return zeros).

    fk: (n,) int32; table: (K, C) float32.  Returns (n, C) float32.
    """
    n = fk.shape[0]
    k, c = table.shape
    n_pad = (-n) % tile
    if n_pad:
        fk = jnp.pad(fk, (0, n_pad), constant_values=-1)
    n_t = fk.shape[0]

    out = pl.pallas_call(
        _kernel,
        grid=(n_t // tile,),
        in_specs=[
            pl.BlockSpec((tile, 1), lambda i: (i, 0)),
            pl.BlockSpec((k, c), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_t, c), jnp.float32),
        interpret=interpret,
    )(fk[:, None], table)
    return out[:n]
