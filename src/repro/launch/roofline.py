"""Roofline-term derivation from compiled dry-run artifacts.

  compute    = HLO_FLOPs   / (chips · 197 TFLOP/s bf16)
  memory     = HLO_bytes   / (chips · 819 GB/s HBM)
  collective = coll_bytes  / (chips · 50 GB/s/link ICI)

`cost_analysis()` on the SPMD-partitioned executable reports *per-device*
numbers (calibrated in tests/test_roofline_calibration.py), so totals are
per_device × chips.  Collective bytes are parsed from the compiled HLO: we
build a symbol table of every op's result size and sum **operand** sizes of
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(the -start variants counted, -done skipped).
"""
from __future__ import annotations

import re

PEAK_FLOPS = 197e12        # bf16 / chip (TPU v5e-class target)
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(%?[\w.\-]+)\s*=\s*(.*)$")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum of operand bytes per collective kind, from partitioned HLO."""
    sizes: dict[str, int] = {}
    pending: list[tuple[str, list[str]]] = []
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1).lstrip("%"), m.group(2)
        # result type = prefix of `rest` up to the op name
        op_m = re.search(r"\)?\s*([a-z][\w\-]*)\(", rest)
        type_part = rest[: op_m.start()] if op_m else rest
        sizes[name] = _type_bytes(type_part)
        if not op_m:
            continue
        op = op_m.group(1)
        kind = next((c for c in _COLLECTIVES if op == c or op == c + "-start"),
                    None)
        if kind is None:
            continue
        args = rest[op_m.end():rest.rfind(")")]
        operands = re.findall(r"%?([\w.\-]+)", args)
        pending.append((kind, operands))
    out: dict[str, int] = {}
    for kind, operands in pending:
        b = sum(sizes.get(o, 0) for o in operands)
        out[kind] = out.get(kind, 0) + b
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def model_flops(cfg, shape) -> float:
    """6·N·D (dense) or 6·N_active·D (MoE) useful training FLOPs; for
    inference cells: 2·N·D per generated/prefilled token."""
    n = param_count(cfg, active_only=True)
    d_tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                     else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * d_tokens


def param_count(cfg, active_only: bool = False) -> float:
    """Analytic parameter count from the config."""
    d, v = cfg.d_model, cfg.vocab
    total = v * d                                     # embed
    if not cfg.tie_embeddings:
        total += d * v
    kinds = cfg.layer_kinds()
    for i, kind in enumerate(kinds):
        if kind == "attn":
            hd = cfg.hd
            total += d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd \
                + cfg.n_heads * hd * d
        elif kind == "mla":
            nope, rd, dv = cfg.hd, cfg.rope_dim, cfg.v_head_dim
            total += d * cfg.q_lora + cfg.q_lora * cfg.n_heads * (nope + rd)
            total += d * (cfg.kv_lora + rd)
            total += cfg.kv_lora * cfg.n_heads * (nope + dv)
            total += cfg.n_heads * dv * d
        elif kind == "mamba":
            di = cfg.ssm_expand * d
            rank = max(1, d // 16)
            total += d * 2 * di + di * (rank + 2 * cfg.ssm_state) \
                + rank * di + di * d
        elif kind == "mlstm":
            total += 5 * d * d + 2 * d * cfg.n_heads
        elif kind == "slstm":
            total += 9 * d * d
        if kind in ("attn", "mla", "mamba"):
            if cfg.is_moe_layer(i):
                f = cfg.moe_d_ff or cfg.d_ff
                e_count = (cfg.topk if active_only else cfg.n_experts)
                total += 3 * d * f * e_count + d * cfg.n_experts  # router
                total += 3 * d * f * cfg.n_shared_experts
            elif cfg.d_ff > 0:
                mult = 3 if cfg.mlp == "swiglu" else 2
                total += mult * d * cfg.d_ff
    if cfg.encoder_layers:
        hd = cfg.hd
        per = (d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd
               + cfg.n_heads * hd * d)
        mult = 3 if cfg.mlp == "swiglu" else 2
        per += mult * d * cfg.d_ff
        total += cfg.encoder_layers * per
        # decoder cross-attention
        total += len(kinds) * (d * cfg.n_heads * hd
                               + 2 * d * cfg.n_kv_heads * hd
                               + cfg.n_heads * hd * d)
    return float(total)


def flash_bytes(cfg, shape, chips: int) -> float:
    """Analytic one-pass q/k/v/out HBM bytes for streamed (flash) attention,
    added to the blockwise-probe byte counts (whose attention loops the
    analyzer counts once).  Train cells pay the pass ~3× (fwd + bwd reads +
    dgrads); prefill/encode ~1×."""
    kinds = cfg.layer_kinds()
    n_attn = sum(1 for k in kinds if k in ("attn", "mla"))
    s = shape.seq_len
    b = shape.global_batch
    dt = 2  # bf16
    if cfg.mla:
        dk, dv, hq, hkv = cfg.hd + cfg.rope_dim, cfg.v_head_dim, \
            cfg.n_heads, cfg.n_heads
    else:
        dk = dv = cfg.hd
        hq, hkv = cfg.n_heads, cfg.n_kv_heads
    per_layer = (b * s * hq * dk + b * s * hkv * (dk + dv)
                 + b * s * hq * dv) * dt
    total = n_attn * per_layer
    if cfg.encoder_layers:
        se = max(s // 4, 8)
        total += cfg.encoder_layers * (
            (b * se * hq * dk + b * se * hkv * (dk + dv)
             + b * se * hq * dv) * dt)
        # decoder cross attention reads encoder K/V per layer
        total += len(kinds) * (b * se * hkv * (dk + dv)) * dt
    mult = 3.0 if shape.kind == "train" else 1.0
    return mult * total / chips


def slstm_correction_flops(cfg, shape, chips: int) -> float:
    """sLSTM's recurrent R-matmul runs in an inherently sequential
    per-token while loop, which HloCostAnalysis counts once; add the
    analytic (trip_count − 1) × body cost.  Applied per device."""
    n_slstm = sum(1 for k in cfg.layer_kinds() if k == "slstm")
    if n_slstm == 0:
        return 0.0
    s = shape.seq_len if shape.kind != "decode" else 1
    if s <= 1:
        return 0.0
    b = shape.global_batch
    body = 2.0 * b * cfg.d_model * 4 * cfg.d_model      # h @ R per step
    return n_slstm * (s - 1) * body / chips


def analytic_hbm_bytes(cfg, shape, chips: int) -> float:
    """Napkin HBM-traffic model per device (what the memory term would be
    with perfect fusion — `bytes accessed` counts pre-fusion dataflow and
    overstates traffic by 1–2 orders of magnitude).  Components:
      train:   weights 2 passes bf16 (fwd+bwd) + optimizer f32 r/w (m,v,p),
               remat residuals ~3 passes, logits ~3 passes, flash attention
               one-pass q/k/v/out, MoE token gather/scatter ~4 passes;
      prefill: weights 1 pass + activations 2 + cache write + attention;
      decode:  weights 1 pass + full cache read + tiny activations.
    """
    n_total = param_count(cfg)
    b, s = shape.global_batch, shape.seq_len
    d, v = cfg.d_model, cfg.vocab
    toks = b * (s if shape.kind != "decode" else 1)
    bytes_ = 0.0
    if shape.kind == "train":
        bytes_ += n_total * (2 * 2 + 12 + 4)          # w fwd+bwd, adam, grads
        bytes_ += 3 * cfg.n_layers * toks * d * 2     # remat residuals
        bytes_ += 3 * toks * v * 2                    # logits
        bytes_ += flash_bytes(cfg, shape, 1)
        if cfg.moe:
            bytes_ += 4 * toks * cfg.topk * d * 4
    elif shape.kind == "prefill":
        bytes_ += n_total * 2
        bytes_ += 2 * cfg.n_layers * toks * d * 2
        bytes_ += flash_bytes(cfg, shape, 1)
        bytes_ += toks * cfg.n_kv_heads * cfg.hd * 2 * cfg.n_layers  # cache
    else:  # decode
        bytes_ += param_count(cfg, active_only=True) * 2
        kinds = cfg.layer_kinds()
        for k in kinds:
            if k == "attn":
                bytes_ += b * s * cfg.n_kv_heads * cfg.hd * 2 * 2
            elif k == "mla":
                bytes_ += b * s * (cfg.kv_lora + cfg.rope_dim) * 2
            elif k == "mamba":
                bytes_ += b * cfg.ssm_expand * d * cfg.ssm_state * 4
            elif k in ("mlstm", "slstm"):
                bytes_ += b * d * (d // max(cfg.n_heads, 1) + 4) * 4
    return bytes_ / chips


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float, chips: int) -> dict:
    compute = flops_per_dev / PEAK_FLOPS
    memory = bytes_per_dev / HBM_BW
    collective = coll_bytes_per_dev / ICI_BW
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    terms["bottleneck"] = max(terms, key=lambda k: terms[k] if k.endswith("_s") else -1)
    terms["bound_s"] = max(compute, memory, collective)
    terms["roofline_fraction"] = compute / max(terms["bound_s"], 1e-30)
    return terms
