"""Production mesh construction.

`make_production_mesh` is a function (never a module-level constant) so
importing this module never touches jax device state.  Single pod =
(data=16, model=16) over 256 chips; multi-pod adds a leading pod=2 axis
(512 chips), with ('pod','data') jointly forming the batch/FSDP dimension.
"""
from __future__ import annotations

import jax

from repro.models.sharding import Ctx


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devices)} — the dry-run entrypoint "
            "must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before any jax import")
    import numpy as np

    return jax.sharding.Mesh(
        np.asarray(devices[:n]).reshape(shape), axes)


def make_ctx(mesh) -> Ctx:
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return Ctx(mesh=mesh, dp_axes=dp, tp_axis="model")
