"""Production training launcher.

On a real multi-host TPU deployment this process runs per host:
`jax.distributed.initialize()` + the production mesh; here it runs the
identical code path on however many devices exist (1 on this CPU box),
exercising mesh construction, sharded state, the fault-tolerant driver,
async checkpointing and the deterministic pipeline.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1_5_0_5b \
        --smoke --steps 30
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.data.pipeline import TokenPipeline
from repro.models import Ctx, init_params
from repro.runtime.fault_tolerance import TrainDriver
from repro.train.optimizer import AdamConfig
from repro.train.train_step import make_train_state, train_step


def build_mesh_or_none():
    devs = jax.devices()
    if len(devs) == 1:
        return None
    # largest (data, model) factorization available
    n = len(devs)
    model = 1
    for cand in (16, 8, 4, 2):
        if n % cand == 0:
            model = cand
            break
    return jax.sharding.Mesh(
        np.asarray(devs).reshape(n // model, model), ("data", "model"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1_5_0_5b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--ckpt", default="/tmp/repro_launch_ckpt")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--compression", action="store_true")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = build_mesh_or_none()
    ctx = Ctx(mesh=mesh) if mesh is not None else Ctx(mesh=None)

    params = init_params(jax.random.PRNGKey(0), cfg)
    state = make_train_state(params, compression=args.compression)
    pipe = TokenPipeline(vocab=cfg.vocab, batch=args.batch, seq_len=args.seq,
                         host=jax.process_index(),
                         n_hosts=jax.process_count())

    def step(st, b):
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        return train_step(st, batch, cfg, ctx, AdamConfig(warmup=10),
                          accum=args.accum)

    drv = TrainDriver(step_fn=jax.jit(step), state=state, pipeline=pipe,
                      ckpt_dir=args.ckpt, ckpt_every=20)
    drv.run(args.steps)
    print(f"done: {len(drv.metrics_log)} steps, "
          f"last loss {drv.metrics_log[-1]['loss']:.4f}, "
          f"recoveries {drv.recoveries}, "
          f"stragglers {len(drv.straggler.slow_steps)}")


if __name__ == "__main__":
    main()
