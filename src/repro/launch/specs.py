"""ShapeDtypeStruct stand-ins + shardings for every (arch × shape) cell.

Nothing here allocates device memory: parameters come from
`jax.eval_shape(init_params, ...)`, inputs are ShapeDtypeStructs, and the
dry-run lowers/compiles against them.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.models.config import SHAPES, ModelConfig, ShapeConfig
from repro.models.sharding import Ctx, batch_spec, cache_spec, param_specs
from repro.models.transformer import cache_struct, init_params


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def params_struct(cfg: ModelConfig):
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: init_params(k, cfg), key)


def param_shardings(struct, ctx: Ctx):
    specs = param_specs(struct, ctx)
    return jax.tree.map(lambda s: NamedSharding(ctx.mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def _extras_struct(cfg: ModelConfig, b: int, s: int) -> dict[str, Any]:
    out = {}
    if cfg.encoder_layers:
        out["frames"] = sds((b, max(s // 4, 8), cfg.d_model), jnp.bfloat16)
    if cfg.n_patches:
        out["patch_embeds"] = sds((b, cfg.n_patches, cfg.d_model),
                                  jnp.bfloat16)
    return out


def batch_struct(cfg: ModelConfig, shape: ShapeConfig, *, train: bool):
    b, s = shape.global_batch, shape.seq_len
    out = {"tokens": sds((b, s), jnp.int32)}
    if train:
        out["targets"] = sds((b, s), jnp.int32)
    out.update(_extras_struct(cfg, b, s))
    return out


def batch_shardings(batch, ctx: Ctx):
    def leaf(x):
        spec = [None] * len(x.shape)
        spec[0] = batch_spec(ctx)
        return NamedSharding(ctx.mesh, P(*spec))

    return jax.tree.map(leaf, batch)


def decode_structs(cfg: ModelConfig, shape: ShapeConfig):
    b, s = shape.global_batch, shape.seq_len
    s_enc = max(s // 4, 8) if cfg.encoder_layers else 0
    token = sds((b,), jnp.int32)
    pos = sds((), jnp.int32)
    cache = cache_struct(cfg, b, s, s_enc)
    return token, pos, cache


def cache_shardings(cache, batch: int, ctx: Ctx):
    return jax.tree.map(
        lambda x: NamedSharding(ctx.mesh, cache_spec(x.shape, batch, ctx)),
        cache, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def cell(arch: str, shape_name: str):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    return cfg, shape
