"""Serving launcher: continuous-batching engine on the local device set.

    PYTHONPATH=src python -m repro.launch.serve --arch granite_moe_1b_a400m \
        --smoke --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models import Ctx, init_params
from repro.serve.batcher import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1_5_0_5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, Ctx(mesh=None), slots=args.slots,
                      max_len=args.max_len)

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    reqs = []
    for i in range(args.requests):
        r = Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        4 + int(rng.integers(0, 6))
                                        ).astype(np.int32),
                    max_new=args.max_new)
        reqs.append(r)
        eng.submit(r)
    eng.run_until_drained()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in reqs)
    print(f"{len(reqs)} requests, {toks} tokens, {eng.ticks} ticks, "
          f"{dt:.2f}s ({toks / dt:.1f} tok/s on CPU)")


if __name__ == "__main__":
    main()
