import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) cell against ShapeDtypeStruct stand-ins, print memory/cost analysis,
and emit the roofline terms to JSON.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1_5_0_5b \
      --shape train_4k [--multipod] [--out results/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all

The two os.environ lines above MUST stay the first statements in this file:
jax locks the device count at first initialization.
"""
import argparse        # noqa: E402
import json            # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCHS, SKIPS, get_config, shapes_for  # noqa: E402
from repro.launch import roofline as R                           # noqa: E402
from repro.launch.mesh import make_ctx, make_production_mesh    # noqa: E402
from repro.launch import specs as SP                             # noqa: E402
from repro.models.config import SHAPES                           # noqa: E402
from repro.models.transformer import decode_step, prefill        # noqa: E402
from repro.train.optimizer import AdamConfig                     # noqa: E402
from repro.train.train_step import train_step  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P       # noqa: E402


def _opt_struct(pstruct):
    """ShapeDtypeStructs for the TrainState built from params structs."""
    from repro.train.optimizer import AdamState
    from repro.train.train_step import TrainState

    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    return TrainState(
        params=pstruct,
        opt=AdamState(m=jax.tree.map(f32, pstruct),
                      v=jax.tree.map(f32, pstruct),
                      step=jax.ShapeDtypeStruct((), jnp.int32)),
        ef=None)


def probe_config(cfg, reps: int, attn_impl: str = "naive"):
    """Config with `reps` pattern-repeats (for cost extrapolation: XLA's
    HloCostAnalysis counts while-loop bodies once, so scanned-layer costs
    are measured at 1 and 2 reps and extrapolated linearly to the real
    depth — dot-flop counting itself was calibrated exactly).

    attn_impl='naive'     exact attention FLOPs (S×S visible)  -> compute &
                          collective terms;
    attn_impl='blockwise' flash semantics (no S² materialization) -> memory
                          term, plus an analytic one-pass q/k/v/out byte
                          correction (roofline.flash_bytes)."""
    import dataclasses

    plen = len(cfg.pattern)
    enc = min(cfg.encoder_layers, reps) if cfg.encoder_layers else 0
    return dataclasses.replace(cfg, n_layers=plen * reps, encoder_layers=enc,
                               unroll=True, attn_impl=attn_impl)


def _env_overrides(cfg):
    """§Perf hillclimb levers applied via environment (each variant runs in
    its own process; see scripts/run_hillclimb.py):
      REPRO_PARAM_DTYPE  bfloat16 params (FSDP gathers halve)
      REPRO_CAPACITY     MoE capacity factor
      REPRO_QBLOCK unused here (attention block sizes are code-level)
    """
    import dataclasses

    kw = {}
    if os.environ.get("REPRO_PARAM_DTYPE"):
        kw["param_dtype"] = os.environ["REPRO_PARAM_DTYPE"]
    if os.environ.get("REPRO_CAPACITY"):
        kw["capacity_factor"] = float(os.environ["REPRO_CAPACITY"])
    return dataclasses.replace(cfg, **kw) if kw else cfg


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               cfg_override=None):
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    cfg = _env_overrides(cfg)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = make_ctx(mesh)
    pstruct = SP.params_struct(cfg)
    pshard = SP.param_shardings(pstruct, ctx)

    if shape.kind == "train":
        state = _opt_struct(pstruct)
        state_shard = type(state)(
            params=pshard,
            opt=type(state.opt)(m=pshard, v=pshard,
                                step=NamedSharding(mesh, P())),
            ef=None)
        batch = SP.batch_struct(cfg, shape, train=True)
        bshard = SP.batch_shardings(batch, ctx)

        def fn(st, b):
            return train_step(st, b, cfg, ctx, AdamConfig())

        jitted = jax.jit(fn, in_shardings=(state_shard, bshard),
                         donate_argnums=(0,))
        lowered = jitted.lower(state, batch)
    elif shape.kind == "prefill":
        batch = SP.batch_struct(cfg, shape, train=False)
        bshard = SP.batch_shardings(batch, ctx)

        def fn(p, b):
            return prefill(p, b, cfg, ctx)

        jitted = jax.jit(fn, in_shardings=(pshard, bshard))
        lowered = jitted.lower(pstruct, batch)
    else:  # decode
        token, pos, cache = SP.decode_structs(cfg, shape)
        cshard = SP.cache_shardings(cache, shape.global_batch, ctx)
        tshard = NamedSharding(
            mesh, P(ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0])
            if shape.global_batch % ctx.dp_size == 0 else P())

        def fn(p, t, c, ps):
            return decode_step(p, t, c, ps, cfg, ctx)

        jitted = jax.jit(fn,
                         in_shardings=(pshard, tshard, cshard,
                                       NamedSharding(mesh, P())),
                         donate_argnums=(2,))
        lowered = jitted.lower(pstruct, token, cache, pos)
    return cfg, shape, mesh, lowered


def _measure(arch, shape_name, multi_pod, cfg_override=None):
    t0 = time.perf_counter()
    cfg, shape, mesh, lowered = lower_cell(arch, shape_name,
                                           multi_pod=multi_pod,
                                           cfg_override=cfg_override)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0
    ca = compiled.cost_analysis() or {}
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    coll = R.collective_bytes(hlo)
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "code_bytes": getattr(ma, "generated_code_size_in_bytes", None),
        }
    except Exception:
        mem = {}
    return {
        "cfg": cfg, "shape": shape, "mesh": mesh,
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll": coll, "coll_total": float(coll.get("total", 0.0)),
        "mem": mem, "t_lower": t_lower, "t_compile": t_compile,
    }


def run_cell(arch: str, shape_name: str, *, multi_pod: bool) -> dict:
    cfg_full = get_config(arch)
    plen = len(cfg_full.pattern)
    reps = cfg_full.n_layers // plen

    # full program: the compile proof + memory analysis
    full = _measure(arch, shape_name, multi_pod)
    cfg, shape, mesh = full["cfg"], full["shape"], full["mesh"]
    chips = mesh.size
    t_lower, t_compile = full["t_lower"], full["t_compile"]
    mem, coll = full["mem"], full["coll"]

    if reps > 2:
        # shallow probes -> per-rep slope -> extrapolate to real depth
        p1 = _measure(arch, shape_name, multi_pod,
                      cfg_override=probe_config(cfg_full, 1))
        p2 = _measure(arch, shape_name, multi_pod,
                      cfg_override=probe_config(cfg_full, 2))
        extrap = lambda f1, f2: f1 + (reps - 1) * (f2 - f1)
        flops_dev = extrap(p1["flops"], p2["flops"])
        bytes_naive = extrap(p1["bytes"], p2["bytes"])
        coll_dev = extrap(p1["coll_total"], p2["coll_total"])
        has_attn = any(k in ("attn", "mla") for k in cfg_full.pattern) \
            or cfg_full.encoder_layers > 0
        if has_attn and shape.kind != "decode":
            b1 = _measure(arch, shape_name, multi_pod,
                          cfg_override=probe_config(cfg_full, 1, "blockwise"))
            b2 = _measure(arch, shape_name, multi_pod,
                          cfg_override=probe_config(cfg_full, 2, "blockwise"))
            bytes_dev = extrap(b1["bytes"], b2["bytes"]) \
                + R.flash_bytes(cfg_full, shape, chips)
        else:
            bytes_dev = bytes_naive
    else:
        flops_dev, bytes_dev = full["flops"], full["bytes"]
        bytes_naive = bytes_dev
        coll_dev = full["coll_total"]
    # inherently-sequential sLSTM recurrence: analytic correction
    flops_dev += R.slstm_correction_flops(cfg_full, shape, chips)
    terms = R.roofline_terms(flops_dev, bytes_dev, coll_dev, chips)
    mf = R.model_flops(cfg, shape)
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "flops_per_dev": flops_dev, "bytes_per_dev": bytes_dev,
        "bytes_per_dev_naive_attn": bytes_naive,
        "collective_bytes_per_dev": coll_dev,
        "collectives": coll, "memory": mem,
        "model_flops_total": mf,
        "useful_flops_ratio": mf / max(flops_dev * chips, 1e-30),
        "lower_s": t_lower, "compile_s": t_compile,
        "params": R.param_count(cfg),
        "params_active": R.param_count(cfg, active_only=True),
        **terms,
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = []
    if args.all:
        for arch in ARCHS:
            for shape in shapes_for(arch):
                cells.append((arch, shape, False))
                cells.append((arch, shape, True))
    else:
        assert args.arch and args.shape
        if args.shape in SKIPS.get(args.arch, {}):
            print(f"SKIP {args.arch} {args.shape}: "
                  f"{SKIPS[args.arch][args.shape]}")
            return
        cells.append((args.arch, args.shape, args.multipod))

    for arch, shape, mp in cells:
        tag = f"{arch}__{shape}__{'multipod' if mp else 'pod'}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path):
            print(f"cached {tag}")
            continue
        print(f"=== {tag} ===", flush=True)
        try:
            res = run_cell(arch, shape, multi_pod=mp)
            print(json.dumps({k: v for k, v in res.items()
                              if k not in ("collectives", "memory")},
                             indent=None, default=str), flush=True)
            with open(path, "w") as f:
                json.dump(res, f, indent=1, default=str)
        except Exception:
            traceback.print_exc()
            with open(path + ".err", "w") as f:
                f.write(traceback.format_exc())


if __name__ == "__main__":
    main()
