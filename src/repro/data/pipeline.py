"""Synthetic tokenized data pipeline: deterministic, host-sharded,
background-prefetched.

Determinism contract: batch contents are a pure function of
(seed, step, host), so a restart or an elastic rescale replays the exact
stream from the restored step — the data pipeline never needs
checkpointing beyond the step counter.  On a multi-host cluster each
process draws only its `process_index` slice of the global batch.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np


class TokenPipeline:
    def __init__(self, *, vocab: int, batch: int, seq_len: int,
                 seed: int = 0, host: int = 0, n_hosts: int = 1,
                 prefetch: int = 2, extras: Optional[dict] = None,
                 structured: bool = False):
        assert batch % n_hosts == 0
        self.vocab = vocab
        self.local_batch = batch // n_hosts
        self.seq_len = seq_len
        self.seed = seed
        self.host = host
        self.extras = extras or {}
        # structured=True draws from a noisy affine-recurrence language
        # (t_{i+1} = (31·t_i + 7) mod V, 10% noise) so training drivers show
        # an actually-falling loss instead of ln(V) on uniform noise.
        self.structured = structured
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._step = 0
        self._thread: Optional[threading.Thread] = None

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host]))
        if self.structured:
            toks = np.empty((self.local_batch, self.seq_len + 1),
                            dtype=np.int64)
            toks[:, 0] = rng.integers(0, self.vocab, self.local_batch)
            noise = rng.random((self.local_batch, self.seq_len)) < 0.1
            rand = rng.integers(0, self.vocab,
                                (self.local_batch, self.seq_len))
            for i in range(self.seq_len):
                nxt = (31 * toks[:, i] + 7) % self.vocab
                toks[:, i + 1] = np.where(noise[:, i], rand[:, i], nxt)
            toks = toks.astype(np.int32)
        else:
            toks = rng.integers(0, self.vocab,
                                (self.local_batch, self.seq_len + 1),
                                dtype=np.int32)
        out = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
        for name, shape in self.extras.items():
            out[name] = rng.normal(size=(self.local_batch, *shape)).astype(
                np.float32)
        return out

    # ---- prefetching iterator ------------------------------------------------
    def start(self, from_step: int = 0) -> None:
        self._step = from_step
        self._stop.clear()

        def worker():
            s = from_step
            while not self._stop.is_set():
                b = self.batch_at(s)
                while not self._stop.is_set():
                    try:
                        self._q.put((s, b), timeout=0.1)
                        break
                    except queue.Full:
                        continue
                s += 1

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def __iter__(self) -> Iterator[tuple[int, dict[str, np.ndarray]]]:
        while True:
            yield self._q.get()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2)
            self._thread = None
        while not self._q.empty():
            self._q.get_nowait()
