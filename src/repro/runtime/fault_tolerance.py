"""Fault-tolerant training driver: checkpoint/restart, failure retry,
straggler monitoring, elastic rescale hooks.

The driver owns the loop; the step function is pure — so recovery is
always "restore state pytree, replay data stream from step k", which is
exactly the multi-host recovery story (deterministic pipeline + sharded
checkpoints).  Failure injection is a constructor hook so tests can kill
arbitrary steps.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax

from repro.checkpoint.checkpoint import (AsyncCheckpointer, latest_step,
                                         restore)


@dataclasses.dataclass
class StragglerStats:
    ema: float = 0.0
    count: int = 0
    slow_steps: list = dataclasses.field(default_factory=list)
    threshold: float = 3.0

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        if self.count == 0:
            self.ema = dt
        slow = self.count > 2 and dt > self.threshold * self.ema
        self.ema = 0.9 * self.ema + 0.1 * dt
        self.count += 1
        if slow:
            self.slow_steps.append((step, dt, self.ema))
        return slow


class TrainDriver:
    def __init__(self, *, step_fn: Callable, state, pipeline, ckpt_dir: str,
                 ckpt_every: int = 50, max_retries: int = 3,
                 fail_hook: Optional[Callable[[int], None]] = None,
                 state_shardings=None):
        self.step_fn = step_fn
        self.state = state
        self.pipeline = pipeline
        self.ckpt = AsyncCheckpointer(ckpt_dir)
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.max_retries = max_retries
        self.fail_hook = fail_hook
        self.state_shardings = state_shardings
        self.straggler = StragglerStats()
        self.metrics_log: list[dict] = []
        self.recoveries = 0

    def _restore_latest(self, default_step: int) -> int:
        step = latest_step(self.ckpt_dir)
        if step is None:
            return default_step
        self.state = restore(self.ckpt_dir, step, self.state,
                             shardings=self.state_shardings)
        return step

    def run(self, n_steps: int, start_step: int = 0) -> Any:
        step = self._restore_latest(start_step)
        while step < n_steps:
            batch = self.pipeline.batch_at(step)
            t0 = time.perf_counter()
            try:
                if self.fail_hook is not None:
                    self.fail_hook(step)      # may raise (simulated failure)
                self.state, metrics = self.step_fn(self.state, batch)
                jax.block_until_ready(metrics["loss"])
            except Exception:
                # node failure: restore last checkpoint and replay
                self.recoveries += 1
                if self.recoveries > self.max_retries:
                    raise
                self.ckpt.wait()
                step = self._restore_latest(start_step)
                continue
            dt = time.perf_counter() - t0
            self.straggler.observe(step, dt)
            self.metrics_log.append(
                {"step": step, "loss": float(metrics["loss"]), "dt": dt})
            step += 1
            if step % self.ckpt_every == 0:
                self.ckpt.save(step, self.state)
        self.ckpt.wait()
        return self.state
