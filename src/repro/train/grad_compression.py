"""Int8 gradient compression with error feedback.

Per-leaf symmetric int8 quantization of the gradient with a persistent
error-feedback buffer (residual added back before the next quantization),
the standard trick that keeps compressed-SGD/Adam convergent.  In a
multi-pod deployment this transform wraps the *cross-pod* leg of the
gradient all-reduce (the slow DCI hop): each pod reduces in full precision
over ICI, quantizes, exchanges int8 over DCI, dequantizes.  On a single
program the quantize→dequantize round trip is numerically identical to the
deployed path, so convergence behaviour is testable here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _q8(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dq8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads(grads, ef_buf):
    """Returns (dequantized grads as seen after the compressed exchange,
    new error-feedback buffers)."""

    def leaf(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = _q8(g32)
        dq = _dq8(q, s)
        return dq.astype(g.dtype), g32 - dq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef_buf)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))
