"""The train step: loss, grads, (optional) gradient compression, Adam.

With microbatch accumulation (`accum > 1`) the gradient reduce-scatter of
microbatch k overlaps microbatch k+1's compute under XLA's latency-hiding
scheduler — the standard compute/comm overlap at scale.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.sharding import Ctx
from repro.models.transformer import forward_train
from repro.train.grad_compression import compress_grads, ef_init
from repro.train.optimizer import AdamConfig, AdamState, adam_init, adam_update


class TrainState(NamedTuple):
    params: Any
    opt: AdamState
    ef: Optional[Any] = None     # error-feedback buffers (compression on)


def make_train_state(params, *, compression: bool = False) -> TrainState:
    return TrainState(params=params, opt=adam_init(params),
                      ef=ef_init(params) if compression else None)


def loss_fn(params, batch, cfg: ModelConfig, ctx: Ctx):
    import os

    logits = forward_train(params, batch, cfg, ctx)
    targets = batch["targets"]
    s = targets.shape[1]
    logits = logits[:, -s:].astype(jnp.float32)   # drop patch positions
    lse = jax.nn.logsumexp(logits, axis=-1)
    if os.environ.get("REPRO_LOSS_MODE", "gather") == "onehot":
        # §Perf: label lookup as a one-hot contraction — partitions cleanly
        # over the model-sharded vocab axis (no cross-shard gather; XLA
        # fuses the one-hot into the reduction without materializing it).
        onehot = jax.nn.one_hot(targets, logits.shape[-1],
                                dtype=logits.dtype)
        lab = jnp.sum(logits * onehot, axis=-1)
    else:
        lab = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    ce = lse - lab
    if mask is not None:
        ce = ce * mask
        return ce.sum() / jnp.maximum(mask.sum(), 1.0)
    return ce.mean()


def train_step(state: TrainState, batch, cfg: ModelConfig, ctx: Ctx,
               opt_cfg: AdamConfig = AdamConfig(), accum: int = 1):
    if accum == 1:
        loss, grads = jax.value_and_grad(loss_fn)(state.params, batch, cfg, ctx)
    else:
        # microbatch accumulation: batch leading dim split into `accum`
        def micro(carry, mb):
            acc, lsum = carry
            l, g = jax.value_and_grad(loss_fn)(state.params, mb, cfg, ctx)
            return (jax.tree.map(jnp.add, acc, g), lsum + l), None

        def split(x):
            b = x.shape[0]
            return x.reshape(accum, b // accum, *x.shape[1:])

        mbs = jax.tree.map(split, batch)
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                            state.params)
        (gsum, lsum), _ = jax.lax.scan(micro, (zero, 0.0), mbs)
        grads = jax.tree.map(lambda g: g / accum, gsum)
        loss = lsum / accum

    ef = state.ef
    if ef is not None:
        grads, ef = compress_grads(grads, ef)

    new_params, new_opt, gnorm = adam_update(grads, state.opt, state.params,
                                             opt_cfg)
    metrics = {"loss": loss, "grad_norm": gnorm, "step": new_opt.step}
    return TrainState(new_params, new_opt, ef), metrics
