"""Adam with decoupled weight decay, implemented as pure pytree functions.

Optimizer moments inherit the parameter PartitionSpecs (sharding.py), so
m/v are FSDP-sharded over the batch axes — ZeRO-1/3 falls out of GSPMD
with no additional machinery.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup: int = 100


class AdamState(NamedTuple):
    m: Any
    v: Any
    step: jax.Array


def adam_init(params) -> AdamState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamState(m=zeros, v=jax.tree.map(jnp.copy, zeros),
                     step=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def adam_update(grads, state: AdamState, params, cfg: AdamConfig):
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = cfg.lr * jnp.minimum(1.0, step.astype(jnp.float32) / cfg.warmup)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vh = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:   # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamState(new_m, new_v, step), gnorm
