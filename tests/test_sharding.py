"""Sharded execution: co-partitioned TPC-H through shard_map must match
the single-device engine (and the Volcano oracle) exactly, and every
Exchange the Sharding pass plants must be load-bearing.

conftest.py forces 8 virtual CPU devices before the first jax import;
when that failed (jax was already loaded), the mesh tests skip rather
than mis-measure against a 1-device "mesh"."""
import copy
import dataclasses

import pytest

from repro.core import CompiledQuery, VolcanoEngine, preset
from repro.core import ir
from repro.core.mesh import resolve_shards
from repro.core.passes.pipeline import Settings, optimize
from repro.core.plan_cache import PlanCache
from repro.relational.queries import QUERIES

from test_queries import SORT_INSENSITIVE, assert_same


def _devices() -> int:
    import jax

    return len(jax.devices())


def _needs(n):
    return pytest.mark.skipif(
        _devices() < n,
        reason=f"needs {n} simulated devices (jax imported before conftest "
               "could set XLA_FLAGS)")


def sharded(n: int) -> Settings:
    return dataclasses.replace(preset("opt"), shards=n)


@pytest.fixture(scope="module")
def oracle(db):
    eng = VolcanoEngine(db)
    return {name: eng.execute(fn()) for name, fn in QUERIES.items()}


# -- tier-1 smoke: 2-device mesh ---------------------------------------------

@_needs(2)
@pytest.mark.parametrize("qname", ["q1", "q6", "q12"])
def test_two_shard_smoke(db, oracle, qname):
    """Fast 2-device check: a routed-table scan+agg (q1/q6) and one
    co-partitioned lineitem-orders join (q12) against the oracle."""
    cq = CompiledQuery(QUERIES[qname](), db, sharded(2))
    assert cq.n_shards == 2
    res = cq.run()
    assert_same(res, oracle[qname], qname in SORT_INSENSITIVE)
    # running twice exercises the per-shard observation merge path
    res2 = cq.run()
    assert_same(res2, oracle[qname], qname in SORT_INSENSITIVE)


@_needs(2)
def test_exchange_placement_minimal(db):
    """Co-partitioned pipelines shard without data movement: q6 (no join)
    and q12 (lineitem routed to the orders partition root) must lower
    with zero Exchange nodes; the verifier runs inside optimize()."""
    for qname in ("q6", "q12"):
        lowered = optimize(QUERIES[qname](), db, sharded(2))
        n_ex = sum(isinstance(n, ir.Exchange) for n in ir.walk(lowered))
        assert n_ex == 0, f"{qname}: gratuitous Exchange planted"


@_needs(2)
def test_exchange_count_bounded(db):
    """Per-query Exchange count never exceeds the number of eligible
    consumers (non-co-partitioned join builds + global sort/limit/agg
    inputs + partitioned root).  The verifier's `exchange-count` rule
    enforces the bound inside optimize(); this re-counts it end to end."""
    for qname in sorted(QUERIES):
        lowered = optimize(QUERIES[qname](), db, sharded(2))
        n_ex = sum(isinstance(n, ir.Exchange) for n in ir.walk(lowered))
        n_joins = sum(isinstance(n, ir.Join) for n in ir.walk(lowered))
        n_tail = sum(isinstance(n, (ir.Sort, ir.Limit, ir.Agg))
                     for n in ir.walk(lowered))
        assert n_ex <= n_joins + n_tail + 1, qname


def test_mesh_shape_joins_cache_key(db):
    plan = QUERIES["q6"]
    cache = PlanCache(db)
    k1 = cache.key_for(plan(), preset("opt"))
    if _devices() >= 2:
        k2 = cache.key_for(plan(), sharded(2))
        assert k1 != k2
    # auto (shards=0) must key on the RESOLVED device count, not the raw 0
    k_auto = cache.key_for(plan(), preset("opt-shard"))
    assert resolve_shards(preset("opt-shard")) in k_auto[:-1]
    assert k_auto != k1


def test_batch_compile_rejects_mesh(db):
    if _devices() < 2:
        pytest.skip("needs 2 devices")
    from repro.core.compile import CompiledQueryBatch

    with pytest.raises(NotImplementedError):
        CompiledQueryBatch([QUERIES["q6"]()], db, sharded(2))


# -- full sweep: 4-device mesh (slow) ----------------------------------------

@pytest.mark.slow
@_needs(4)
@pytest.mark.parametrize("qname", sorted(QUERIES))
def test_four_shard_matches_oracle(db, oracle, qname):
    cq = CompiledQuery(QUERIES[qname](), db, sharded(4))
    assert cq.n_shards == 4
    assert_same(cq.run(), oracle[qname], qname in SORT_INSENSITIVE)
