"""Vectorized bind-many execution: `CompiledQuery.run_many` (one vmapped
XLA dispatch for N bindings), `PlanCache.execute_many` (plan-key
partitioning + bucket-padding accounting), and the QueryServer's
coalescing window."""
import numpy as np
import pytest

from repro.core import PlanCache, VolcanoEngine, preset
from repro.core import compile as compile_mod
from repro.core.compile import bucket_size
from repro.relational.queries import (PARAM_ALT_BINDINGS as ALT_BINDINGS,
                                      PARAM_QUERIES)
from repro.relational.schema import days
from repro.serve.query_server import QueryServer
from test_queries import assert_same


def q6_bindings(n):
    """n distinct q6 bindings (vary the quantity cutoff)."""
    _, defaults = PARAM_QUERIES["q6"]
    return [dict(defaults, qty_max=10.0 + 0.35 * i) for i in range(n)]


def assert_identical(got: dict, want: dict):
    """Bit-for-bit: batched and scalar paths run the same staged program,
    so even float results must agree exactly."""
    assert set(got) == set(want)
    for k in got:
        np.testing.assert_array_equal(got[k], want[k], err_msg=k)


# ---------------------------------------------------------------------------
# acceptance criterion: 64 bindings of q6 -> ONE XLA execution, results
# matching 64 sequential run() calls bit-for-bit and the Volcano oracle.
# ---------------------------------------------------------------------------

def test_run_many_64_single_dispatch_matches_sequential_and_oracle(db):
    build, defaults = PARAM_QUERIES["q6"]
    cache = PlanCache(db)
    cq, _ = cache.get(build(), preset("opt"), defaults)
    cq.run_many(q6_bindings(64))          # warm: traces bucket 64 once

    bindings = [dict(b, qty_max=b["qty_max"] + 0.1) for b in q6_bindings(64)]
    stagings = compile_mod.STAGINGS
    traces, execs = cq.n_batch_traces, cq.n_executions
    batched = cq.run_many(bindings)
    assert cq.n_executions - execs == 1, "64 bindings must be ONE dispatch"
    assert cq.n_batch_traces - traces == 0, "warm bucket must not retrace"
    assert compile_mod.STAGINGS - stagings == 0, "run_many must not re-stage"

    sequential = [cq.run(b) for b in bindings]
    oracle = VolcanoEngine(db)
    for b, got, want in zip(bindings, batched, sequential):
        assert_identical(got, want)
        assert_same(got, oracle.execute(build(), b), sort_insensitive=False)


@pytest.mark.parametrize("qname", sorted(PARAM_QUERIES))
def test_run_many_matches_sequential_all_param_queries(db, qname):
    """Every parameterized workload (incl. the new q12/q14/q19 classes)
    produces identical results batched vs scalar."""
    build, defaults = PARAM_QUERIES[qname]
    cache = PlanCache(db)
    cq, runtime = cache.get(build(), preset("opt"), defaults)
    alt = {k: v for k, v in ALT_BINDINGS[qname].items() if k in runtime}
    bindings = [runtime, dict(runtime, **alt), runtime]
    for got, want in zip(cq.run_many(bindings),
                         [cq.run(b) for b in bindings]):
        assert_identical(got, want)


def test_bucket_padding_bounds_retraces(db):
    """Batch sizes are padded to power-of-two buckets: 5 and 6 share the
    8-bucket (one trace), 9 opens the 16-bucket."""
    assert [bucket_size(n) for n in (1, 2, 3, 5, 8, 9, 64, 65)] == \
        [1, 2, 4, 8, 8, 16, 64, 128]
    build, defaults = PARAM_QUERIES["q6"]
    cache = PlanCache(db)
    cq, _ = cache.get(build(), preset("opt"), defaults)
    base = cq.n_batch_traces
    r5 = cq.run_many(q6_bindings(5))
    assert len(r5) == 5 and cq.n_batch_traces - base == 1
    cq.run_many(q6_bindings(6))            # same bucket: no retrace
    assert cq.n_batch_traces - base == 1
    cq.run_many(q6_bindings(9))            # next bucket
    assert cq.n_batch_traces - base == 2
    # padded slots are sliced off: batch 5 results equal scalar runs
    for got, want in zip(r5, [cq.run(b) for b in q6_bindings(5)]):
        assert_identical(got, want)


def test_execute_many_partitions_by_plan_key(db):
    """Compile-time params split the batch: q3 with two distinct LIMIT
    values runs as two groups against two cache entries, and results come
    back positionally."""
    build, defaults = PARAM_QUERIES["q3"]
    cache = PlanCache(db)
    reqs = [dict(defaults),
            dict(defaults, topn=5),
            dict(defaults, cutoff=days("1995-06-15")),   # same key as [0]
            dict(defaults, topn=5, cutoff=days("1995-06-15"))]
    results = cache.execute_many(build(), preset("opt"), reqs)
    assert cache.stats.compiles == 2       # one per LIMIT value
    assert cache.stats.batch_traces == 2   # one vmapped trace per group
    # groups of 2 pad to bucket 2: no padded slots here
    assert cache.stats.padded_slots == 0
    for req, got in zip(reqs, results):
        assert len(next(iter(got.values()))) == req["topn"]
        assert_same(got, VolcanoEngine(db).execute(build(), req),
                    sort_insensitive=True)


def test_execute_many_padding_accounting(db):
    build, defaults = PARAM_QUERIES["q6"]
    cache = PlanCache(db)
    cache.execute_many(build(), preset("opt"), q6_bindings(5))
    assert cache.stats.padded_slots == 3   # bucket 8 - batch 5
    assert cache.stats.batch_traces == 1


# ---------------------------------------------------------------------------
# server coalescing window
# ---------------------------------------------------------------------------

def test_server_coalesces_same_key_requests_into_one_dispatch(db):
    """64 concurrent q6 requests inside one window -> one group, one
    vmapped XLA execution, results scattered back per request."""
    build, _ = PARAM_QUERIES["q6"]
    bindings = q6_bindings(64)
    with QueryServer(db, preset("opt"), window_s=30.0,
                     max_batch=128) as srv:
        futs = [srv.submit(build(), b) for b in bindings]
        srv.drain()                       # flushes the partial window
        results = [f.result(timeout=60) for f in futs]
        assert srv.stats.batches == 1
        assert srv.stats.coalesced == 64
        assert srv.stats.completed == 64 and srv.stats.errors == 0
        assert srv.cache.stats.compiles == 1
        cq, _ = srv.cache.get(build(), preset("opt"), bindings[0])
        assert cq.n_executions == 1, "the whole window must be ONE dispatch"
    oracle = VolcanoEngine(db)
    for b, got in zip(bindings, results):
        assert_same(got, oracle.execute(build(), b), sort_insensitive=False)


def test_server_windows_partition_by_plan_key(db):
    """Requests for different plan keys never share a window: q6 and the
    two structural variants of q3 form three batches."""
    b6, d6 = PARAM_QUERIES["q6"]
    b3, d3 = PARAM_QUERIES["q3"]
    reqs = [(b6(), dict(d6)),
            (b3(), dict(d3)),
            (b6(), dict(d6, qty_max=30.0)),
            (b3(), dict(d3, topn=5)),
            (b6(), dict(d6, qty_max=35.0))]
    with QueryServer(db, preset("opt"), window_s=30.0) as srv:
        results = srv.serve_batch(reqs)
        assert srv.stats.batches == 3
        assert srv.stats.coalesced == 3    # the three q6 riders
        assert srv.cache.stats.compiles == 3
    oracle = VolcanoEngine(db)
    for (plan, bindings), got in zip(reqs, results):
        assert_same(got, oracle.execute(plan, bindings),
                    sort_insensitive=True)


def test_server_drain_flushes_partial_window(db):
    """Satellite: traffic stopping mid-tick must not strand requests — a
    window far from full (and with an hour-long deadline) completes as
    soon as drain() is called."""
    build, defaults = PARAM_QUERIES["q6"]
    with QueryServer(db, preset("opt"), window_s=3600.0,
                     max_batch=64) as srv:
        futs = [srv.submit(build(), b) for b in q6_bindings(3)]
        assert not any(f.done() for f in futs)
        srv.drain()
        assert all(f.done() for f in futs)
        assert srv.stats.completed == 3 and srv.stats.errors == 0
        assert srv.stats.batches == 1


def test_server_cancelled_request_does_not_poison_window_or_drain(db):
    """Regression: a client cancelling its future mid-window must neither
    fail the rest of the group nor deadlock drain() (plain-CANCELLED
    futures don't count as complete for concurrent.futures.wait until
    notified via the executor protocol)."""
    build, defaults = PARAM_QUERIES["q6"]
    with QueryServer(db, preset("opt"), window_s=3600.0,
                     max_batch=64) as srv:
        futs = [srv.submit(build(), b) for b in q6_bindings(5)]
        assert futs[2].cancel()
        srv.drain()
        assert all(f.done() for f in futs)
        assert srv.stats.errors == 0
        others = [f.result(timeout=60) for i, f in enumerate(futs) if i != 2]
        assert len(others) == 4
        want = VolcanoEngine(db).execute(build(), q6_bindings(5)[0])
        assert_same(others[0], want, sort_insensitive=False)


def test_server_full_window_dispatches_without_tick(db):
    """A window hitting max_batch flushes immediately even though its
    deadline is far away."""
    build, _ = PARAM_QUERIES["q6"]
    with QueryServer(db, preset("opt"), window_s=3600.0,
                     max_batch=4) as srv:
        futs = [srv.submit(build(), b) for b in q6_bindings(4)]
        results = [f.result(timeout=120) for f in futs]
        assert len(results) == 4
        assert srv.stats.batches == 1 and srv.stats.coalesced == 4
