"""Plan IR structural helpers: children/replace_children/walk must agree
on every node type (the analysis layer and every pass traverse through
them), and plan_repr must surface the physical annotations."""
import pytest

from repro.core import ir, preset
from repro.core.expr import Cmp, col, lit
from repro.core.passes.pipeline import optimize
from repro.relational.queries import QUERIES


def _nodes():
    scan = ir.Scan("lineitem")
    scan2 = ir.Scan("orders")
    sel = ir.Select(scan, Cmp("<", col("l_quantity"), lit(24.0)))
    proj = ir.Project(scan, {"q": col("l_quantity")}, keep_input=False)
    join = ir.Join(scan, scan2, "l_orderkey", "o_orderkey")
    agg = ir.Agg(scan, ["l_returnflag"], [ir.AggSpec("n", "count")])
    compact = ir.Compact(scan, 1024)
    sort = ir.Sort(scan, [("l_quantity", True)])
    limit = ir.Limit(sort, 5)
    return {
        "Scan": (scan, []),
        "Select": (sel, [scan]),
        "Project": (proj, [scan]),
        "Join": (join, [scan, scan2]),
        "Agg": (agg, [scan]),
        "Compact": (compact, [scan]),
        "Sort": (sort, [scan]),
        "Limit": (limit, [sort]),
    }


@pytest.mark.parametrize("name", list(_nodes()))
def test_children_per_node_type(name):
    node, kids = _nodes()[name]
    assert ir.children(node) == kids


@pytest.mark.parametrize("name", list(_nodes()))
def test_replace_children_round_trips(name):
    node, kids = _nodes()[name]
    fresh = [ir.Scan("part") for _ in kids]
    ir.replace_children(node, fresh)
    assert ir.children(node) == fresh
    ir.replace_children(node, kids)
    assert ir.children(node) == kids


def test_join_replace_children_order():
    stream, build = ir.Scan("lineitem"), ir.Scan("orders")
    j = ir.Join(stream, build, "l_orderkey", "o_orderkey")
    s2, b2 = ir.Scan("partsupp"), ir.Scan("part")
    ir.replace_children(j, [s2, b2])
    assert j.stream is s2 and j.build is b2


def test_walk_is_preorder_and_complete():
    nodes = _nodes()
    limit = nodes["Limit"][0]
    got = list(ir.walk(limit))
    assert got[0] is limit
    assert [type(n).__name__ for n in got] == ["Limit", "Sort", "Scan"]
    join = nodes["Join"][0]
    got = list(ir.walk(join))
    assert got[0] is join
    assert got[1] is join.stream and got[2] is join.build


def test_walk_visits_every_node_of_real_plans():
    for fn in QUERIES.values():
        plan = fn()
        seen = list(ir.walk(plan))
        # every child of every visited node is itself visited
        ids = {id(n) for n in seen}
        for n in seen:
            for c in ir.children(n):
                assert id(c) in ids


def test_plan_repr_shows_physical_annotations(db):
    plan = optimize(QUERIES["q3"](), db, preset("opt"))
    rep = ir.plan_repr(plan)
    assert "pk_gather" in rep
    assert "build_table=" in rep
    assert "date_slice[" in rep and ".." in rep
    assert "cols=[" in rep            # pruned column lists, not counts
    assert "Compact(cap=" in rep and "point=c" in rep


def test_plan_repr_composite_and_domains(db):
    plan = optimize(QUERIES["q9full"](), db, preset("opt"))
    rep = ir.plan_repr(plan)
    assert "l_suppkey=ps_suppkey" in rep      # second key pair shown
    assert "bucket_width=" in rep
    plan = optimize(QUERIES["q1"](), db, preset("opt"))
    rep = ir.plan_repr(plan)
    assert "domains=" in rep                  # dense agg planned domains
