"""End-to-end correctness: every engine configuration must produce the
same result as the interpreted Volcano oracle for every TPC-H query."""
import numpy as np
import pytest

from repro.core import CompiledQuery, VolcanoEngine, preset
from repro.relational.queries import QUERIES

CONFIGS = ["naive", "template", "tpch", "strdict", "opt"]

# The exhaustive 5-config x 15-query sweep takes many minutes; by default
# only the ladder endpoints run (naive = compilation without domain
# knowledge, opt = everything).  `pytest -m slow` (or `-m ""`) restores the
# full matrix.
FAST_CONFIGS = ["naive", "opt"]
CONFIG_PARAMS = [
    pytest.param(c) if c in FAST_CONFIGS
    else pytest.param(c, marks=pytest.mark.slow)
    for c in CONFIGS
]


@pytest.fixture(scope="module")
def oracle(db):
    eng = VolcanoEngine(db)
    return {name: eng.execute(fn()) for name, fn in QUERIES.items()}


def canon(res: dict[str, np.ndarray], sort: bool) -> dict[str, np.ndarray]:
    """Canonicalize: round floats, optionally sort rows by all columns."""
    out = {}
    names = sorted(res)
    if not sort:
        return {k: res[k] for k in names}
    keys = []
    for k in names:
        v = res[k]
        keys.append(np.round(v.astype(np.float64), 2) if v.dtype.kind == "f" else v)
    order = np.lexsort(tuple(reversed(keys)))
    return {k: res[k][order] for k in names}


def assert_same(a: dict, b: dict, sort_insensitive: bool):
    assert set(a) == set(b), f"columns differ: {set(a)} vs {set(b)}"
    ca, cb = canon(a, sort_insensitive), canon(b, sort_insensitive)
    for k in ca:
        va, vb = ca[k], cb[k]
        assert len(va) == len(vb), f"{k}: {len(va)} vs {len(vb)} rows"
        if va.dtype.kind == "f" or vb.dtype.kind == "f":
            np.testing.assert_allclose(
                va.astype(np.float64), vb.astype(np.float64),
                rtol=2e-3, atol=1e-2, err_msg=k)
        else:
            np.testing.assert_array_equal(va, vb, err_msg=k)


# Queries whose final ordering can differ under float ties — compare as sets.
SORT_INSENSITIVE = {"q10", "q18", "q3"}


@pytest.mark.parametrize("config", CONFIG_PARAMS)
@pytest.mark.parametrize("qname", sorted(QUERIES))
def test_engine_matches_oracle(db, oracle, qname, config):
    cq = CompiledQuery(QUERIES[qname](), db, preset(config))
    res = cq.run()
    assert_same(res, oracle[qname], qname in SORT_INSENSITIVE)


@pytest.mark.parametrize("qname", sorted(QUERIES))
def test_oracle_nonempty(oracle, qname):
    res = oracle[qname]
    n = len(next(iter(res.values())))
    assert n > 0, f"{qname} returned no rows — predicate constants degenerate"
