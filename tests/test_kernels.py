"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes/dtypes/group counts, plus hypothesis property tests."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings as hsettings, strategies as st
except ImportError:   # degrade gracefully: property tests skip, rest run
    from _hypothesis_stub import given, hsettings, st  # noqa: F401

from repro.kernels import ops, ref


@pytest.mark.parametrize("n", [1, 7, 100, 2048, 5000])
@pytest.mark.parametrize("n_groups", [1, 6, 25, 130])
@pytest.mark.parametrize("n_aggs", [1, 3, 8])
def test_filter_agg_matches_ref(n, n_groups, n_aggs):
    rng = np.random.default_rng(n * 1000 + n_groups + n_aggs)
    mask = jnp.asarray(rng.random(n) < 0.6)
    gidx = jnp.asarray(rng.integers(0, n_groups, n), dtype=jnp.int32)
    vals = jnp.asarray(rng.normal(size=(n, n_aggs)), dtype=jnp.float32)
    out = ops.filter_agg(mask, gidx, vals, n_groups, tile=1024)
    want = ref.filter_agg_ref(mask, gidx, vals, n_groups)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n", [3, 513, 4096])
@pytest.mark.parametrize("k,c", [(5, 1), (25, 4), (640, 3)])
def test_gather_join_matches_ref(n, k, c):
    rng = np.random.default_rng(n + k + c)
    fk = jnp.asarray(rng.integers(0, k, n), dtype=jnp.int32)
    table = jnp.asarray(rng.normal(size=(k, c)), dtype=jnp.float32)
    out = ops.gather_join(fk, table, tile=512)
    want = ref.gather_join_ref(fk, table)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("n", [10, 1000, 9001])
@pytest.mark.parametrize("k", [1, 10, 32])
def test_masked_topk_matches_ref(n, k):
    rng = np.random.default_rng(n + k)
    # distinct values so ordering is unambiguous
    vals = jnp.asarray(rng.permutation(n).astype(np.float32))
    mask = jnp.asarray(rng.random(n) < 0.7)
    tv, ti = ops.masked_topk(vals, mask, k, tile=2048)
    wv, wi = ref.masked_topk_ref(vals, mask, k)
    np.testing.assert_allclose(tv, wv, rtol=0, atol=0)
    np.testing.assert_array_equal(np.asarray(ti), np.asarray(wi))


# ---------------------------------------------------------------------------
# single-pass stream compaction (+ key→slot translation)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 5, 37, 2048, 5000])
@pytest.mark.parametrize("cap", [8, 64, 512])
@pytest.mark.parametrize("p", [0.0, 0.05, 0.5, 1.0])
def test_compact_matches_ref(n, cap, p):
    """Sweep crosses the interesting regimes: count == 0 (p=0), heavy
    overflow (p=1 with cap < n), partial tiles (n not a tile multiple)."""
    rng = np.random.default_rng(n * 7 + cap + int(p * 10))
    mask = jnp.asarray(rng.random(n) < p)
    idx, count = ops.compact(mask, cap, tile=1024)
    widx, wcount = ref.compact_ref(mask, cap)
    assert int(count) == int(wcount) == int(np.asarray(mask).sum())
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(widx))


@pytest.mark.parametrize("n,cap", [(100, 16), (2500, 256), (64, 8)])
def test_compact_translate_matches_ref(n, cap):
    rng = np.random.default_rng(n + cap)
    mask = jnp.asarray(rng.random(n) < 0.3)
    idx, count, slot = ops.compact_translate(mask, cap, tile=512)
    widx, wcount = ref.compact_ref(mask, cap)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(widx))
    np.testing.assert_array_equal(np.asarray(slot),
                                  np.asarray(ref.slot_of_ref(mask)))
    assert int(count) == int(wcount)


def test_compact_overflow_keeps_exact_count():
    """count is the cumsum total, NOT clipped at capacity — the excess IS
    the overflow signal and its magnitude drives re-planning."""
    mask = jnp.ones((300,), dtype=bool)
    idx, count = ops.compact(mask, 16, tile=128)
    assert int(count) == 300
    np.testing.assert_array_equal(np.asarray(idx), np.arange(16))


def test_compact_vmapped():
    """vmap over batched masks (the run_many path stages kernels under
    vmap): per-slot results must equal per-slot scalar calls."""
    import jax

    rng = np.random.default_rng(0)
    masks = jnp.asarray(rng.random((4, 200)) < 0.25)
    bidx, bcount = jax.vmap(lambda m: ops.compact(m, 32, tile=64))(masks)
    for i in range(4):
        idx, count = ops.compact(masks[i], 32, tile=64)
        np.testing.assert_array_equal(np.asarray(bidx[i]), np.asarray(idx))
        assert int(bcount[i]) == int(count)


def test_compact_pred_matches_ref():
    """In-kernel predicate evaluation from named column blocks + scalars."""
    rng = np.random.default_rng(3)
    n = 777
    cols = {"a": jnp.asarray(rng.normal(size=n), jnp.float32),
            "b": jnp.asarray(rng.integers(0, 10, n), jnp.int32)}
    scalars = [jnp.float32(0.2)]

    def pred(c, s):
        return (c["a"] < s[0]) & (c["b"] >= 3)

    idx, count, slot = ops.compact_pred(cols, scalars, pred, 128,
                                        tile=256, translate=True)
    mask = pred(cols, scalars)
    widx, wcount = ref.compact_ref(mask, 128)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(widx))
    np.testing.assert_array_equal(np.asarray(slot),
                                  np.asarray(ref.slot_of_ref(mask)))
    assert int(count) == int(wcount)


# ---------------------------------------------------------------------------
# the fused selective pipeline: pred -> compact -> segment-reduce, one pass
# ---------------------------------------------------------------------------

def _pipeline_case(n, n_groups, seed):
    rng = np.random.default_rng(seed)
    cols = {"x": jnp.asarray(rng.normal(size=n), jnp.float32),
            "g": jnp.asarray(rng.integers(0, max(n_groups, 1), n), jnp.int32)}
    scalars = [jnp.float32(0.5)]
    pred = lambda c, s: c["x"] < s[0]
    vals = lambda c, s: [c["x"] * 2.0, jnp.float32(1.0)]
    gidx = None if n_groups == 1 else (lambda c, s: c["g"])
    return cols, scalars, pred, vals, gidx


@pytest.mark.parametrize("n", [1, 20, 1000, 4097])
@pytest.mark.parametrize("n_groups", [1, 7, 64])
@pytest.mark.parametrize("capacity", [0, 64])
def test_selective_filter_agg_matches_ref(n, n_groups, capacity):
    cols, scalars, pred, vals, gidx = _pipeline_case(n, n_groups, n)
    translate = capacity > 0
    got = ops.selective_filter_agg(cols, scalars, pred, vals, gidx, 2,
                                   n_groups, capacity, translate, tile=512)
    want = ref.selective_filter_agg_ref(cols, scalars, pred, vals, gidx, 2,
                                        n_groups, capacity, translate)
    assert len(got) == len(want)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               rtol=1e-5, atol=1e-4)
    assert int(got[1]) == int(want[1])
    for g, w in zip(got[2:], want[2:]):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_selective_filter_agg_empty_and_full():
    """count == 0 (no row passes) and all-pass both behave: zero sums /
    identity compaction respectively."""
    n = 130
    cols = {"x": jnp.asarray(np.arange(n), jnp.float32)}
    scalars = []
    vals = lambda c, s: [c["x"]]
    never = lambda c, s: c["x"] < -1.0
    sums, count, idx = ops.selective_filter_agg(
        cols, scalars, never, vals, None, 1, 1, capacity=16, tile=64)
    assert int(count) == 0
    assert float(np.asarray(sums).sum()) == 0.0
    np.testing.assert_array_equal(np.asarray(idx), np.zeros(16))
    always = lambda c, s: c["x"] >= 0.0
    sums, count, idx = ops.selective_filter_agg(
        cols, scalars, always, vals, None, 1, 1, capacity=256, tile=64)
    assert int(count) == n
    np.testing.assert_allclose(float(np.asarray(sums)[0, 0]),
                               float(np.arange(n).sum()), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(idx)[:n], np.arange(n))


# ---------------------------------------------------------------------------
# property tests (system invariants)
# ---------------------------------------------------------------------------

@hsettings(max_examples=25, deadline=None)
@given(st.integers(1, 400), st.integers(1, 12), st.integers(0, 2**31 - 1))
def test_filter_agg_total_invariant(n, g, seed):
    """Sum over groups == masked sum over rows (conservation)."""
    rng = np.random.default_rng(seed)
    mask = jnp.asarray(rng.random(n) < 0.5)
    gidx = jnp.asarray(rng.integers(0, g, n), dtype=jnp.int32)
    vals = jnp.asarray(rng.normal(size=(n, 2)), dtype=jnp.float32)
    out = ops.filter_agg(mask, gidx, vals, g, tile=128)
    total = np.where(np.asarray(mask)[:, None], np.asarray(vals), 0).sum(0)
    np.testing.assert_allclose(np.asarray(out).sum(0), total, rtol=1e-4,
                               atol=1e-4)


@hsettings(max_examples=25, deadline=None)
@given(st.integers(1, 500), st.integers(3, 64), st.integers(0, 2**31 - 1))
def test_compact_prefix_invariant(n, cap, seed):
    """The emitted prefix is exactly the first min(count, cap) valid row
    ids in ascending order, and slot_of inverts it (slot_of[idx[i]] == i)."""
    rng = np.random.default_rng(seed)
    mask = rng.random(n) < rng.random()
    idx, count, slot = ops.compact_translate(jnp.asarray(mask), cap, tile=64)
    idx, slot = np.asarray(idx), np.asarray(slot)
    valid_ids = np.flatnonzero(mask)
    k = min(int(count), cap)
    np.testing.assert_array_equal(idx[:k], valid_ids[:k])
    np.testing.assert_array_equal(idx[k:], 0)
    np.testing.assert_array_equal(slot[mask], np.arange(len(valid_ids)))
    assert (slot[~mask] == -1).all()


@hsettings(max_examples=25, deadline=None)
@given(st.integers(1, 300), st.integers(2, 50), st.integers(0, 2**31 - 1))
def test_gather_join_row_identity(n, k, seed):
    """Gathering the identity table returns one-hot rows that sum to 1."""
    rng = np.random.default_rng(seed)
    fk = jnp.asarray(rng.integers(0, k, n), dtype=jnp.int32)
    table = jnp.eye(k, dtype=jnp.float32)
    out = np.asarray(ops.gather_join(fk, table, tile=128))
    np.testing.assert_allclose(out.sum(1), np.ones(n), atol=1e-6)
    np.testing.assert_array_equal(out.argmax(1), np.asarray(fk))
