"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes/dtypes/group counts, plus hypothesis property tests."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings as hsettings, strategies as st
except ImportError:   # degrade gracefully: property tests skip, rest run
    from _hypothesis_stub import given, hsettings, st  # noqa: F401

from repro.kernels import ops, ref


@pytest.mark.parametrize("n", [1, 7, 100, 2048, 5000])
@pytest.mark.parametrize("n_groups", [1, 6, 25, 130])
@pytest.mark.parametrize("n_aggs", [1, 3, 8])
def test_filter_agg_matches_ref(n, n_groups, n_aggs):
    rng = np.random.default_rng(n * 1000 + n_groups + n_aggs)
    mask = jnp.asarray(rng.random(n) < 0.6)
    gidx = jnp.asarray(rng.integers(0, n_groups, n), dtype=jnp.int32)
    vals = jnp.asarray(rng.normal(size=(n, n_aggs)), dtype=jnp.float32)
    out = ops.filter_agg(mask, gidx, vals, n_groups, tile=1024)
    want = ref.filter_agg_ref(mask, gidx, vals, n_groups)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n", [3, 513, 4096])
@pytest.mark.parametrize("k,c", [(5, 1), (25, 4), (640, 3)])
def test_gather_join_matches_ref(n, k, c):
    rng = np.random.default_rng(n + k + c)
    fk = jnp.asarray(rng.integers(0, k, n), dtype=jnp.int32)
    table = jnp.asarray(rng.normal(size=(k, c)), dtype=jnp.float32)
    out = ops.gather_join(fk, table, tile=512)
    want = ref.gather_join_ref(fk, table)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("n", [10, 1000, 9001])
@pytest.mark.parametrize("k", [1, 10, 32])
def test_masked_topk_matches_ref(n, k):
    rng = np.random.default_rng(n + k)
    # distinct values so ordering is unambiguous
    vals = jnp.asarray(rng.permutation(n).astype(np.float32))
    mask = jnp.asarray(rng.random(n) < 0.7)
    tv, ti = ops.masked_topk(vals, mask, k, tile=2048)
    wv, wi = ref.masked_topk_ref(vals, mask, k)
    np.testing.assert_allclose(tv, wv, rtol=0, atol=0)
    np.testing.assert_array_equal(np.asarray(ti), np.asarray(wi))


# ---------------------------------------------------------------------------
# property tests (system invariants)
# ---------------------------------------------------------------------------

@hsettings(max_examples=25, deadline=None)
@given(st.integers(1, 400), st.integers(1, 12), st.integers(0, 2**31 - 1))
def test_filter_agg_total_invariant(n, g, seed):
    """Sum over groups == masked sum over rows (conservation)."""
    rng = np.random.default_rng(seed)
    mask = jnp.asarray(rng.random(n) < 0.5)
    gidx = jnp.asarray(rng.integers(0, g, n), dtype=jnp.int32)
    vals = jnp.asarray(rng.normal(size=(n, 2)), dtype=jnp.float32)
    out = ops.filter_agg(mask, gidx, vals, g, tile=128)
    total = np.where(np.asarray(mask)[:, None], np.asarray(vals), 0).sum(0)
    np.testing.assert_allclose(np.asarray(out).sum(0), total, rtol=1e-4,
                               atol=1e-4)


@hsettings(max_examples=25, deadline=None)
@given(st.integers(1, 300), st.integers(2, 50), st.integers(0, 2**31 - 1))
def test_gather_join_row_identity(n, k, seed):
    """Gathering the identity table returns one-hot rows that sum to 1."""
    rng = np.random.default_rng(seed)
    fk = jnp.asarray(rng.integers(0, k, n), dtype=jnp.int32)
    table = jnp.eye(k, dtype=jnp.float32)
    out = np.asarray(ops.gather_join(fk, table, tile=128))
    np.testing.assert_allclose(out.sum(1), np.ones(n), atol=1e-6)
    np.testing.assert_array_equal(out.argmax(1), np.asarray(fk))
