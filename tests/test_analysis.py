"""Schema & property inference (core/analysis): dtype/provenance facts,
derived cardinality/alignment properties, memoization, and the overhead
bound the ISSUE acceptance criteria pin down."""
import dataclasses
import time

import pytest

from repro.core import VolcanoEngine, ir, preset
from repro.core.analysis import (ColInfo, SchemaError, analyze,
                                 base_colinfo, composite_pack_bound,
                                 schema_of)
from repro.core.expr import Arith, Cmp, col, lit
from repro.core.passes.pipeline import optimize
from repro.relational.queries import QUERIES


# ---------------------------------------------------------------------------
# schema inference
# ---------------------------------------------------------------------------

def test_root_schema_matches_volcano_output_columns(db):
    eng = VolcanoEngine(db)
    for qname in ["q1", "q3", "q6", "q7", "q12", "q14"]:
        plan = QUERIES[qname]()
        sch = schema_of(plan, db)
        got = eng.execute(QUERIES[qname]())
        assert set(got) <= set(sch), (
            f"{qname}: Volcano emits {set(got) - set(sch)} outside the "
            "inferred schema")


def test_base_column_facts(db):
    sch = schema_of(ir.Scan("lineitem"), db)
    assert sch["l_quantity"].dtype == "float"
    assert sch["l_shipdate"].dtype == "date"
    assert sch["l_shipmode"].dtype == "code"
    assert sch["l_comment"].dtype == "string" if "l_comment" in sch else True
    # FK provenance: l_orderkey indexes orders' dense PK
    assert sch["l_orderkey"].parent == "orders"
    assert sch["l_orderkey"].domain == db.table("orders").nrows
    # CAT domain is the vocabulary size
    assert sch["l_shipmode"].domain == len(db.table("lineitem").vocabs["l_shipmode"])
    # PK of a single-key table is its own parent
    osch = schema_of(ir.Scan("orders"), db)
    assert osch["o_orderkey"].parent == "orders"


def test_rename_inherits_provenance(db):
    p = ir.Project(ir.Scan("nation"), {"n1_key": col("n_nationkey")},
                   keep_input=False)
    sch = schema_of(p, db)
    assert set(sch) == {"n1_key"}
    assert sch["n1_key"].parent == "nation"
    assert sch["n1_key"].table == "nation" and sch["n1_key"].col == "n_nationkey"


def test_computed_output_dtype(db):
    p = ir.Project(ir.Scan("lineitem"),
                   {"rev": Arith("*", col("l_extendedprice"),
                                 col("l_discount")),
                    "cnt": Arith("+", col("l_linenumber"), lit(1))},
                   keep_input=False)
    sch = schema_of(p, db)
    assert sch["rev"].dtype == "float" and sch["rev"].table is None
    assert sch["cnt"].dtype == "int"


def test_dangling_column_raises_schema_error(db):
    p = ir.Project(ir.Scan("orders"), {"x": col("no_such_col")})
    with pytest.raises(SchemaError):
        schema_of(p, db)
    with pytest.raises(SchemaError):
        schema_of(ir.Scan("orders", columns=["o_orderkey", "bogus"]), db)


def test_join_schema_union_and_semi(db):
    li, o = ir.Scan("lineitem"), ir.Scan("orders")
    inner = ir.Join(li, o, "l_orderkey", "o_orderkey")
    sch = schema_of(inner, db)
    assert "o_orderdate" in sch and "l_quantity" in sch
    semi = ir.Join(ir.Scan("lineitem"), ir.Scan("orders"),
                   "l_orderkey", "o_orderkey", kind="semi")
    sch = schema_of(semi, db)
    assert "o_orderdate" not in sch and "l_quantity" in sch


# ---------------------------------------------------------------------------
# derived properties
# ---------------------------------------------------------------------------

def test_scan_properties(db):
    a = analyze(ir.Scan("lineitem"), db)
    info = a.info(a.plan)
    assert info.card == db.table("lineitem").nrows
    assert info.aligned == "lineitem"
    sliced = ir.Scan("lineitem",
                     date_slice=ir.DateSlice("l_shipdate", 9000, 9400))
    info = analyze(sliced, db).info(sliced)
    assert 0 < info.card < db.table("lineitem").nrows
    assert info.aligned is None           # slice re-packs rows
    assert info.clustered_by == "l_shipdate"
    assert info.sorted_by == (("l_shipdate", True),)


def test_select_keeps_compact_kills_alignment(db):
    sel = ir.Select(ir.Scan("orders"), Cmp("<", col("o_totalprice"),
                                           lit(1000.0)))
    a = analyze(sel, db)
    assert a.info(sel).aligned == "orders"
    cap = ir.Compact(ir.Select(ir.Scan("orders"),
                               Cmp("<", col("o_totalprice"), lit(1000.0))),
                     2048)
    a = analyze(cap, db)
    assert a.info(cap).aligned is None
    assert a.info(cap).card == 2048
    measure = ir.Compact(ir.Scan("orders"), 0)   # measure-only point
    a = analyze(measure, db)
    assert a.info(measure).aligned == "orders"


def test_limit_sort_agg_cards(db):
    agg = ir.Agg(ir.Scan("lineitem"), ["l_returnflag"],
                 [ir.AggSpec("n", "count")])
    srt = ir.Sort(agg, [("l_returnflag", True)])
    lim = ir.Limit(srt, 2)
    a = analyze(lim, db)
    assert a.info(lim).card == 2
    assert a.info(srt).sorted_by == (("l_returnflag", True),)
    scalar = ir.Agg(ir.Scan("lineitem"), [], [ir.AggSpec("n", "count")])
    assert analyze(scalar, db).info(scalar).card == 1


def test_join_inherits_stream_properties(db):
    li = ir.Scan("lineitem")
    j = ir.Join(li, ir.Scan("orders"), "l_orderkey", "o_orderkey")
    a = analyze(j, db)
    assert a.info(j).card == db.table("lineitem").nrows
    assert a.info(j).aligned == "lineitem"


def test_memoization_single_visit(db):
    plan = QUERIES["q3"]()
    a = analyze(plan, db)
    first = {id(n): a.info(n) for n in ir.walk(plan)}
    again = {id(n): a.info(n) for n in ir.walk(plan)}
    for k in first:
        assert first[k] is again[k]       # same NodeInfo object: memoized


def test_base_colinfo_cache_revalidates_on_stats_mutation(db):
    ci = base_colinfo("orders", "o_orderkey", db)
    st = db.table("orders").stats["o_orderkey"]
    old = st.max
    try:
        st.max = old + 12345
        ci2 = base_colinfo("orders", "o_orderkey", db)
        assert ci2.hi == old + 12345      # cache did not serve stale stats
        assert ci2 is not ci
    finally:
        st.max = old
    ci3 = base_colinfo("orders", "o_orderkey", db)
    assert ci3.hi == old


def test_composite_pack_bound():
    K2, packed = composite_pack_bound(100, [9, 7])
    assert K2 == 10 and packed == 100 * 10 + 9
    K2, packed = composite_pack_bound(None, [9])
    assert K2 == 10 and packed is None
    K2, packed = composite_pack_bound(5, [])
    assert K2 == 1 << 20 and packed == 5 * K2 + (K2 - 1)


def test_colinfo_is_immutable():
    ci = ColInfo("int", "orders", "o_orderkey")
    with pytest.raises(dataclasses.FrozenInstanceError):
        ci.dtype = "float"


# ---------------------------------------------------------------------------
# overhead bound (ISSUE acceptance: analysis <= 5% of optimize on q1..q19)
# ---------------------------------------------------------------------------

def test_analysis_overhead_bound(db):
    s_on = preset("opt")
    s_off = dataclasses.replace(s_on, verify_passes=False)
    for fn in QUERIES.values():                     # warm caches/sketches
        optimize(fn(), db, s_on)

    def best(f, r=5):
        times = []
        for _ in range(r):
            t0 = time.perf_counter()
            f()
            times.append(time.perf_counter() - t0)
        return min(times)

    t_opt = best(lambda: [optimize(fn(), db, s_on)
                          for fn in QUERIES.values()])
    finals = [optimize(fn(), db, s_off) for fn in QUERIES.values()]
    t_an = best(lambda: [analyze(p, db) for p in finals])
    # one full analysis pass over every query's final plan costs <= 5% of
    # the default (shipped, verifier-on) optimize sweep
    assert t_an <= 0.05 * t_opt, (
        f"analysis {t_an * 1e3:.2f}ms vs optimize {t_opt * 1e3:.2f}ms "
        f"({100 * t_an / t_opt:.1f}% > 5%)")
