"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step + one decode step on CPU; output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, smoke_config
from repro.models import Ctx, decode_step, forward_train, init_cache, init_params

CTX = Ctx(mesh=None)

# The full 3-test x 11-arch smoke matrix costs many minutes of CPU jit; by
# default one representative of each family runs (dense attention, MoE,
# recurrent/xLSTM).  `pytest -m slow` (or `-m ""`) restores the full matrix.
FAST_ARCHS = ["qwen1_5_0_5b", "granite_moe_1b_a400m", "xlstm_125m"]
ARCH_PARAMS = [
    pytest.param(a) if a in FAST_ARCHS
    else pytest.param(a, marks=pytest.mark.slow)
    for a in ARCHS
]


def _batch(cfg, b=2, s=16):
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                                   dtype=jnp.int32),
             "targets": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                                    dtype=jnp.int32)}
    if cfg.encoder_layers:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, s // 4, cfg.d_model)), dtype=jnp.float32)
    if cfg.n_patches:
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_patches, cfg.d_model)),
            dtype=jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_smoke_forward(arch):
    cfg = smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits = jax.jit(lambda p, b: forward_train(p, b, cfg, CTX))(params, batch)
    b, s = batch["tokens"].shape
    extra = cfg.n_patches
    assert logits.shape == (b, s + extra, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_smoke_train_step(arch):
    from repro.train.train_step import make_train_state, train_step

    cfg = smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    state = make_train_state(params)
    batch = _batch(cfg)
    state2, metrics = jax.jit(
        lambda st, b: train_step(st, b, cfg, CTX))(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # parameters actually moved
    delta = jax.tree.reduce(
        lambda a, l: a + float(jnp.abs(l).sum()),
        jax.tree.map(jnp.subtract, state2.params, state.params), 0.0)
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_smoke_decode(arch):
    cfg = smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, smax = 2, 24
    cache = init_cache(cfg, b, smax, s_enc=8 if cfg.encoder_layers else 0)
    tok = jnp.ones((b,), jnp.int32)
    logits, cache2 = jax.jit(
        lambda p, t, c, pos: decode_step(p, t, c, pos, cfg, CTX))(
        params, tok, cache, jnp.int32(5))
    assert logits.shape == (b, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert jax.tree.structure(cache2) == jax.tree.structure(cache)


def test_full_configs_match_assignment():
    """The exact published numbers from the assignment block."""
    c = get_config("qwen1_5_0_5b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab, c.qkv_bias) == (24, 1024, 16, 16, 2816, 151_936, True)
    c = get_config("chatglm3_6b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab, c.rope) == (28, 4096, 32, 2, 13_696, 65_024, "half")
    c = get_config("phi3_medium_14b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (40, 5120, 40, 10, 17_920, 100_352)
    c = get_config("h2o_danube3_4b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab, c.attn) == (24, 3840, 32, 8, 10_240, 32_000, "swa")
    c = get_config("seamless_m4t_large_v2")
    assert (c.n_layers + c.encoder_layers, c.d_model, c.d_ff,
            c.vocab) == (24, 1024, 8192, 256_206)
    c = get_config("deepseek_v2_236b")
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab, c.n_experts, c.topk,
            c.kv_lora, c.moe_d_ff) == (60, 5120, 128, 102_400, 160, 6, 512,
                                       1536)
    c = get_config("granite_moe_1b_a400m")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.vocab,
            c.n_experts, c.topk, c.moe_d_ff) == (24, 1024, 16, 8, 49_155,
                                                 32, 8, 512)
    c = get_config("internvl2_76b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (80, 8192, 64, 8, 28_672, 128_256)
    c = get_config("xlstm_125m")
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab, c.d_ff) == (
        12, 768, 4, 50_304, 0)
    c = get_config("jamba_v0_1_52b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab, c.n_experts, c.topk) == (32, 4096, 32, 8, 14_336,
                                              65_536, 16, 2)
    assert c.pattern[4] == "attn" and c.pattern.count("mamba") == 7
