"""Distributed-substrate behaviour: checkpoint/restore round trip, async
atomicity, fault-tolerant driver recovery, straggler detection, data
pipeline determinism, gradient compression numerics, serving engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import (AsyncCheckpointer, latest_step,
                                         restore, save)
from repro.configs import smoke_config
from repro.data.pipeline import TokenPipeline
from repro.models import Ctx, init_params
from repro.runtime.fault_tolerance import StragglerStats, TrainDriver
from repro.train.grad_compression import compress_grads, ef_init
from repro.train.optimizer import AdamConfig
from repro.train.train_step import make_train_state, train_step

CTX = Ctx(mesh=None)


@pytest.fixture()
def tiny():
    cfg = smoke_config("qwen1_5_0_5b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_checkpoint_roundtrip(tmp_path, tiny):
    cfg, params = tiny
    state = make_train_state(params)
    path = save(str(tmp_path), 7, state)
    assert os.path.exists(os.path.join(path, "manifest.json"))
    assert latest_step(str(tmp_path)) == 7
    restored = restore(str(tmp_path), 7, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_async(tmp_path, tiny):
    cfg, params = tiny
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for step in (1, 2, 3):
        ck.save(step, {"w": jnp.ones((4,)) * step})
    ck.wait()
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_00000002", "step_00000003"]
    r = restore(str(tmp_path), 3, {"w": jnp.zeros((4,))})
    np.testing.assert_array_equal(np.asarray(r["w"]), 3 * np.ones(4))


def test_pipeline_determinism_and_sharding():
    kw = dict(vocab=100, batch=8, seq_len=16, seed=42)
    p1 = TokenPipeline(**kw)
    p2 = TokenPipeline(**kw)
    b1, b2 = p1.batch_at(5), p2.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(p1.batch_at(5)["tokens"],
                              p1.batch_at(6)["tokens"])
    # host sharding: different hosts draw different slices
    h0 = TokenPipeline(**kw, host=0, n_hosts=2).batch_at(5)
    h1 = TokenPipeline(**kw, host=1, n_hosts=2).batch_at(5)
    assert h0["tokens"].shape[0] == 4
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_pipeline_prefetch():
    p = TokenPipeline(vocab=50, batch=4, seq_len=8)
    p.start(from_step=3)
    it = iter(p)
    s, b = next(it)
    assert s == 3 and b["tokens"].shape == (4, 8)
    s2, _ = next(it)
    assert s2 == 4
    p.stop()


def test_grad_compression_error_feedback(tiny):
    cfg, params = tiny
    grads = jax.tree.map(
        lambda p: jnp.full(p.shape, 1e-3, jnp.float32), params)
    ef = ef_init(params)
    total = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    for _ in range(8):
        dq, ef = compress_grads(grads, ef)
        total = jax.tree.map(jnp.add, total, dq)
    # error feedback: accumulated dequantized grads converge to 8 x grads
    for t, g in zip(jax.tree.leaves(total), jax.tree.leaves(grads)):
        np.testing.assert_allclose(np.asarray(t), 8 * np.asarray(g),
                                   rtol=0.02, atol=1e-5)


def test_driver_recovers_from_failures(tmp_path, tiny):
    cfg, params = tiny
    state = make_train_state(params)
    pipe = TokenPipeline(vocab=cfg.vocab, batch=2, seq_len=16)
    stepper = jax.jit(lambda st, b: train_step(
        st, {k: jnp.asarray(v) for k, v in b.items()}, cfg, CTX,
        AdamConfig(lr=1e-3)))
    boom = {"armed": True}

    def fail_hook(step):
        if step == 5 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("simulated node failure")

    drv = TrainDriver(step_fn=stepper, state=state, pipeline=pipe,
                      ckpt_dir=str(tmp_path), ckpt_every=2,
                      fail_hook=fail_hook)
    final = drv.run(8)
    assert drv.recoveries == 1
    assert len([m for m in drv.metrics_log if m["step"] == 7]) >= 1
    assert int(final.opt.step) > 0
    losses = [m["loss"] for m in drv.metrics_log]
    assert all(np.isfinite(losses))


def test_straggler_detection():
    st = StragglerStats(threshold=2.0)
    for i in range(10):
        st.observe(i, 0.1)
    assert st.observe(10, 1.0)          # 10x the EMA -> flagged
    assert st.slow_steps and st.slow_steps[-1][0] == 10
    assert not st.observe(11, 0.1)


def test_serve_engine_continuous_batching(tiny):
    from repro.serve.batcher import Request, ServeEngine

    cfg, params = tiny
    eng = ServeEngine(params, cfg, CTX, slots=2, max_len=32)
    reqs = [Request(rid=i, prompt=np.arange(3 + i, dtype=np.int32) % cfg.vocab,
                    max_new=4) for i in range(4)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    assert all(r.done for r in reqs)
    for r in reqs:
        assert 1 <= len(r.out) <= r.max_new + 1
        assert all(0 <= t < cfg.vocab for t in r.out)


def test_elastic_restore_reshape(tmp_path):
    """Restore onto a different (logical) target: dtype/shape adaptation."""
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    save(str(tmp_path), 1, tree)
    like = {"w": jax.ShapeDtypeStruct((4, 4), jnp.bfloat16)}
    out = restore(str(tmp_path), 1, like)
    assert out["w"].dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out["w"], np.float32),
                               np.arange(16).reshape(4, 4))
