"""Selection-vector compaction: oracle equivalence of compacted plans,
overflow-fallback correctness, the retrace bound (≤ one trace per capacity
bucket), and capacity wiring into the plan-cache key."""
import dataclasses

import numpy as np
import pytest

from repro.core import CompiledQuery, PlanCache, VolcanoEngine, preset
from repro.core import compile as compile_mod
from repro.core import ir
from repro.core.expr import Cmp, col, lit
from repro.core.ir import Agg, AggSpec, Compact, Scan, Select
from repro.core.passes.compaction import strip_compaction
from repro.relational.queries import (PARAM_ALT_BINDINGS as ALT_BINDINGS,
                                      PARAM_QUERIES, QUERIES)
from test_queries import assert_same

CONFIGS = ["naive", "template", "tpch", "strdict", "opt"]
# mirror test_queries: ladder endpoints always, interior rungs under -m slow
FAST_CONFIGS = ["naive", "opt"]
CONFIG_PARAMS = [
    pytest.param(c) if c in FAST_CONFIGS
    else pytest.param(c, marks=pytest.mark.slow)
    for c in CONFIGS
]
TARGETS = ["q3", "q6", "q19"]


def _compacted(settings):
    return dataclasses.replace(settings, compaction=True)


def _mask_only(settings):
    return dataclasses.replace(settings, compaction=False)


# ---------------------------------------------------------------------------
# oracle equivalence: compacted vs mask-only plans, every preset
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("config", CONFIG_PARAMS)
@pytest.mark.parametrize("qname", TARGETS)
def test_compacted_matches_mask_only_and_oracle(db, qname, config):
    want = VolcanoEngine(db).execute(QUERIES[qname]())
    on = CompiledQuery(QUERIES[qname](), db,
                       _compacted(preset(config))).run()
    off = CompiledQuery(QUERIES[qname](), db,
                        _mask_only(preset(config))).run()
    assert_same(on, want, sort_insensitive=True)
    assert_same(off, want, sort_insensitive=True)


@pytest.mark.parametrize("qname", TARGETS + ["q12"])
def test_compacted_param_variants_match_oracle(db, qname):
    """Param plans keep runtime predicates un-estimable (selectivity 1.0
    for Param bounds), but compile-time params and static conjuncts still
    plant points — both bindings must match the oracle."""
    build, defaults = PARAM_QUERIES[qname]
    cache = PlanCache(db)
    oracle = VolcanoEngine(db)
    for bindings in (defaults, dict(defaults, **ALT_BINDINGS[qname])):
        got = cache.execute(build(), _compacted(preset("opt")), bindings)
        assert_same(got, oracle.execute(build(), bindings),
                    sort_insensitive=True)


def test_compaction_points_planted_on_selective_queries(db):
    """The pass must actually fire on the selective workload (capacities
    are power-of-two buckets strictly below the stream cardinality)."""
    planted = {}
    for qname in ("q3", "q5", "q7", "q10"):
        cq = CompiledQuery(QUERIES[qname](), db, preset("opt"))
        planted[qname] = cq.capacities
        assert cq.compaction_points == len(cq.capacities)
    assert any(planted.values()), f"no compaction anywhere: {planted}"
    n_li = db.table("lineitem").nrows
    for qname, caps in planted.items():
        for cap in caps:
            assert cap & (cap - 1) == 0, f"{qname}: {cap} not a pow2 bucket"
            assert cap < n_li


# ---------------------------------------------------------------------------
# overflow fallback
# ---------------------------------------------------------------------------

def _overflowing_plan():
    """Hand-planted Compact whose capacity is far below the surviving
    rows: every execution must overflow and fall back."""
    sel = Select(Scan("lineitem"), Cmp("<", col("l_quantity"), lit(26.0)))
    return Agg(Compact(sel, 64), [],
               [AggSpec("s", "sum", col("l_extendedprice")),
                AggSpec("c", "count")])


def test_overflow_falls_back_to_uncompacted_twin(db):
    want = VolcanoEngine(db).execute(_overflowing_plan())
    before = compile_mod.STAGINGS
    cq = CompiledQuery(_overflowing_plan(), db, preset("opt"))
    assert cq.compaction_points == 1
    r1 = cq.run()
    assert cq.n_overflows == 1
    # the fallback twin staged exactly once (plus the compacted program)
    assert compile_mod.STAGINGS - before == 2
    r2 = cq.run()
    assert cq.n_overflows == 2
    assert compile_mod.STAGINGS - before == 2, \
        "repeat overflows must reuse the compiled twin"
    assert_same(r1, want, sort_insensitive=False)
    assert_same(r2, want, sort_insensitive=False)


def test_overflow_fallback_in_batched_execution(db):
    """run_many with a hand-planted overflowing point: every slot falls
    back and still matches the scalar path."""
    build, defaults = PARAM_QUERIES["q6"]
    plan = build()
    # squeeze the q6 select through a 64-row bucket: defaults survive far
    # more rows than that, so all slots overflow
    assert isinstance(plan.child, Select)
    plan = Agg(Compact(plan.child, 64), [], plan.aggs)
    cq = CompiledQuery(plan, db, preset("opt"), params=defaults)
    bindings = [defaults, dict(defaults, qty_max=30.0), defaults]
    batched = cq.run_many(bindings)
    assert cq.n_overflows >= len(bindings)
    for got, b in zip(batched, [cq.run(b) for b in bindings]):
        for k in got:
            np.testing.assert_array_equal(got[k], b[k], err_msg=k)


def test_overflow_fallback_with_compaction_pass_disabled(db):
    """A hand-planted Compact can overflow even when the pass is off
    (e.g. a ladder preset); the fallback twin must still exist."""
    want = VolcanoEngine(db).execute(_overflowing_plan())
    cq = CompiledQuery(_overflowing_plan(), db, preset("naive"))
    assert cq.compaction_points == 1
    got = cq.run()
    assert cq.n_overflows == 1
    assert_same(got, want, sort_insensitive=False)


def test_strip_compaction_removes_every_point(db):
    plan = _overflowing_plan()
    stripped = strip_compaction(plan)
    assert not [n for n in ir.walk(stripped) if isinstance(n, Compact)]


def test_planner_capacities_do_not_overflow(db):
    """The margin + pow2 bucket must hold the actual surviving rows for
    the literal TPC-H workload (overflow would silently double latency)."""
    for qname in sorted(QUERIES):
        cq = CompiledQuery(QUERIES[qname](), db, preset("opt"))
        cq.run()
        assert cq.n_overflows == 0, \
            f"{qname} overflowed its planned capacities {cq.capacities}"


# ---------------------------------------------------------------------------
# retrace bound + plan-cache wiring
# ---------------------------------------------------------------------------

def test_one_trace_per_capacity_bucket(db):
    """Re-binding runtime params on a compacted plan re-executes the same
    jitted program: one scalar trace, one vmapped trace per batch bucket,
    no re-staging — the capacity buckets are static shapes of one entry."""
    build, defaults = PARAM_QUERIES["q12"]
    cache = PlanCache(db)
    cq, runtime = cache.get(build(), preset("opt"), defaults)
    assert cq.compaction_points, "q12's receipt-window plan must compact"
    before = compile_mod.STAGINGS
    alt = {k: v for k, v in ALT_BINDINGS["q12"].items() if k in runtime}
    for b in (runtime, dict(runtime, **alt), runtime):
        cache.execute(build(), preset("opt"), dict(defaults, **b))
    assert cq.n_traces == 1
    assert compile_mod.STAGINGS - before == 0
    cache.run_many(cq, [runtime, dict(runtime, **alt)])
    cache.run_many(cq, [dict(runtime, **alt), runtime])
    assert cq.n_batch_traces == 1          # one bucket-2 trace, reused
    assert cq.n_overflows == 0


def test_capacities_are_part_of_the_plan_key(db):
    cache = PlanCache(db)
    s_on, s_off = preset("opt"), _mask_only(preset("opt"))
    plan = QUERIES["q3"]()
    key_on = cache.key_for(plan, s_on)
    key_off = cache.key_for(plan, s_off)
    assert key_off[-1] == ()
    # the key's capacity vector is exactly the compiled entry's static
    # shapes, and deterministic: same plan, same buckets
    cq, _ = cache.get(QUERIES["q3"](), s_on)
    assert key_on[-1] == cq.capacities and cq.capacities
    assert cache.key_for(QUERIES["q3"](), s_on) == key_on


def test_cache_counts_compactions_and_overflows(db):
    cache = PlanCache(db)
    cache.execute(QUERIES["q3"](), preset("opt"))
    cache.execute(QUERIES["q3"](), preset("opt"))
    assert cache.stats.compactions == 2
    assert cache.stats.overflows == 0
    key, plan = None, _overflowing_plan()
    cache.execute(plan, preset("opt"))
    assert cache.stats.compactions == 3
    assert cache.stats.overflows == 1


# ---------------------------------------------------------------------------
# dense-agg group-count estimate (ROADMAP residual: q3 top-k)
# ---------------------------------------------------------------------------

def test_dense_agg_output_compacts_before_sort(db):
    """The balls-in-bins group estimate (live key population from join
    match fractions x distinct-count stats) must come in tight enough to
    plant a Compact between q3's Sort and its dense Agg — the naive
    min(valid rows, domain) bound never did — without overflowing, and
    with oracle-identical results."""
    cq = CompiledQuery(QUERIES["q3"](), db, preset("opt"))
    planted = [n for n in ir.walk(cq.plan) if isinstance(n, ir.Sort)
               and isinstance(n.child, Compact)
               and isinstance(n.child.child, Agg)
               and n.child.child.strategy == "dense"]
    assert planted, "no Compact planted between Sort and the dense Agg"
    point = planted[0].child
    domain = 1
    for d in planted[0].child.child.domains:
        domain *= d
    # the win the planner demands: capacity at least 2x under the
    # uncompacted dense output the Sort would otherwise consume
    assert point.capacity * 2 <= domain
    got = cq.run()
    assert cq.n_overflows == 0, f"overflowed {cq.capacities}"
    assert_same(got, VolcanoEngine(db).execute(QUERIES["q3"]()),
                sort_insensitive=True)


def test_dense_group_estimate_tightens_but_stays_safe(db):
    """Param-bound q3 under both default and alternative bindings: the
    tightened capacities must neither overflow nor drift from the oracle
    (the estimate only narrows capacity, never correctness)."""
    build, defaults = PARAM_QUERIES["q3"]
    cache = PlanCache(db)
    oracle = VolcanoEngine(db)
    for bindings in (defaults, dict(defaults, **ALT_BINDINGS["q3"])):
        got = cache.execute(build(), preset("opt"), bindings)
        assert_same(got, oracle.execute(build(), bindings),
                    sort_insensitive=True)
    assert cache.stats.overflows == 0
