"""Fallback decorators when `hypothesis` is not installed: property tests
become `pytest.importorskip("hypothesis")` skips while every non-property
test in the module still collects and runs (the dev dependency set in
requirements-dev.txt installs the real thing)."""
import pytest


def given(*_args, **_kwargs):
    def deco(fn):
        def _skipped():
            pytest.importorskip("hypothesis")
        _skipped.__name__ = fn.__name__
        _skipped.__doc__ = fn.__doc__
        return _skipped
    return deco


def hsettings(*_args, **_kwargs):
    return lambda fn: fn


class _AnyStrategy:
    """Accepts any strategies.<name>(...) call at decoration time."""

    def __getattr__(self, _name):
        return lambda *a, **k: None


st = _AnyStrategy()
