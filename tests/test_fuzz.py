"""Plan fuzzer (analysis/fuzz.py): seeded random plans must be
verifier-clean at every ladder rung and compiled execution must match the
Volcano oracle.  The fast tier runs a small sample; the nightly CI runs
`python -m repro.core.analysis.fuzz --n 200` (and `-m slow` here)."""
import numpy as np
import pytest

from repro.core import ir
from repro.core.analysis.fuzz import random_plan, run_fuzz


def test_random_plans_are_deterministic(db):
    a = ir.plan_repr(random_plan(np.random.default_rng(7), db))
    b = ir.plan_repr(random_plan(np.random.default_rng(7), db))
    assert a == b


def test_random_plans_cover_the_shapes(db):
    kinds = set()
    for seed in range(60):
        plan = random_plan(np.random.default_rng(seed), db)
        for n in ir.walk(plan):
            kinds.add(type(n).__name__)
            if isinstance(n, ir.Join):
                kinds.add(f"join:{n.kind}")
                if n.stream_key2:
                    kinds.add("join:composite")
    assert {"Scan", "Select", "Join", "Agg", "Sort", "Project"} <= kinds
    assert {"join:inner", "join:composite"} <= kinds
    assert {"join:semi", "join:anti"} & kinds


def test_fuzz_optimize_clean_across_ladder(db):
    rep = run_fuzz(db, n=40, compile_every=0)    # optimize-only, all rungs
    assert rep.n_plans == 40
    assert rep.ok, rep.failures[:3]


def test_fuzz_compiled_matches_oracle(db):
    rep = run_fuzz(db, n=5, presets=["opt"], compile_presets=["naive", "opt"])
    assert rep.n_compiled == 10
    assert rep.ok, rep.failures[:3]


@pytest.mark.slow
def test_fuzz_large(db):
    rep = run_fuzz(db, n=200, compile_every=4)
    assert rep.ok, rep.failures[:5]
