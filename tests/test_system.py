"""System-level behaviour of the staged query compiler: pass annotations,
per-query specialized input sets, and a property test driving random
queries through both engines."""
import numpy as np

try:
    from hypothesis import given, settings as hsettings, strategies as st
except ImportError:   # degrade gracefully: property tests skip, rest run
    from _hypothesis_stub import given, hsettings, st  # noqa: F401

from repro.core import CompiledQuery, VolcanoEngine, optimize, preset
from repro.core import ir
from repro.core.expr import (And, Arith, Cmp, CodeRange, StrIn, col, lit)
from repro.core.ir import Agg, AggSpec, Scan, Select
from repro.relational.queries import QUERIES, q12


def _find(plan, typ):
    return [n for n in ir.walk(plan) if isinstance(n, typ)]


def test_q12_fully_lowered(db):
    """The paper's running example: after the pipeline, Q12's plan is
    specialized end-to-end (Fig 8 -> §3 optimizations)."""
    plan = optimize(q12(), db, preset("opt"))
    scans = _find(plan, ir.Scan)
    li = [s for s in scans if s.table == "lineitem"][0]
    assert li.date_slice is not None             # §3.2.3 date index
    assert li.date_slice.col == "l_receiptdate"  # most selective bound
    assert li.columns is not None and "l_comment" not in li.columns  # §3.6.1
    join = _find(plan, ir.Join)[0]
    assert join.strategy == "pk_gather"          # §3.2.1 partitioning
    assert join.build_table == "orders"
    agg = _find(plan, ir.Agg)[0]
    assert agg.strategy == "dense"               # §3.2.2 hashmap lowering
    assert agg.domains == [7]                    # |shipmode dictionary|
    # §3.4: string predicates lowered to integer code predicates
    kinds = {type(e).__name__ for n in ir.walk(plan)
             if isinstance(n, ir.Select)
             for e in _conjuncts(n.pred)}
    assert "StrIn" not in kinds


def _conjuncts(e):
    from repro.core.expr import conjuncts

    out = []
    for c in conjuncts(e):
        out.append(c)
    return out


def test_naive_preset_leaves_plan_generic(db):
    plan = optimize(q12(), db, preset("naive"))
    assert all(j.strategy == "generic" for j in _find(plan, ir.Join))
    assert all(a.strategy in ("generic", "scalar")
               for a in _find(plan, ir.Agg))
    assert all(s.date_slice is None for s in _find(plan, ir.Scan))


def test_column_pruning_shrinks_inputs(db):
    """§3.6.1: the specialized program loads only referenced columns."""
    full = CompiledQuery(QUERIES["q6"](), db, preset("naive"))
    pruned = CompiledQuery(QUERIES["q6"](), db, preset("opt"))
    assert pruned.input_nbytes() < full.input_nbytes()
    li_cols = [k for k in pruned.inputs if k.startswith("lineitem/col/")]
    assert len(li_cols) <= 4


def test_hoisting_equivalence(db):
    import dataclasses

    s_on = preset("opt")
    s_off = dataclasses.replace(preset("opt"), hoist=False)
    a = CompiledQuery(QUERIES["q3"](), db, s_on).run()
    b = CompiledQuery(QUERIES["q3"](), db, s_off).run()
    for k in a:
        va, vb = a[k], b[k]
        if va.dtype.kind == "f":
            np.testing.assert_allclose(va.astype(float), vb.astype(float),
                                       rtol=1e-3)
        else:
            np.testing.assert_array_equal(va, vb)


def test_string_dict_lowering_is_ordered(db):
    """startsWith lowers to a code range because the dictionary is sorted."""
    from repro.core.passes.string_dict import StringDictionary

    plan = Select(Scan("part"),
                  __import__("repro.core.expr", fromlist=["StrStartsWith"]
                             ).StrStartsWith("p_type", "PROMO"))
    plan = StringDictionary().run(plan, db, preset("opt"))
    pred = plan.pred
    assert isinstance(pred, CodeRange)
    part = db.table("part")
    vocab = part.vocabs["p_type"]
    inside = vocab[pred.lo:pred.hi]
    assert all(v.startswith("PROMO") for v in inside)
    assert not any(v.startswith("PROMO")
                   for v in np.concatenate([vocab[:pred.lo], vocab[pred.hi:]]))


# ---------------------------------------------------------------------------
# property test: random single-table aggregation queries
# ---------------------------------------------------------------------------

NUM_COLS = ["l_quantity", "l_extendedprice", "l_discount", "l_tax"]


@hsettings(max_examples=12, deadline=None)
@given(
    st.sampled_from(NUM_COLS),
    st.sampled_from(["<", "<=", ">", ">="]),
    st.floats(0.0, 1.0),
    st.sampled_from([None, "l_returnflag", "l_shipmode"]),
    st.booleans(),
)
def test_random_query_equivalence(db, valcol, op, frac, group, with_date):
    t = db.table("lineitem")
    lo, hi = t.stats[valcol].min, t.stats[valcol].max
    thresh = float(lo + frac * (hi - lo))
    pred = Cmp(op, col(valcol), lit(thresh))
    if with_date:
        pred = And(pred, Cmp(">=", col("l_shipdate"), lit(9000)))
    aggs = [AggSpec("s", "sum", Arith("*", col("l_extendedprice"),
                                      col("l_quantity"))),
            AggSpec("c", "count")]
    plan_fn = lambda: Agg(Select(Scan("lineitem"), pred),
                          [group] if group else [], list(aggs))
    want = VolcanoEngine(db).execute(plan_fn())
    got = CompiledQuery(plan_fn(), db, preset("opt")).run()
    # canonicalize by group key
    if group:
        oa = np.argsort(want[group])
        ob = np.argsort(got[group])
        np.testing.assert_array_equal(want[group][oa], got[group][ob])
        np.testing.assert_allclose(want["s"][oa].astype(float),
                                   got["s"][ob].astype(float), rtol=2e-3)
        np.testing.assert_array_equal(want["c"][oa], got["c"][ob])
    else:
        np.testing.assert_allclose(want["s"].astype(float),
                                   got["s"].astype(float), rtol=2e-3)
        np.testing.assert_array_equal(want["c"], got["c"])


def test_batch_compilation_matches_singles(db):
    """Beyond-paper cross-query compilation: one staged program for many
    queries returns identical results and shares base-column inputs."""
    from repro.core.compile import CompiledQueryBatch

    names = ["q1", "q6", "q14"]
    batch = CompiledQueryBatch([QUERIES[n]() for n in names], db,
                               preset("opt"))
    res = batch.run()
    singles = [CompiledQuery(QUERIES[n](), db, preset("opt")) for n in names]
    total_single_inputs = sum(len(s.inputs) for s in singles)
    assert len(batch.inputs) < total_single_inputs   # shared scans dedup'd
    for r, s in zip(res, singles):
        want = s.run()
        for k in want:
            if want[k].dtype.kind == "f":
                np.testing.assert_allclose(r[k].astype(float),
                                           want[k].astype(float), rtol=1e-3)
            else:
                np.testing.assert_array_equal(r[k], want[k])
