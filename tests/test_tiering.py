"""Execution tiers (docs §11): ladder, tiered cache, persistence, server.

Covers the four layers the tier abstraction spans:

  * the ladder itself — target derivation, per-tier settings (the
    interpret rung must be *exactly* the server's historical
    `pipeline.degrade`), demotion clamping, promotion paths;
  * the Runnable contract — `OracleQuery` is substitutable for
    `CompiledQuery` (same binding validation, same results, run and
    run_many);
  * the tiered PlanCache — a cold request is served by the oracle with
    ZERO staging, a background promotion hot-swaps the target tier in
    with zero result drift, promotion is deduplicated, and a typed
    compile failure falls back to the ready tier (sticky, no retry
    storm);
  * warm-state persistence — save/load round-trips the compaction
    feedback store and warm hints keyed by content fingerprint; a
    corrupt or mismatched file is a cold start, never a crash.
"""
import dataclasses
import os
import threading

import numpy as np
import pytest

from repro.core import compile as compile_mod
from repro.core import tiering
from repro.core.plan_cache import PlanCache
from repro.core.tiering import (COMPILED, INTERPRET, OPT_PALLAS, ORACLE,
                                Runnable, TierLadder)
from repro.core.volcano import OracleQuery, VolcanoEngine
from repro.core.passes.pipeline import degrade, preset
from repro.relational.queries import (PARAM_ALT_BINDINGS, PARAM_QUERIES,
                                      QUERIES)
from repro.serve.query_server import QueryServer
from tests.test_queries import assert_same

OPT = preset("opt")


# -- the ladder --------------------------------------------------------------

def test_ladder_target_derivation():
    assert TierLadder(OPT).target is COMPILED
    assert TierLadder(dataclasses.replace(OPT, use_pallas=True)).target \
        is OPT_PALLAS
    assert TierLadder(dataclasses.replace(OPT, engine="volcano")).target \
        is ORACLE


def test_ladder_interpret_is_exactly_degrade():
    # the server's shed-plan rung and the cache's interpret tier must be
    # the same settings object value, or the two subsystems would key
    # different plan-cache entries for the same rung
    lad = TierLadder(OPT)
    assert lad.settings_for(INTERPRET) == degrade(OPT)


def test_ladder_settings_preserve_semantics():
    lad = TierLadder(dataclasses.replace(OPT, use_pallas=True))
    assert lad.settings_for(COMPILED).use_pallas is False
    assert lad.settings_for(ORACLE).engine == "volcano"
    with pytest.raises(ValueError):
        TierLadder(OPT).settings_for(OPT_PALLAS)


def test_ladder_demote_clamps():
    lad = TierLadder(OPT)
    assert lad.demote(COMPILED) is INTERPRET
    assert lad.demote(COMPILED, 2) is ORACLE
    assert lad.demote(ORACLE, 5) is ORACLE


def test_promotion_path():
    lad = TierLadder(OPT)
    assert lad.promotion_path(ORACLE) == [COMPILED]
    assert lad.promotion_path(ORACLE, through=True) == [INTERPRET, COMPILED]
    assert lad.promotion_path(COMPILED) == []
    assert tiering.tier("oracle") is ORACLE
    with pytest.raises(KeyError):
        tiering.tier("warp-speed")


# -- the Runnable contract ---------------------------------------------------

def test_oracle_query_satisfies_runnable(db):
    fn, defaults = PARAM_QUERIES["q6"]
    oq = OracleQuery(fn(), db, params=defaults)
    assert isinstance(oq, Runnable)
    assert oq.tier_name == "oracle"
    assert oq.compaction_points == 0 and oq.n_overflows == 0


def test_oracle_query_matches_compiled(db):
    fn, defaults = PARAM_QUERIES["q6"]
    alt = dict(defaults, **PARAM_ALT_BINDINGS["q6"])
    oq = OracleQuery(fn(), db, params=defaults)
    from repro.core import CompiledQuery
    cq = CompiledQuery(fn(), db, OPT, params=defaults)
    assert_same(oq.run(defaults), cq.run(defaults), False)
    for a, b in zip(oq.run_many([defaults, alt]),
                    cq.run_many([defaults, alt])):
        assert_same(a, b, False)
    assert oq.n_executions == 3


def test_oracle_query_binding_validation(db):
    fn, defaults = PARAM_QUERIES["q6"]
    oq = OracleQuery(fn(), db, params=defaults)
    with pytest.raises(KeyError):
        oq.run({"date_lo": 1})          # missing params
    with pytest.raises(KeyError):
        oq.run(dict(defaults, bogus=1))  # unknown param
    plain = OracleQuery(QUERIES["q6"](), db)
    assert plain.param_spec == {}
    assert plain.run() is not None


# -- the tiered cache --------------------------------------------------------

def q6_req():
    fn, defaults = PARAM_QUERIES["q6"]
    return fn(), defaults


def test_cold_serve_is_oracle_with_zero_staging(db):
    cache = PlanCache(db, tiered=True)
    try:
        plan, defaults = q6_req()
        key, prepared, runtime, owned = cache._prepare(plan, OPT, defaults, "residual")
        gate = threading.Event()   # holds the promoter at the door so the
        #                            cold read is deterministic
        before = compile_mod.STAGINGS
        run, _, tier_name = cache._get_tiered_prepared(
            key, prepared, runtime, owned, OPT,
            compile_hook=lambda k: gate.wait(60))
        # the caller's thread never staged anything: request 1 is served
        # before the target tier exists
        assert tier_name == "oracle"
        assert isinstance(run, OracleQuery)
        assert compile_mod.STAGINGS == before
        assert cache.stats.tier_hits == {"oracle": 1}
        assert cache.stats.misses == 1
        gate.set()
    finally:
        cache.close()


def test_promotion_hot_swaps_with_zero_drift(db):
    cache = PlanCache(db, tiered=True)
    try:
        plan, defaults = q6_req()
        key, prepared, runtime, owned = cache._prepare(plan, OPT, defaults, "residual")
        gate = threading.Event()
        run1, _, tier1 = cache._get_tiered_prepared(
            key, prepared, runtime, owned, OPT,
            compile_hook=lambda k: gate.wait(60))
        assert tier1 == "oracle"
        res1 = run1.run(runtime)
        gate.set()
        assert cache.await_promotion(plan, OPT, defaults, timeout=120)
        res2, tier2 = cache.execute_tiered(plan, OPT, defaults)
        assert tier2 == "compiled"
        oracle = VolcanoEngine(db).execute(q6_req()[0], defaults)
        assert_same(res1, oracle, False)
        assert_same(res2, oracle, False)
        assert cache.stats.promotions == 1
        assert cache.stats.promote_failures == 0
        # promoted entry is the canonical one: plain get() now hits
        cq, _ = cache.get(plan, OPT, defaults)
        assert cq.tier_name == "compiled"
    finally:
        cache.close()


def test_promotion_is_deduplicated(db):
    cache = PlanCache(db, tiered=True)
    try:
        plan, defaults = q6_req()
        for _ in range(8):
            _, _, tier_name = cache.get_tiered(plan, OPT, defaults)
        cache.await_promotion(plan, OPT, defaults, timeout=120)
        # eight requests raced the single promotion; exactly one compile
        assert cache.stats.compiles == 1
        assert cache.stats.misses == 1
        assert cache.stats.hits >= 7
    finally:
        cache.close()


def test_promote_through_builds_interpret_rung(db):
    cache = PlanCache(db, tiered=True, promote_through=True)
    try:
        plan, defaults = q6_req()
        cache.get_tiered(plan, OPT, defaults)
        assert cache.await_promotion(plan, OPT, defaults, timeout=240)
        # two rungs landed: interpret then compiled
        assert cache.stats.promotions == 2
        assert cache.stats.compiles == 2
    finally:
        cache.close()


def test_promotion_failure_falls_back_sticky(db):
    cache = PlanCache(db, tiered=True)
    try:
        plan, defaults = q6_req()
        key, prepared, runtime, owned = cache._prepare(plan, OPT, defaults, "residual")
        calls = []

        def boom(k):
            calls.append(k)
            raise RuntimeError("injected compile fault")

        run, _, tier_name = cache._get_tiered_prepared(
            key, prepared, runtime, owned, OPT, compile_hook=boom)
        assert tier_name == "oracle"
        assert not cache.await_promotion(plan, OPT, defaults, timeout=60)
        assert cache.stats.promote_failures == 1
        # the ready tier keeps serving, and the failure is sticky — no
        # promotion retry storm on subsequent requests
        for _ in range(3):
            _, _, t = cache._get_tiered_prepared(
                key, prepared, runtime, owned, OPT, compile_hook=boom)
            assert t == "oracle"
        assert len(calls) == 1
        assert cache.stats.promote_failures == 1
    finally:
        cache.close()


def test_oracle_target_ladder_degenerates(db):
    cache = PlanCache(db, tiered=True)
    try:
        volcano = dataclasses.replace(OPT, engine="volcano")
        plan, defaults = q6_req()
        _, _, tier_name = cache.get_tiered(plan, volcano, defaults)
        assert tier_name == "oracle"
        # nothing to promote toward; await resolves immediately as False
        assert not cache.await_promotion(plan, volcano, defaults, timeout=5)
        assert cache.stats.promotions == 0
    finally:
        cache.close()


# -- persistence -------------------------------------------------------------

def test_warm_state_round_trip(db, tmp_path):
    path = str(tmp_path / "warm.json")
    cache = PlanCache(db)
    plan, defaults = q6_req()
    cache.execute(plan, OPT, defaults)
    # synthesize a converged feedback record: persisted overrides must
    # drive the restored cache's first compile capacities
    base = cache.key_for(plan, OPT, defaults)[:-1]
    fb = cache._feedback[base]
    overrides = {pid: int(v) + 32 for pid, v in fb.est_params.items()
                 if isinstance(v, (int, np.integer))}
    fb.overrides = dict(overrides) or {"p0": 64}
    fb.replans = 2
    assert cache.save(path) >= 1

    fresh = PlanCache(db)
    assert fresh.load(path) >= 1
    assert fresh.stats.restored >= 1
    assert fresh.is_warm(plan, OPT, defaults)
    rec = fresh._feedback[fresh.key_for(plan, OPT, defaults)[:-1]]
    assert rec.overrides == fb.overrides
    assert rec.replans == 2
    # live observations beat stale disk: loading twice doesn't clobber
    assert fresh.load(path) == 0


def test_corrupt_or_mismatched_warm_state_is_cold_start(db, tmp_path):
    cache = PlanCache(db)
    missing = str(tmp_path / "nope.json")
    assert cache.load(missing) == 0
    truncated = tmp_path / "warm.json"
    truncated.write_text('{"version": 1, "db": "x", "feedback": [{')
    assert cache.load(str(truncated)) == 0
    truncated.write_text('{"version": 99, "db": "x", "feedback": []}')
    assert cache.load(str(truncated)) == 0
    truncated.write_text('{"version": 1, "db": "other", "feedback": []}')
    assert cache.load(str(truncated)) == 0
    assert cache.stats.restored == 0


def test_save_is_atomic_and_versioned(db, tmp_path):
    import json
    path = str(tmp_path / "warm.json")
    cache = PlanCache(db)
    plan, defaults = q6_req()
    cache.execute(plan, OPT, defaults)
    cache.save(path)
    payload = json.loads(open(path).read())
    assert payload["version"] == 1
    assert payload["db"] == db.content_fingerprint()
    assert payload["feedback"][0]["warm"] is True
    assert not [p for p in os.listdir(str(tmp_path))
                if p.startswith(".warm-state-")]


def test_content_fingerprint_stability(db):
    # process-restart stand-in: same data -> same fingerprint; the
    # process-local monotonic fingerprint is NOT what's persisted
    assert db.content_fingerprint() == db.content_fingerprint()
    from repro.relational.loader import Database
    other = Database.tpch(sf=0.01, seed=1)
    assert other.content_fingerprint() != db.content_fingerprint()


# -- the tiered server -------------------------------------------------------

def test_server_ladder_parity(db):
    with QueryServer(db, OPT) as srv:
        # the degradation rung is the ladder's interpret tier — identical
        # to the historical degrade(settings) plan key
        assert srv._degraded_settings == degrade(OPT)
        assert srv.ladder.target is COMPILED


def test_tiered_server_serves_cold_then_promotes(db, tmp_path):
    path = str(tmp_path / "server-warm.json")
    plan_fn, defaults = PARAM_QUERIES["q6"]
    oracle_res = VolcanoEngine(db).execute(plan_fn(), defaults)

    gate = threading.Event()   # deterministic: request 1 beats promotion
    srv = QueryServer(db, OPT, tiered=True, warm_state_path=path,
                      compile_hook=lambda k: gate.wait(60))
    try:
        res1 = srv.submit(plan_fn(), defaults).result(timeout=120)
        assert_same(res1, oracle_res, False)
        assert srv.stats.tier_served.get("oracle", 0) >= 1
        gate.set()
        srv.cache.await_promotion(plan_fn(), OPT, defaults, timeout=120)
        res2 = srv.submit(plan_fn(), defaults).result(timeout=120)
        assert_same(res2, oracle_res, False)
        assert srv.stats.tier_served.get("compiled", 0) >= 1
    finally:
        srv.close()
    assert os.path.exists(path)

    # restart: warm metadata restored, prewarm promotes without traffic
    srv2 = QueryServer(db, OPT, tiered=True, warm_state_path=path)
    try:
        assert srv2.cache.stats.restored >= 1
        assert srv2.prewarm([(plan_fn(), defaults)]) == 1
        assert srv2.cache.await_promotion(plan_fn(), OPT, defaults,
                                          timeout=120)
        res = srv2.submit(plan_fn(), defaults).result(timeout=120)
        assert_same(res, oracle_res, False)
        # request 1 after prewarm runs on the target tier, not the oracle
        assert srv2.stats.tier_served == {"compiled": 1}
    finally:
        srv2.close()


def test_tiered_cache_run_many_skips_pad_accounting(db):
    cache = PlanCache(db, tiered=True)
    try:
        plan, defaults = q6_req()
        key, prepared, runtime, owned = cache._prepare(plan, OPT, defaults, "residual")
        gate = threading.Event()
        run, runtime, _ = cache._get_tiered_prepared(
            key, prepared, runtime, owned, OPT,
            compile_hook=lambda k: gate.wait(60))
        gate.set()
        assert isinstance(run, OracleQuery)
        alt = dict(defaults, **PARAM_ALT_BINDINGS["q6"])
        results = cache.run_many(run, [runtime, alt, alt])
        assert len(results) == 3
        # the oracle executes bindings one by one: no pow2 bucket, no
        # padded-slot accounting (3 -> bucket 4 would charge 1)
        assert cache.stats.padded_slots == 0
    finally:
        cache.close()


def test_promoter_close_is_idempotent(db):
    cache = PlanCache(db, tiered=True)
    plan, defaults = q6_req()
    cache.get_tiered(plan, OPT, defaults)
    cache.close()
    cache.close()
    # a post-close request still serves the ready tier (promotion is
    # re-armed lazily; the pool was rebuilt or the ladder already done)
    _, _, tier_name = cache.get_tiered(plan, OPT, defaults)
    assert tier_name in ("oracle", "compiled")
    cache.close()
