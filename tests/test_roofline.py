"""Roofline machinery: HLO collective parsing, cost-analysis calibration
(per-device semantics), analytic param counts vs real param trees."""
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.configs import ARCHS, get_config, smoke_config
from repro.launch import roofline as R
from repro.models import init_params
from repro.models.config import SHAPES


def test_collective_parser_operand_bytes():
    hlo = textwrap.dedent("""\
      %dot = f32[256,512]{1,0} dot(%a, %b), lhs_contracting_dims={1}
      %all-reduce = f32[256,512]{1,0} all-reduce(%dot), channel_id=1
      %ag = bf16[64,64]{1,0} all-gather(%small), dimensions={0}
      %small = bf16[8,64]{1,0} add(%x, %y)
    """)
    out = R.collective_bytes(hlo)
    assert out["all-reduce"] == 256 * 512 * 4
    assert out["all-gather"] == 8 * 64 * 2          # operand, not result
    assert out["total"] == out["all-reduce"] + out["all-gather"]


@pytest.mark.slow
def test_cost_analysis_is_per_device():
    """Calibration quoted in roofline.py: SPMD cost analysis reports
    per-device flops (exact 2MKN / n_devices for a sharded matmul).

    slow: forks an 8-host-device XLA compilation subprocess, which takes
    multiple minutes on constrained CPU containers."""
    code = textwrap.dedent("""\
      import os
      os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
      import jax, jax.numpy as jnp, numpy as np
      from jax.sharding import NamedSharding, PartitionSpec as P
      mesh = jax.sharding.Mesh(np.array(jax.devices()).reshape(2,4),
                               ("data","model"))
      M=K=N=256
      f = jax.jit(lambda a,b: a@b,
          in_shardings=(NamedSharding(mesh,P("data",None)),
                        NamedSharding(mesh,P(None,"model"))))
      c = f.lower(jax.ShapeDtypeStruct((M,K),jnp.float32),
                  jax.ShapeDtypeStruct((K,N),jnp.float32)).compile()
      print(c.cost_analysis()["flops"], 2*M*K*N/8)
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env={"PYTHONPATH": "src",
                                         "PATH": "/usr/bin:/bin"})
    got, want = map(float, out.stdout.split())
    assert got == pytest.approx(want)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_matches_tree(arch):
    """Analytic param_count agrees with the actual parameter tree (on the
    reduced config — same formula, same code path as the full config)."""
    cfg = smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    real = sum(p.size for p in jax.tree.leaves(params))
    # exclude tiny per-layer vector params (norm scales/biases) the analytic
    # count ignores: tolerance scales with d_model * n_layers
    est = R.param_count(cfg)
    tol = 0.05 * real + 20 * cfg.d_model * (cfg.n_layers
                                            + cfg.encoder_layers + 2)
    assert abs(est - real) < tol, (arch, est, real)


def test_model_flops_moe_uses_active():
    cfg = get_config("deepseek_v2_236b")
    shape = SHAPES["train_4k"]
    total = R.param_count(cfg)
    active = R.param_count(cfg, active_only=True)
    assert active < 0.25 * total        # 236B total / ~21B active + embeds
    assert R.model_flops(cfg, shape) == pytest.approx(
        6 * active * shape.global_batch * shape.seq_len)


def test_roofline_terms_bottleneck():
    t = R.roofline_terms(197e12, 819e9 * 2, 0.0, 1)
    assert t["bottleneck"] == "memory_s"
    assert t["roofline_fraction"] == pytest.approx(0.5)
