"""Overload-hardened serving: admission control (budget, fairness,
priorities), deadlines, bounded retry of transient faults, the
degradation ladder, adaptive windows, close() grace accounting, the
in-flight-dedup failure path, submit/close races, and the seeded chaos
harness (every future resolves, retried transients succeed, ServerStats
balances exactly, zero oracle drift)."""
import threading
import time

import pytest

from repro.core import VolcanoEngine, preset
from repro.core import compile as compile_mod
from repro.relational.queries import (PARAM_ALT_BINDINGS as ALT_BINDINGS,
                                      PARAM_QUERIES)
from repro.serve.admission import (AdmissionController, DeadlineExceeded,
                                   LatencyHistogram, Overloaded, RateEMA,
                                   TransientError)
from repro.serve.chaos import ChaosSchedule, run_chaos
from repro.serve.query_server import QueryServer
from test_queries import assert_same


def assert_matches(got, want):
    assert_same(got, want, sort_insensitive=True)


def _balanced(stats) -> bool:
    return stats.outstanding() == 0


# ---------------------------------------------------------------------------
# admission controller (pure unit tests, no db)
# ---------------------------------------------------------------------------

def test_admission_budget_and_fairness():
    adm = AdmissionController(budget=4, tenant_frac=0.5)
    adm.admit("a")
    adm.admit("a")
    with pytest.raises(Overloaded) as ei:       # tenant cap = ceil(.5*4) = 2
        adm.admit("a")
    assert ei.value.reason == "fairness" and ei.value.tenant == "a"
    adm.admit("b")
    adm.admit("b")                              # budget now full (4)
    with pytest.raises(Overloaded) as ei:
        adm.admit("c")
    assert ei.value.reason == "budget"
    # release frees both the budget and the tenant's share
    adm.release("a")
    adm.admit("a")
    assert adm.pending() == 4


def test_admission_priority_headroom_and_tenant_bypass():
    adm = AdmissionController(budget=4, tenant_frac=0.5, headroom=1)
    for _ in range(2):
        adm.admit("a")
    # priority bypasses the tenant cap while the budget has room
    adm.admit("a", priority=1)
    adm.admit("b")
    # budget full: normal traffic rejected, priority uses the headroom
    with pytest.raises(Overloaded):
        adm.admit("b")
    adm.admit("b", priority=1)
    with pytest.raises(Overloaded):             # headroom exhausted too
        adm.admit("c", priority=1)
    assert adm.pending() == 5


def test_admission_anonymous_exempt_from_tenant_cap():
    adm = AdmissionController(budget=4, tenant_frac=0.5)
    for _ in range(4):
        adm.admit(None)                         # bounded only by the budget
    with pytest.raises(Overloaded) as ei:
        adm.admit(None)
    assert ei.value.reason == "budget"


def test_latency_histogram_quantiles():
    h = LatencyHistogram()
    for _ in range(90):
        h.observe(0.001)
    for _ in range(10):
        h.observe(1.0)
    assert 0.0003 < h.p50() < 0.0015            # within one octave of 1 ms
    assert 0.3 < h.p99() < 1.5                  # within one octave of 1 s
    assert h.count == 100
    assert 0.09 < h.mean() < 0.12


def test_rate_ema_tracks_arrival_interval():
    ema = RateEMA()
    t = 0.0
    for _ in range(50):
        ema.observe(t)
        t += 0.01
    assert ema.interval() == pytest.approx(0.01, rel=1e-6)
    assert ema.rate() == pytest.approx(100.0, rel=1e-6)


def test_chaos_schedule_replays_from_seed():
    a, b = ChaosSchedule.seeded(5), ChaosSchedule.seeded(5)
    assert (a.compile_fails, a.exec_faults, a.slows) == \
        (b.compile_fails, b.exec_faults, b.slows)
    c = ChaosSchedule.seeded(6)
    assert (a.compile_fails, a.exec_faults, a.slows) != \
        (c.compile_fails, c.exec_faults, c.slows)


# ---------------------------------------------------------------------------
# server behaviors (db-backed)
# ---------------------------------------------------------------------------

def test_adaptive_window_scales_with_arrival_rate(db):
    with QueryServer(db, preset("opt"), window_s=0.0025,
                     max_batch=64) as srv:
        # dense traffic: window ≈ time for a full batch to arrive
        t = 0.0
        for _ in range(50):
            srv._arrivals.observe(t)
            t += 1e-5
        dense = srv._window_len(0)
        assert dense == pytest.approx(64e-5, rel=1e-6)
        # sparse traffic: clamped at 4x the base window
        srv._arrivals = type(srv._arrivals)()
        t = 0.0
        for _ in range(50):
            srv._arrivals.observe(t)
            t += 0.1
        sparse = srv._window_len(0)
        assert sparse == pytest.approx(4 * 0.0025, rel=1e-6)
        # overload rung shrinks both the window and the batch cap
        assert srv._window_len(1) == pytest.approx(sparse / 4, rel=1e-6)
        assert srv._batch_cap(1) == 16 and srv._batch_cap(0) == 64


def test_deadline_miss_fails_typed_without_poisoning_group(db):
    build, defaults = PARAM_QUERIES["q6"]
    with QueryServer(db, preset("opt"), window_s=0.25, max_batch=64,
                     adaptive_window=False) as srv:
        dead = srv.submit(build(), dict(defaults), timeout_s=0.02)
        live = srv.submit(build(), dict(defaults,
                                        **ALT_BINDINGS["q6"]))
        # same window: the flusher dispatches at ~0.25 s, far past the
        # first request's deadline — it must fail alone, typed
        with pytest.raises(DeadlineExceeded):
            dead.result(timeout=60)
        assert_matches(live.result(timeout=60),
                       VolcanoEngine(db).execute(
                           build(), dict(defaults, **ALT_BINDINGS["q6"])))
        srv.drain()
        st = srv.stats
    assert st.deadline_misses == 1
    assert st.errors == 1 and st.completed == 1
    assert _balanced(st)


def test_transient_fault_retried_and_succeeds(db):
    build, defaults = PARAM_QUERIES["q6"]
    calls = []

    def exec_hook(key, attempt):
        calls.append(attempt)
        if len(calls) == 1:
            raise TransientError("injected")

    with QueryServer(db, preset("opt"), exec_hook=exec_hook,
                     window_s=0.001, max_batch=4,
                     retry_backoff_s=0.001) as srv:
        fut = srv.submit(build(), dict(defaults))
        srv.flush()
        got = fut.result(timeout=120)
        st = srv.stats
    assert_matches(got, VolcanoEngine(db).execute(build(), defaults))
    assert calls == [0, 1]            # one failed attempt, one replay
    assert st.retries == 1 and st.errors == 0 and st.completed == 1
    assert _balanced(st)


def test_non_transient_fault_not_retried(db):
    build, defaults = PARAM_QUERIES["q6"]

    def exec_hook(key, attempt):
        raise ValueError("poisoned batch")

    with QueryServer(db, preset("opt"), exec_hook=exec_hook,
                     window_s=0.001, max_batch=4) as srv:
        fut = srv.submit(build(), dict(defaults))
        srv.flush()
        with pytest.raises(ValueError):
            fut.result(timeout=120)
        st = srv.stats
    assert st.retries == 0 and st.errors == 1
    assert _balanced(st)


def test_degradation_ladder_sheds_then_rejects(db):
    """Deterministic walk up the ladder: gate execution so pending grows
    one request at a time; rungs fire off the pre-admission load
    (budget 8: shed_batch at load .5/.625, shed_plan at .75/.875, then
    reject), degraded requests run mask-only plans with identical
    results, and the gate release drains everything cleanly."""
    build, defaults = PARAM_QUERIES["q6"]
    gate = threading.Event()

    def exec_hook(key, attempt):
        assert gate.wait(timeout=120)

    srv = QueryServer(db, preset("opt"), exec_hook=exec_hook,
                      window_s=0.001, max_batch=1, max_workers=2,
                      budget=8, shed_batch_load=0.5, shed_plan_load=0.75)
    try:
        futs = [srv.submit(build(), dict(defaults)) for _ in range(8)]
        with pytest.raises(Overloaded):
            srv.submit(build(), dict(defaults))
        gate.set()
        want = VolcanoEngine(db).execute(build(), defaults)
        for f in futs:
            assert_matches(f.result(timeout=120), want)
    finally:
        gate.set()
        srv.close()
    st = srv.stats
    assert st.shed_batch == 2 and st.shed_plan == 2 and st.rejected == 1
    assert st.completed == 8 and st.errors == 0
    assert srv.cache.stats.degraded == 2
    # degraded settings key their own cache entries (mask-only twin)
    assert srv.cache.stats.compiles == 2
    assert _balanced(st)


def test_inflight_dedup_owner_compile_failure_hands_off(db):
    """Satellite regression: the owner's compile raises -> exactly one
    parked waiter becomes the new owner, recompiles, and the cache ends
    warm; the owner's own window fails with the compile error."""
    build, defaults = PARAM_QUERIES["q6"]
    started, release = threading.Event(), threading.Event()
    calls = []

    def hook(_key):
        calls.append(None)
        if len(calls) == 1:
            started.set()
            assert release.wait(timeout=120)
            raise RuntimeError("boom: owner compile failed")

    before = compile_mod.STAGINGS
    with QueryServer(db, preset("opt"), compile_hook=hook, max_batch=1,
                     window_s=0.001, max_workers=4) as srv:
        f1 = srv.submit(build(), dict(defaults))
        assert started.wait(timeout=120)        # owner inside its compile
        f2 = srv.submit(build(), dict(defaults, **ALT_BINDINGS["q6"]))
        while srv.stats.shared_compiles == 0 and not f2.done():
            time.sleep(0.01)                    # waiter parked on the event
        release.set()                           # owner now raises
        with pytest.raises(RuntimeError, match="boom"):
            f1.result(timeout=120)
        got = f2.result(timeout=120)            # waiter re-owned + compiled
        st, cst = srv.stats, srv.cache.stats
        # cache ends warm: a fresh request is a pure hit
        hits_before = srv.cache.stats.hits
        f3 = srv.submit(build(), dict(defaults))
        srv.flush()
        f3.result(timeout=120)
    assert_matches(got, VolcanoEngine(db).execute(
        build(), dict(defaults, **ALT_BINDINGS["q6"])))
    assert len(calls) == 2                      # one failed, one successful
    assert cst.compiles == 1                    # only the waiter's compile
    assert compile_mod.STAGINGS - before == 1
    assert st.shared_compiles == 1 and st.errors == 1
    assert srv.cache.stats.hits > hits_before


def test_submit_racing_close_raises_before_windowing(db):
    """Satellite: a submit whose _prepare straddles close() must raise at
    the post-prepare closed re-check — never window the request or leave
    a future pending."""
    build, defaults = PARAM_QUERIES["q6"]
    srv = QueryServer(db, preset("opt"))
    entered, closed = threading.Event(), threading.Event()
    real_prepare = srv.cache._prepare

    def stalled_prepare(*a, **kw):
        entered.set()
        assert closed.wait(timeout=120)
        return real_prepare(*a, **kw)

    srv.cache._prepare = stalled_prepare
    result = {}

    def racer():
        try:
            result["fut"] = srv.submit(build(), dict(defaults))
        except BaseException as e:
            result["exc"] = e

    t = threading.Thread(target=racer)
    t.start()
    assert entered.wait(timeout=120)
    srv.close()                   # closes while the submit is in _prepare
    closed.set()
    t.join(timeout=120)
    assert not t.is_alive()
    assert "fut" not in result
    assert isinstance(result["exc"], RuntimeError)
    assert "closed" in str(result["exc"])
    assert srv.stats.submitted == 0 and not srv._windows
    assert _balanced(srv.stats)


def test_close_timeout_knob_counts_grace_expired(db):
    """Satellite: the grace period is a constructor knob, and requests it
    strands are counted in grace_expired — not folded into errors."""
    build, defaults = PARAM_QUERIES["q6"]
    release = threading.Event()

    def exec_hook(key, attempt):
        assert release.wait(timeout=120)    # a stuck worker

    srv = QueryServer(db, preset("opt"), exec_hook=exec_hook,
                      window_s=0.001, max_batch=1, close_timeout_s=0.05)
    fut = srv.submit(build(), dict(defaults))
    srv.flush()
    t0 = time.monotonic()
    srv.close()
    # close() did not wait out the stuck worker
    assert time.monotonic() - t0 < 30
    assert fut.done(), "close() left the stranded future pending"
    with pytest.raises(RuntimeError, match="grace"):
        fut.result()
    st = srv.stats
    assert st.grace_expired == 1 and st.errors == 0
    assert _balanced(st)
    # unstick the worker and join it: its late settle of the already
    # grace-failed future must count nothing
    release.set()
    srv._pool.shutdown(wait=True)
    assert srv.stats.completed == 0 and srv.stats.grace_expired == 1
    assert _balanced(srv.stats)


# ---------------------------------------------------------------------------
# chaos harness (tier-1 acceptance)
# ---------------------------------------------------------------------------

def test_chaos_every_future_resolves_and_stats_balance(db):
    """Seeded chaos: injected compile failures, transient execution
    faults, slow executions, and a mid-window close.  Every submitted
    future resolves (result or typed error), every retried transient
    succeeds, ServerStats balances exactly, and completed results carry
    zero drift vs the Volcano oracle."""
    sched = ChaosSchedule(compile_fails={0}, exec_faults={1, 4},
                          slows={2, 6}, slow_s=0.005)
    report = run_chaos(db, seed=7, n_requests=32, schedule=sched,
                       close_mid_window=True, max_batch=4,
                       window_s=0.002, budget=64)
    st = report["stats"]
    assert report["all_resolved"], "a submitted future never resolved"
    assert report["balanced"], f"stats don't balance: {st}"
    assert st.outstanding() == 0
    assert report["oracle_drift"] == 0
    assert report["retried_ok"], \
        f"retries={st.retries} injected={report['injected']} " \
        f"outcomes={report['outcomes']}"
    # the schedule guarantees each fault family actually fired
    assert report["injected"]["compile_fail"] >= 1
    assert report["injected"]["exec_fault"] >= 1
    assert report["injected"]["slow"] >= 1
    # a compile fault fails its own window, typed
    assert report["outcomes"]["compile_fault"] >= 1
    assert st.errors >= report["outcomes"]["compile_fault"]


def test_chaos_seeded_schedule_run(db):
    """The rate-driven seeded schedule form: still fully resolved and
    balanced (fault counts vary with the seed, invariants must not)."""
    report = run_chaos(db, seed=11, n_requests=24,
                       close_mid_window=False, max_batch=4)
    assert report["all_resolved"] and report["balanced"]
    assert report["oracle_drift"] == 0 and report["retried_ok"]
