"""Runtime layer: plan cache hit/miss accounting, param-bound vs
literal-baked equivalence, single-staging re-binding (the compile-counter
acceptance criterion), and the concurrent query server incl. two requests
sharing one in-flight compilation."""
import threading

import numpy as np
import pytest

from repro.core import CompiledQuery, PlanCache, VolcanoEngine, preset
from repro.core import compile as compile_mod
from repro.relational.queries import (PARAM_ALT_BINDINGS as ALT_BINDINGS,
                                      PARAM_QUERIES, QUERIES)
from repro.relational.schema import days
from repro.serve.query_server import QueryServer
from test_queries import assert_same

CONFIGS = ["naive", "template", "tpch", "strdict", "opt"]


def assert_matches(got, want):
    # param results compare row-order-insensitively: ties under alternative
    # bindings may sort differently between engines
    assert_same(got, want, sort_insensitive=True)


# ---------------------------------------------------------------------------
# acceptance criterion: same parameterized query, two bindings, ONE staging,
# both matching the Volcano oracle under every preset in CONFIGS.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("config", CONFIGS)
def test_rebind_single_staging_matches_oracle(db, config):
    build, defaults = PARAM_QUERIES["q6"]
    alt = dict(defaults, **ALT_BINDINGS["q6"])
    cache = PlanCache(db)
    oracle = VolcanoEngine(db)
    before = compile_mod.STAGINGS
    for bindings in (defaults, alt):
        got = cache.execute(build(), preset(config), bindings)
        want = oracle.execute(build(), bindings)
        assert_matches(got, want)
    assert compile_mod.STAGINGS - before == 1, \
        "re-binding must not re-stage/re-JIT"
    assert cache.stats == type(cache.stats)(hits=1, misses=1, compiles=1)
    # and the jitted program itself traced exactly once
    (cq,) = [cache.get(build(), preset(config), defaults)[0]]
    assert cq.n_traces == 1


@pytest.mark.parametrize("qname", sorted(PARAM_QUERIES))
def test_param_bound_equals_literal_baked(db, qname):
    """Default bindings reproduce the literal query exactly; alternative
    bindings match the oracle evaluated under the same bindings."""
    build, defaults = PARAM_QUERIES[qname]
    cache = PlanCache(db)
    got = cache.execute(build(), preset("opt"), defaults)
    literal = CompiledQuery(QUERIES[qname](), db, preset("opt")).run()
    assert_matches(got, literal)
    alt = dict(defaults, **ALT_BINDINGS[qname])
    assert_matches(cache.execute(build(), preset("opt"), alt),
                   VolcanoEngine(db).execute(build(), alt))


def test_specialize_mode_bakes_every_binding(db):
    build, defaults = PARAM_QUERIES["q6"]
    alt = dict(defaults, **ALT_BINDINGS["q6"])
    cache = PlanCache(db)
    a = cache.execute(build(), preset("opt"), defaults, mode="specialize")
    b = cache.execute(build(), preset("opt"), alt, mode="specialize")
    a2 = cache.execute(build(), preset("opt"), defaults, mode="specialize")
    assert cache.stats.compiles == 2     # one per distinct binding
    assert cache.stats.hits == 1         # repeat binding hits
    assert_matches(a, a2)
    assert not np.allclose(a["revenue"], b["revenue"])


def test_structural_params_key_the_cache(db):
    """String / limit params are compile-time: a new value is a new cache
    entry, a repeated value is a hit."""
    build, defaults = PARAM_QUERIES["q3"]
    cache = PlanCache(db)
    cache.execute(build(), preset("opt"), defaults)
    cache.execute(build(), preset("opt"), dict(defaults, cutoff=days("1995-06-15")))
    assert cache.stats.compiles == 1     # numeric param: same entry
    cache.execute(build(), preset("opt"), dict(defaults, segment="MACHINERY"))
    assert cache.stats.compiles == 2     # string param: new entry
    cache.execute(build(), preset("opt"), dict(defaults, topn=5))
    assert cache.stats.compiles == 3     # limit param: new entry
    got = cache.execute(build(), preset("opt"), dict(defaults, topn=5))
    assert cache.stats.compiles == 3
    assert len(next(iter(got.values()))) == 5


def test_missing_compile_time_binding_raises(db):
    build, defaults = PARAM_QUERIES["q3"]
    cache = PlanCache(db)
    partial = {k: v for k, v in defaults.items() if k != "segment"}
    with pytest.raises(KeyError, match="segment"):
        cache.execute(build(), preset("opt"), partial)


def test_cache_eviction_accounting(db):
    build, defaults = PARAM_QUERIES["q6"]
    cache = PlanCache(db, max_entries=1)
    cache.execute(build(), preset("opt"), defaults)
    cache.execute(build(), preset("naive"), defaults)   # distinct settings
    assert cache.stats.evictions == 1
    assert len(cache) == 1


def test_cache_lru_eviction_order_and_recompile(db):
    """max_entries overflow evicts the *least recently used* entry (a
    fresh hit protects an old entry), stats stay consistent, and
    re-inserting the evicted key recompiles exactly once."""
    build, defaults = PARAM_QUERIES["q6"]
    cache = PlanCache(db, max_entries=2)
    s_opt, s_tpch, s_naive = preset("opt"), preset("tpch"), preset("naive")
    cache.execute(build(), s_opt, defaults)
    cache.execute(build(), s_tpch, defaults)
    cache.execute(build(), s_opt, defaults)      # hit: opt becomes MRU
    cache.execute(build(), s_naive, defaults)    # evicts LRU = tpch
    assert cache.stats.evictions == 1 and len(cache) == 2
    assert cache.contains(cache.key_for(build(), s_opt, defaults))
    assert cache.contains(cache.key_for(build(), s_naive, defaults))
    assert not cache.contains(cache.key_for(build(), s_tpch, defaults))
    # stats stay consistent: every execute was one hit or one miss
    assert cache.stats.hits + cache.stats.misses == 4
    assert cache.stats.compiles == cache.stats.misses == 3
    # re-insert recompiles exactly once, then hits again
    before = compile_mod.STAGINGS
    cache.execute(build(), s_tpch, defaults)
    cache.execute(build(), s_tpch, defaults)
    assert cache.stats.compiles == 4
    assert compile_mod.STAGINGS - before == 1


def test_db_identity_uses_fingerprint_not_id(db):
    """Regression: keying on id(db) can alias a *new* database onto a
    dead one's cache entries once the allocator reuses the address.  The
    monotonic fingerprint never repeats within a process."""
    import gc

    from repro.relational import Database
    from repro.relational.queries import QUERIES

    d1 = Database({})
    f1 = d1.fingerprint
    k1 = PlanCache(d1).key_for(QUERIES["q6"](), preset("opt"))
    del d1
    gc.collect()
    seen = set()
    for _ in range(20):
        d = Database({})      # may well land on d1's freed address
        assert d.fingerprint != f1
        seen.add(d.fingerprint)
        assert PlanCache(d).key_for(QUERIES["q6"](), preset("opt")) != k1
        del d
        gc.collect()
    assert len(seen) == 20, "fingerprints must be unique across databases"
    key = PlanCache(db).key_for(QUERIES["q6"](), preset("opt"))
    assert key[2] == db.fingerprint


def test_reload_invalidates_capacity_memo_and_entries():
    """Regression: the capacity-signature memo is keyed by (plan shape,
    settings, db.fingerprint).  A `Database.reload` changes `Table.stats`
    under the same object — the fingerprint bump must invalidate both the
    memoized capacity vectors and the compiled entries, or a re-planted
    capacity computed against dead statistics gets served to new data."""
    from repro.relational import Database

    db = Database.tpch(sf=0.01, seed=0)
    cache = PlanCache(db)
    plan = QUERIES["q3"]
    k1 = cache.key_for(plan(), preset("opt"))
    caps1 = k1[-1]
    assert caps1, "q3 must plant compaction points"
    cache.execute(plan(), preset("opt"))
    assert cache.stats.compiles == 1

    small = Database.tpch(sf=0.002, seed=1)
    old_fp = db.fingerprint
    db.reload(small.tables)
    assert db.fingerprint != old_fp
    k2 = cache.key_for(plan(), preset("opt"))
    assert k2 != k1
    # the capacity vector was recomputed from the NEW table stats, not
    # reused from the stale memo (an 5x-smaller lineitem cannot plan the
    # same buckets — at worst the points vanish below compact_min_rows)
    assert k2[-1] != caps1
    # and the stale compiled entry is unreachable: fresh compile
    cache.execute(plan(), preset("opt"))
    assert cache.stats.compiles == 2


# ---------------------------------------------------------------------------
# query server
# ---------------------------------------------------------------------------

def test_server_interleaved_concurrent_requests(db):
    build, defaults = PARAM_QUERIES["q6"]
    b3, d3 = PARAM_QUERIES["q3"]
    oracle = VolcanoEngine(db)
    reqs = [
        (build(), defaults),
        (b3(), d3),
        (build(), dict(defaults, **ALT_BINDINGS["q6"])),
        (b3(), dict(d3, cutoff=days("1995-06-15"))),
        (build(), defaults),
    ]
    with QueryServer(db, preset("opt"), max_workers=4) as srv:
        results = srv.serve_batch([(p, dict(b)) for p, b in reqs])
        stats = srv.stats
        cache_stats = srv.cache.stats
    assert stats.completed == len(reqs) and stats.errors == 0
    assert cache_stats.compiles == 2      # one per distinct plan shape
    for (plan, bindings), got in zip(reqs, results):
        assert_matches(got, oracle.execute(plan, bindings))


def test_server_shares_one_inflight_compilation(db):
    """Two concurrent requests for the same plan shape: the second parks on
    the first's in-flight compilation; exactly one staging happens."""
    build, defaults = PARAM_QUERIES["q6"]
    gate, started = threading.Event(), threading.Event()

    def hook(_key):
        started.set()
        assert gate.wait(timeout=60)

    before = compile_mod.STAGINGS
    with QueryServer(db, preset("opt"), compile_hook=hook,
                     max_workers=4) as srv:
        f1 = srv.submit(build(), dict(defaults))
        assert started.wait(timeout=60)   # first request is now compiling
        f2 = srv.submit(build(), dict(defaults, **ALT_BINDINGS["q6"]))
        while srv.stats.shared_compiles == 0 and not f2.done():
            threading.Event().wait(0.01)  # let f2 reach the in-flight check
        gate.set()
        r1, r2 = f1.result(120), f2.result(120)
        assert srv.stats.shared_compiles == 1
        assert srv.cache.stats.compiles == 1
    assert compile_mod.STAGINGS - before == 1
    oracle = VolcanoEngine(db)
    assert_matches(r1, oracle.execute(build(), defaults))
    assert_matches(r2, oracle.execute(build(),
                                      dict(defaults, **ALT_BINDINGS["q6"])))


def test_close_under_load_resolves_every_future(db):
    """Satellite bugfix: close() racing open windows.  Submitters hammer
    the server while it closes mid-traffic; every future that `submit`
    returned must resolve (result or error) — a window popped by the
    flusher around the close, or one stranded undispatched, must be
    flushed or failed, never silently dropped."""
    build, defaults = PARAM_QUERIES["q6"]
    alt = dict(defaults, **ALT_BINDINGS["q6"])
    futs, futs_lock = [], threading.Lock()
    stop = threading.Event()

    srv = QueryServer(db, preset("opt"), max_workers=2,
                      window_s=0.002, max_batch=4)

    def hammer(i):
        b = defaults if i % 2 else alt
        while not stop.is_set():
            try:
                f = srv.submit(build(), dict(b))
            except RuntimeError:
                return            # server closed: expected once racing
            with futs_lock:
                futs.append(f)

    threads = [threading.Thread(target=hammer, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    # let traffic build up, then close mid-flight
    threading.Event().wait(0.05)
    srv.close()
    stop.set()
    for t in threads:
        t.join(timeout=30)
        assert not t.is_alive()
    with futs_lock:
        taken = list(futs)
    assert taken, "no requests made it in before close"
    for f in taken:
        assert f.done(), "close() left a submitted future pending"
    resolved = sum(1 for f in taken
                   if f.exception(timeout=0) is None)
    # at least the pre-close traffic must have real results; the rest
    # must carry an error, not hang
    assert resolved > 0
