"""The opt-pallas rung end-to-end: single-pass compaction swap-in, the
fused filter→compact pipeline, in-kernel selective aggregation, and the
translated (CSR key→slot) pk_gather build — all against the Volcano
oracle / the plain `opt` rung, with kernel-call counters proving the
kernel paths actually executed."""
import copy

import numpy as np
import pytest

from repro.core import CompiledQuery, PlanCache, VolcanoEngine, ir, preset
from repro.core.expr import Cmp, col, lit
from repro.core.ir import Agg, AggSpec, Compact, Join, Scan, Select
from repro.relational.queries import QUERIES
from test_queries import SORT_INSENSITIVE, assert_same


@pytest.fixture
def kernel_calls(monkeypatch):
    """Count invocations of each kernel entry point (the operator layer
    calls through `repro.kernels.ops`, so wrapping there sees them all)."""
    import repro.kernels.ops as kops

    calls = {"compact": 0, "compact_pred": 0, "selective_agg": 0,
             "filter_agg": 0}

    def wrap(name, fn):
        def g(*a, **k):
            calls[name] += 1
            return fn(*a, **k)
        return g

    monkeypatch.setattr(kops, "compact_query",
                        wrap("compact", kops.compact_query))
    monkeypatch.setattr(kops, "compact_pred_query",
                        wrap("compact_pred", kops.compact_pred_query))
    monkeypatch.setattr(kops, "selective_agg_query",
                        wrap("selective_agg", kops.selective_agg_query))
    monkeypatch.setattr(kops, "filter_agg_query",
                        wrap("filter_agg", kops.filter_agg_query))
    return calls


# which kernel entry point each representative query must exercise:
#   q3  — plain single-pass compact (mask from a join survives upstream)
#   q6  — the whole selective pipeline (pred + scalar agg, no compact)
#   q12 — fused pred + compact (Select absorbed into the compaction kernel)
#   q17 — fused pred + TRANSLATED compact on a pk_gather build side
_EXPECT = {"q3": "compact", "q6": "selective_agg", "q12": "compact_pred",
           "q17": "compact_pred"}


@pytest.mark.parametrize("qname", sorted(_EXPECT))
def test_pallas_rung_matches_oracle(db, qname, kernel_calls):
    plan = QUERIES[qname]()
    want = VolcanoEngine(db).execute(copy.deepcopy(plan))
    cq = CompiledQuery(copy.deepcopy(plan), db, preset("opt-pallas"))
    got = cq.run()
    assert_same(got, want, qname in SORT_INSENSITIVE)
    assert kernel_calls[_EXPECT[qname]] > 0, \
        f"{qname} never hit the {_EXPECT[qname]} kernel path"
    assert cq.n_overflows == 0


def test_q17_plants_translated_build_compact(db):
    """The Compaction pass compacts q17's selective pk_gather build under
    use_pallas (translate point), which the positional-alignment verifier
    must accept — and must keep refusing without the translation."""
    cq = CompiledQuery(QUERIES["q17"](), db, preset("opt-pallas"))
    tr = [n for n in ir.walk(cq.plan)
          if isinstance(n, ir.Compact) and n.translate and n.capacity > 0]
    assert tr, "no translate point planted on q17's build side"
    # without the kernel path the same site must NOT be planted: pk_gather
    # stays positional and the build frame stays intact
    cq_opt = CompiledQuery(QUERIES["q17"](), db, preset("opt"))
    assert not any(n.translate for n in ir.walk(cq_opt.plan)
                   if isinstance(n, ir.Compact))


def _translated_build_plan(cap: int) -> ir.Plan:
    """A hand-lowered pk_gather whose build side is a hand-planted
    translate-Compact: stream lineitem, build the sub-64-row slice of
    part, carry one build column through the join into a scalar agg."""
    build = Compact(
        Select(Scan("part"), Cmp("<", col("p_size"), lit(10.0))),
        cap, translate=True)
    j = Join(Scan("lineitem"), build, "l_partkey", "p_partkey",
             strategy="pk_gather", build_table="part")
    return Agg(j, [], [AggSpec("s", "sum", col("p_size")),
                       AggSpec("c", "count")])


def _uncompacted_twin(plan: ir.Plan) -> ir.Plan:
    from repro.core.passes.compaction import strip_compaction

    return strip_compaction(copy.deepcopy(plan))


@pytest.mark.parametrize("pname", ["opt", "opt-pallas"])
def test_translated_pk_gather_matches_uncompacted(db, pname):
    """The CSR slot_of probe (Pallas kernel under opt-pallas, the XLA
    cumsum fallback under opt) gives bit-identical results to the
    positional join over the uncompacted build."""
    # part@sf0.01 has 2000 rows, ~360 pass the filter: 1024 really
    # compacts (cap < nrows) without overflowing (cap > valid rows)
    plan = _translated_build_plan(1024)
    want = CompiledQuery(_uncompacted_twin(plan), db, preset("opt")).run()
    cq = CompiledQuery(plan, db, preset(pname))
    got = cq.run()
    assert cq.n_overflows == 0
    tr = [n for n in ir.walk(cq.plan)
          if isinstance(n, ir.Compact) and n.translate and n.capacity > 0]
    assert tr, "hand-planted translate point was optimized away"
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-5, atol=1e-3,
                                   err_msg=k)


def test_translated_build_overflow_falls_back(db):
    """An undershot translate capacity drops probe targets (slots past the
    bucket) — the overflow flag must fire and the uncompacted twin must
    deliver the correct result anyway."""
    plan = _translated_build_plan(64)     # far below the valid build rows
    want = CompiledQuery(_uncompacted_twin(plan), db, preset("opt")).run()
    cq = CompiledQuery(plan, db, preset("opt-pallas"))
    got = cq.run()
    assert cq.n_overflows == 1
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-5, atol=1e-3,
                                   err_msg=k)


def test_fused_interception_bails_on_unsafe_predicate(db):
    """A Compact over a Select whose predicate needs 2-D string blocks
    (not kernel-representable) must fall back to ordinary evaluation —
    same results, no crash."""
    from repro.core.expr import StrContainsWord

    plan = Agg(
        Compact(Select(Scan("part"), StrContainsWord("p_name", "green")),
                1024),
        [], [AggSpec("c", "count")])
    want = CompiledQuery(copy.deepcopy(plan), db, preset("opt")).run()
    got = CompiledQuery(copy.deepcopy(plan), db, preset("opt-pallas")).run()
    np.testing.assert_array_equal(got["c"], want["c"])


def test_pallas_rung_run_many(db):
    """Batched (vmapped) execution through the kernel paths: per-slot
    results equal scalar runs."""
    from repro.relational.queries import PARAM_QUERIES

    build, defaults = PARAM_QUERIES["q6"]
    cache = PlanCache(db)
    cq, runtime = cache.get(build(), preset("opt-pallas"), defaults)
    b2 = dict(runtime, qty_max=float(runtime["qty_max"]) + 1.0)
    results = cq.run_many([runtime, b2])
    for got, b in zip(results, [runtime, b2]):
        want = cq.run(b)
        for k in got:
            np.testing.assert_allclose(got[k], want[k], rtol=1e-6,
                                       err_msg=k)
