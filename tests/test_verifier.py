"""Inter-pass verifier: clean on every real workload, and mutation tests
proving a deliberately broken plan/pass is caught with correct pass
attribution (the ISSUE acceptance criteria)."""
import dataclasses

import pytest

from repro.core import ir, preset
from repro.core.analysis import PlanInvariantError, check_plan, verify_plan
from repro.core.expr import Cmp, Param, col, lit
from repro.core.passes import pipeline as pipeline_mod
from repro.core.passes.pipeline import LADDER, optimize
from repro.relational.queries import PARAM_QUERIES, QUERIES


# ---------------------------------------------------------------------------
# zero violations on everything that exists
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("config", LADDER + ["opt-pallas"])
def test_all_queries_verify_clean(db, config):
    s = preset(config)
    assert s.verify_passes        # default-on everywhere
    for fn in QUERIES.values():
        optimize(fn(), db, s)     # raises PlanInvariantError on violation


def test_param_queries_verify_clean(db):
    for fn, params in PARAM_QUERIES.values():
        optimize(fn(), db, preset("opt"), bindings=dict(params),
                 est_params=dict(params))
        optimize(fn(), db, preset("opt"), est_params=dict(params))


def test_final_plans_check_clean(db):
    for fn in QUERIES.values():
        plan = optimize(fn(), db, preset("opt"))
        assert check_plan(plan, db, preset("opt")) == []


# ---------------------------------------------------------------------------
# mutation tests: broken plans / broken passes are caught and attributed
# ---------------------------------------------------------------------------

def test_broken_input_attributed_to_input(db):
    plan = ir.Limit(ir.Scan("orders"), 5)     # Limit needs a Sort below
    with pytest.raises(PlanInvariantError) as ei:
        optimize(plan, db, preset("opt"))
    assert ei.value.rule == "limit-above-sort"
    assert ei.value.pass_name == "input"


def test_dangling_column_attributed_to_input(db):
    plan = ir.Select(ir.Scan("orders"), Cmp("<", col("nope"), lit(1)))
    with pytest.raises(PlanInvariantError) as ei:
        optimize(plan, db, preset("opt"))
    assert ei.value.rule == "column-resolution"
    assert ei.value.pass_name == "input"


class _BreakRename:
    """Mutation pass: drops a Project rename's source (the ISSUE's example
    miscompile — downstream consumers reference a column nobody makes)."""
    name = "BreakRename"

    def run(self, plan, db, settings):
        for node in ir.walk(plan):
            if isinstance(node, ir.Project):
                name = next(iter(node.outputs))
                node.outputs[name] = col("__missing__")
                break
        return plan


def test_broken_pass_attributed_by_name(db, monkeypatch):
    real = pipeline_mod.build_pipeline

    def sabotaged(settings, bindings=None, est_params=None, observed=None):
        passes = real(settings, bindings, est_params, observed)
        passes.insert(3, _BreakRename())
        return passes

    monkeypatch.setattr(pipeline_mod, "build_pipeline", sabotaged)
    # q7 renames nation columns through Projects; the breaker hits one
    with pytest.raises(PlanInvariantError) as ei:
        pipeline_mod.optimize(QUERIES["q7"](), db, preset("opt"))
    assert ei.value.pass_name == "BreakRename"
    assert ei.value.rule == "schema"
    assert "__missing__" in str(ei.value)


def test_compact_under_positional_build_is_caught(db):
    plan = optimize(QUERIES["q3"](), db, preset("opt"))
    joins = [n for n in ir.walk(plan)
             if isinstance(n, ir.Join) and n.strategy == "pk_gather"]
    assert joins, "q3@opt must contain a pk_gather join"
    j = joins[0]
    j.build = ir.Compact(j.build, 1024)   # re-packs rows: key != row id
    bad = [v for v in check_plan(plan, db, preset("opt"))
           if v.rule == "positional-build-alignment"]
    assert bad and "aligned" in bad[0].message


def test_dense_agg_without_domains_is_caught(db):
    plan = optimize(QUERIES["q1"](), db, preset("opt"))
    aggs = [n for n in ir.walk(plan)
            if isinstance(n, ir.Agg) and n.strategy == "dense"]
    assert aggs, "q1@opt must lower to a dense agg"
    aggs[0].domains = None
    bad = [v for v in check_plan(plan, db, preset("opt"))
           if v.rule == "dense-agg-domain"]
    assert bad


def test_dense_agg_undersized_domain_is_caught(db):
    plan = optimize(QUERIES["q1"](), db, preset("opt"))
    agg = next(n for n in ir.walk(plan)
               if isinstance(n, ir.Agg) and n.strategy == "dense")
    agg.domains = [1] * len(agg.domains)  # below the static key bounds
    bad = [v for v in check_plan(plan, db, preset("opt"))
           if v.rule == "dense-agg-domain"]
    assert bad and "scatter" in bad[0].message


def test_key_pack_overflow_is_caught(db):
    st_ps = db.table("partsupp").stats["ps_partkey"]
    st_li = db.table("lineitem").stats["l_partkey"]
    old_ps, old_li = st_ps.max, st_li.max
    try:
        st_ps.max = st_li.max = 2 ** 31
        with pytest.raises(PlanInvariantError) as ei:
            # naive keeps the composite join generic (no bucket_gather)
            optimize(QUERIES["q9full"](), db, preset("naive"))
        assert ei.value.rule == "key-pack"
    finally:
        st_ps.max, st_li.max = old_ps, old_li


def test_string_param_in_scalar_position_is_caught(db):
    plan = ir.Select(ir.Scan("orders"),
                     Cmp("<", col("o_totalprice"), Param("p", "str")))
    bad = [v for v in check_plan(plan, db) if v.rule == "param-dtypes"]
    assert bad


def test_param_dtype_conflict_is_caught(db):
    from repro.core.expr import And
    plan = ir.Select(ir.Scan("orders"),
                     And(Cmp("<", col("o_totalprice"), Param("p", "float32")),
                         Cmp("<", col("o_shippriority"), Param("p", "int32"))))
    bad = [v for v in check_plan(plan, db) if v.rule == "param-dtypes"]
    assert bad


def test_date_slice_on_non_date_column_is_caught(db):
    plan = ir.Scan("orders",
                   date_slice=ir.DateSlice("o_totalprice", 0, 10))
    bad = [v for v in check_plan(plan, db) if v.rule == "date-slice"]
    assert bad and "non-DATE" in bad[0].message


def test_join_key_dtype_mismatch_is_caught(db):
    plan = ir.Join(ir.Scan("lineitem"), ir.Scan("orders"),
                   "l_quantity", "o_orderkey")   # float vs int
    bad = [v for v in check_plan(plan, db) if v.rule == "join-keys"]
    assert bad and "mismatch" in bad[0].message


def test_verify_plan_names_pass_and_rule_in_message(db):
    plan = ir.Limit(ir.Scan("orders"), 5)
    with pytest.raises(PlanInvariantError) as ei:
        verify_plan(plan, db, preset("opt"), pass_name="SomePass")
    msg = str(ei.value)
    assert "SomePass" in msg and "limit-above-sort" in msg
    assert "Scan(orders" in msg          # plan_repr excerpt included


def test_verify_passes_off_skips_checking(db):
    s = dataclasses.replace(preset("opt"), verify_passes=False)
    plan = ir.Limit(ir.Scan("orders"), 5)
    optimize(plan, db, s)                # ill-formed, but not checked
