"""Row-layout (AoS, paper §3.3) correctness.

The row layout stages per-dtype-group record matrices behind an
optimization barrier (`operators/scan.py`).  Two properties:

  * oracle equivalence — every query produces the same result under
    `layout="row"` as the interpreted Volcano oracle (the layout is a
    physical-representation experiment, never a semantics change);
  * integer exactness — INT/DATE columns must round-trip the record
    matrix exactly.  A single float32 matrix cannot represent integers
    above 2^24 (24-bit significand), so keys silently snap to even
    values; the dtype-group split is the regression under test here.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import CompiledQuery, VolcanoEngine, preset
from repro.core.expr import Cmp, col, lit
from repro.core.ir import Agg, AggSpec, Scan, Select, Sort
from repro.relational.loader import Database
from repro.relational.queries import QUERIES
from repro.relational.schema import ColKind, ColumnDef, TableSchema
from repro.relational.table import Table
from tests.test_queries import SORT_INSENSITIVE, assert_same

# endpoints of the config ladder, as in test_queries.py: naive exercises
# the char-matrix string path under AoS, opt the fully optimized one
ROW_CONFIGS = ["naive", "opt"]
FAST_QUERIES = ["q1", "q3", "q4", "q6", "q12", "q14", "q19"]
QUERY_PARAMS = [
    pytest.param(q) if q in FAST_QUERIES
    else pytest.param(q, marks=pytest.mark.slow)
    for q in sorted(QUERIES)
]


def row_settings(config: str):
    return dataclasses.replace(preset(config), layout="row")


@pytest.fixture(scope="module")
def oracle(db):
    eng = VolcanoEngine(db)
    return {name: eng.execute(fn()) for name, fn in QUERIES.items()}


@pytest.mark.parametrize("config", ROW_CONFIGS)
@pytest.mark.parametrize("qname", QUERY_PARAMS)
def test_row_layout_matches_oracle(db, oracle, qname, config):
    cq = CompiledQuery(QUERIES[qname](), db, row_settings(config))
    assert_same(cq.run(), oracle[qname], qname in SORT_INSENSITIVE)


# -- integer exactness above 2^24 -------------------------------------------

def _wide_key_db() -> Database:
    """One table whose INT key exceeds float32's exact-integer range:
    16777217 = 2^24 + 1 is the first integer float32 cannot represent."""
    schema = TableSchema("t", [ColumnDef("k", ColKind.INT),
                               ColumnDef("d", ColKind.DATE),
                               ColumnDef("v", ColKind.FLOAT)])
    k = np.array([16777215, 16777216, 16777217, 16777219, 7],
                 dtype=np.int32)
    d = np.array([20089, 20090, 20091, 20092, 20093], dtype=np.int32)
    v = np.array([1.5, 2.5, 3.5, 4.5, 5.5], dtype=np.float32)
    t = Table(schema, len(k), {"k": k, "d": d, "v": v})
    t.compute_stats()
    return Database({"t": t})


def _probe_plan():
    sel = Select(Scan("t"), Cmp("==", col("k"), lit(16777217)))
    agg = Agg(sel, [], [AggSpec("hits", "count"),
                        AggSpec("vsum", "sum", col("v"))])
    return agg


@pytest.mark.parametrize("config", ROW_CONFIGS)
def test_row_layout_int_exact_above_2p24(config):
    db = _wide_key_db()
    res = CompiledQuery(_probe_plan(), db, row_settings(config)).run()
    # under a float32 record matrix 16777217 snaps to 16777216 and the
    # equality probe matches zero rows (or, worse, the neighbor key)
    assert int(res["hits"][0]) == 1
    np.testing.assert_allclose(float(res["vsum"][0]), 3.5, rtol=1e-6)


@pytest.mark.parametrize("config", ROW_CONFIGS)
def test_row_layout_roundtrips_wide_ints(config):
    db = _wide_key_db()
    plan = Sort(Select(Scan("t"), Cmp(">", col("k"), lit(0))),
                [("k", True)])
    res = CompiledQuery(plan, db, row_settings(config)).run()
    np.testing.assert_array_equal(
        np.sort(res["k"]), np.array([7, 16777215, 16777216, 16777217,
                                     16777219], dtype=np.int32))
    oracle = VolcanoEngine(db).execute(Sort(
        Select(Scan("t"), Cmp(">", col("k"), lit(0))), [("k", True)]))
    assert_same(res, oracle, False)
