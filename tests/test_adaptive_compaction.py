"""Adaptive capacity feedback: observed per-point counts drive re-planning
(overflow -> re-plan with measured headroom, sustained underuse -> shrink),
sketch-based initial estimates let parameterized plans compact, and batch
padding is masked out of overflow accounting."""
import dataclasses

import numpy as np
import pytest

from repro.core import CompiledQuery, PlanCache, VolcanoEngine, preset
from repro.core import compile as compile_mod
from repro.core.expr import Cmp, col, lit
from repro.core.ir import Agg, AggSpec, Compact, Scan, Select
from repro.core.passes.compaction import observed_bucket
from repro.relational.queries import PARAM_QUERIES
from repro.relational.schema import days
from test_queries import assert_same

# q3_param bindings: SELECTIVE leaves few lineitem rows past the shipdate
# cutoff (small planted capacities), WIDE leaves many (guaranteed overflow
# of capacities planned for SELECTIVE).
SELECTIVE = {"cutoff": days("1998-06-01"), "segment": "BUILDING", "topn": 10}
WIDE = {"cutoff": days("1995-03-15"), "segment": "BUILDING", "topn": 10}


def _settings(replan_after=2, shrink_after=3):
    return dataclasses.replace(preset("opt"),
                               compact_replan_after=replan_after,
                               compact_shrink_after=shrink_after)


# ---------------------------------------------------------------------------
# sketch-based initial estimates
# ---------------------------------------------------------------------------

def test_quantile_sketch_cdf(db):
    t = db.table("lineitem")
    q = t.quantile_sketch("l_quantity")
    assert np.all(np.diff(q) >= 0)
    arr = t.col("l_quantity")
    for v in (1.0, 24.0, 50.0):
        true = float(np.count_nonzero(arr <= v)) / arr.size
        assert abs(t.cdf("l_quantity", v) - true) < 0.02
    assert t.cdf("l_quantity", -1e9) == 0.0
    assert t.cdf("l_quantity", 1e9) == 1.0


def test_pair_sketch_measures_col_vs_col(db):
    t = db.table("lineitem")
    frac = t.pair_frac("l_commitdate", "<", "l_receiptdate")
    x, y = t.col("l_commitdate"), t.col("l_receiptdate")
    assert frac == float(np.count_nonzero(x < y)) / t.nrows
    assert 0.0 < frac < 1.0
    # cached: second call returns the same object path
    assert t.pair_frac("l_commitdate", "<", "l_receiptdate") == frac


@pytest.mark.parametrize("qname", ["q3", "q12"])
def test_param_plans_now_compact(db, qname):
    """The whole point of the initial estimates: Param-bounded predicates
    used to be estimated at selectivity 1.0, so parameterized plans never
    compacted.  With the quantile/pair sketches fed by the first-seen
    bindings, the q3/q12 classes plant points immediately."""
    build, defaults = PARAM_QUERIES[qname]
    cache = PlanCache(db)
    cq, _ = cache.get(build(), preset("opt"), defaults)
    assert cq.compaction_points > 0, f"{qname}_param planted no points"
    n_li = db.table("lineitem").nrows
    for cap in cq.capacities:
        assert cap & (cap - 1) == 0 and cap < n_li
    # and the planted capacities hold the default binding: no overflow
    cache.execute(build(), preset("opt"), defaults)
    assert cq.n_overflows == 0


# ---------------------------------------------------------------------------
# the feedback loop: overflow -> re-plan, underuse -> shrink
# ---------------------------------------------------------------------------

def test_overflow_feedback_replans_to_measured_capacity(db):
    """Forced-undershoot estimate (plan compiled for a selective binding)
    -> k overflows under a wide binding -> re-plan from observed counts ->
    subsequent wide bindings run compacted with zero overflows."""
    build, _ = PARAM_QUERIES["q3"]
    s = _settings(replan_after=2)
    cache = PlanCache(db)
    oracle = VolcanoEngine(db)

    first = cache.execute(build(), s, SELECTIVE)
    assert_same(first, oracle.execute(build(), SELECTIVE),
                sort_insensitive=True)
    cq0, _ = cache.get(build(), s, SELECTIVE)
    caps0 = cq0.capacities
    assert cq0.compaction_points > 0

    # k wide bindings: every one overflows the selective-planned buckets
    # (results stay correct via the uncompacted twin)
    for _ in range(2):
        got = cache.execute(build(), s, WIDE)
        assert_same(got, oracle.execute(build(), WIDE),
                    sort_insensitive=True)
    assert cq0.n_overflows == 2
    assert cache.stats.replans == 1
    assert cache.stats.shrinks == 0

    # the re-planned entry: fresh compile, measured capacities, and the
    # wide binding now runs compacted with zero overflows
    before = compile_mod.STAGINGS
    got = cache.execute(build(), s, WIDE)
    assert_same(got, oracle.execute(build(), WIDE), sort_insensitive=True)
    cq1, _ = cache.get(build(), s, WIDE)
    assert cq1 is not cq0
    assert cq1.n_overflows == 0
    assert cq1.capacities != caps0
    # capacities come from the observed max counts: each re-planned point
    # is the pow2 bucket just above what was measured
    for pid, cap in cq1.point_caps.items():
        if pid in cq0.observed_max:
            assert cap == observed_bucket(cq0.observed_max[pid])
    # one retrace per direction: the transition compiled exactly once
    # (compile + its overflow-twin are both counted by STAGINGS)
    assert compile_mod.STAGINGS - before <= 2
    cache.execute(build(), s, WIDE)
    assert cq1.n_overflows == 0 and cache.stats.replans == 1


def test_underuse_feedback_shrinks_capacity(db):
    """Oversized capacity (plan compiled for a wide binding) -> k
    consecutive large underuses under a selective binding -> shrink to the
    measured bucket; results checked against the oracle throughout."""
    build, _ = PARAM_QUERIES["q3"]
    s = _settings(shrink_after=3)
    cache = PlanCache(db)
    oracle = VolcanoEngine(db)

    cache.execute(build(), s, WIDE)
    cq0, _ = cache.get(build(), s, WIDE)
    caps0 = cq0.capacities
    assert cq0.compaction_points > 0

    for _ in range(3):
        got = cache.execute(build(), s, SELECTIVE)
        assert_same(got, oracle.execute(build(), SELECTIVE),
                    sort_insensitive=True)
    assert cache.stats.shrinks == 1
    assert cache.stats.replans == 0

    got = cache.execute(build(), s, SELECTIVE)
    assert_same(got, oracle.execute(build(), SELECTIVE),
                sort_insensitive=True)
    cq1, _ = cache.get(build(), s, SELECTIVE)
    assert cq1 is not cq0
    assert sum(cq1.capacities) < sum(caps0)
    assert cq1.n_overflows == 0


def test_feedback_loop_batched(db):
    """The same convergence through execute_many: wide batches overflow
    per-slot, trigger the re-plan, and the converged entry serves batches
    compacted with zero overflows."""
    build, _ = PARAM_QUERIES["q3"]
    s = _settings(replan_after=2)
    cache = PlanCache(db)
    oracle = VolcanoEngine(db)

    cache.execute(build(), s, SELECTIVE)
    cq0, _ = cache.get(build(), s, SELECTIVE)

    wides = [dict(WIDE), dict(WIDE, cutoff=days("1995-04-15"))]
    got = cache.execute_many(build(), s, wides)
    for g, b in zip(got, wides):
        assert_same(g, oracle.execute(build(), b), sort_insensitive=True)
    assert cq0.n_overflows == 2
    assert cache.stats.replans == 1

    got = cache.execute_many(build(), s, wides)
    for g, b in zip(got, wides):
        assert_same(g, oracle.execute(build(), b), sort_insensitive=True)
    cq1, _ = cache.get(build(), s, WIDE)
    assert cq1 is not cq0 and cq1.n_overflows == 0


def test_shrink_decay_survives_a_later_replan(db):
    """A shrink decays the recorded maxima to the streak window; a later
    modest overflow must re-plan to the *measured* demand, not resurrect
    the pre-shrink spike-era capacities (docs §6: a historical spike
    cannot pin capacity up)."""
    build, _ = PARAM_QUERIES["q3"]
    tiny = dict(WIDE, cutoff=days("1998-11-01"))    # deep underuse
    medium = dict(WIDE, cutoff=days("1998-06-01"))  # modest overflow
    s = _settings(replan_after=1, shrink_after=2)
    cache = PlanCache(db)
    oracle = VolcanoEngine(db)

    cache.execute(build(), s, WIDE)
    cq_wide, _ = cache.get(build(), s, WIDE)
    wide_caps = dict(cq_wide.point_caps)

    for _ in range(3):
        cache.execute(build(), s, tiny)
    assert cache.stats.shrinks >= 1

    # modest overflow of the shrunk buckets -> re-plan
    got = cache.execute(build(), s, medium)
    assert_same(got, oracle.execute(build(), medium), sort_insensitive=True)
    assert cache.stats.replans == 1
    got = cache.execute(build(), s, medium)
    assert_same(got, oracle.execute(build(), medium), sort_insensitive=True)
    cq_new, _ = cache.get(build(), s, medium)
    assert cq_new.n_overflows == 0
    # re-planned shared points sit at measured headroom, strictly below
    # the estimate-era wide capacities — the spike did not come back
    shared = set(cq_new.point_caps) & set(wide_caps)
    assert shared
    for pid in shared:
        assert cq_new.point_caps[pid] < wide_caps[pid]


def test_feedback_off_never_replans(db):
    build, _ = PARAM_QUERIES["q3"]
    s = dataclasses.replace(_settings(replan_after=1),
                            compact_feedback=False)
    cache = PlanCache(db)
    cache.execute(build(), s, SELECTIVE)
    cq0, _ = cache.get(build(), s, SELECTIVE)
    for _ in range(3):
        cache.execute(build(), s, WIDE)
    assert cq0.n_overflows == 3
    assert cache.stats.replans == 0 and cache.stats.shrinks == 0
    cq1, _ = cache.get(build(), s, WIDE)
    assert cq1 is cq0


# ---------------------------------------------------------------------------
# batch padding is masked out of overflow accounting (satellite bugfix)
# ---------------------------------------------------------------------------

def test_padding_slots_do_not_count_as_overflows(db):
    """3 bindings pad to a 4-bucket by repeating the last one; with the
    last binding overflowing a hand-planted 64-row point, exactly the
    real slots (here: one) may count — the pad slot echoes the overflow
    but nobody asked for its rows, so it must trigger neither accounting
    nor a fallback re-run."""
    build, defaults = PARAM_QUERIES["q6"]
    plan = build()
    assert isinstance(plan.child, Select)
    plan = Agg(Compact(plan.child, 64), [], plan.aggs)
    cq = CompiledQuery(plan, db, preset("opt"), params=defaults)
    tiny = dict(defaults, qty_max=1.0)      # l_quantity < 1: zero rows
    bindings = [tiny, tiny, defaults]       # only the LAST slot overflows
    results = cq.run_many(bindings)
    assert cq.n_overflows == 1, \
        "pad slot (a repeat of the overflowing last binding) was counted"
    for got, b in zip(results, bindings):
        want = cq.run(b)
        for k in got:
            np.testing.assert_array_equal(got[k], want[k], err_msg=k)


def test_observed_counts_are_true_counts(db):
    """The staged count is the cumsum total over the full mask — exact
    even when it exceeds capacity (that magnitude is what re-planning
    uses), not clipped at the bucket."""
    sel = Select(Scan("lineitem"), Cmp("<", col("l_quantity"), lit(26.0)))
    plan = Agg(Compact(sel, 64), [],
               [AggSpec("c", "count")])
    cq = CompiledQuery(plan, db, preset("opt"))
    res = cq.run()
    true_rows = int(res["c"][0])
    assert true_rows > 64
    assert cq.observed_max == {"h0": true_rows}


def test_hand_planted_point_replans_from_observed(db):
    """PR-5 residual: hand-planted Compact nodes got their counts observed
    but the pass's pre-existing-point branch never consulted the feedback
    store, so an undershot hand capacity overflowed on every execution
    forever.  The pass now assigns hand points stable h-ids and applies
    the observed override exactly like planted points."""
    def build():
        sel = Select(Scan("lineitem"), Cmp("<", col("l_quantity"), lit(2.0)))
        return Agg(Compact(sel, 64), [], [AggSpec("c", "count")])

    s = _settings(replan_after=1)
    cache = PlanCache(db)
    res = cache.execute(build(), s)
    true_rows = int(res["c"][0])
    assert true_rows > 64                    # the hand capacity undershot
    assert cache.stats.replans == 1          # ... and the overflow re-planned

    cq, _ = cache.get(build(), s)
    assert cq.point_caps["h0"] == observed_bucket(true_rows)
    res2 = cache.execute(build(), s)
    assert int(res2["c"][0]) == true_rows
    assert cq.n_overflows == 0 and cache.stats.replans == 1
