"""Shared fixtures: one TPC-H database for the whole session (generation +
auxiliary-structure builds dominate per-module setup cost otherwise)."""
import pytest

from repro.relational import Database


@pytest.fixture(scope="session")
def db():
    return Database.tpch(sf=0.01, seed=0)
