"""Shared fixtures: one TPC-H database for the whole session (generation +
auxiliary-structure builds dominate per-module setup cost otherwise).

Multi-device simulation: XLA fixes its device list at the first jax
import, so the flag asking the CPU backend for 8 virtual devices must be
in the environment before any test module (or the library under test)
imports jax.  Conftest import runs first under pytest, making this the
one reliable place; the guard keeps `pytest` usable from a REPL where
jax is already loaded (sharded tests then skip via `needs_devices`).
"""
import os
import sys

if "jax" not in sys.modules:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8"
    ).strip()

import pytest

from repro.relational import Database


@pytest.fixture(scope="session")
def db():
    return Database.tpch(sf=0.01, seed=0)
