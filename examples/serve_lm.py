"""Serving driver: continuous-batching engine on a reduced-config model.

    PYTHONPATH=src python examples/serve_lm.py --requests 6 --slots 2
"""
import argparse

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models import Ctx, init_params
from repro.serve.batcher import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1_5_0_5b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    ctx = Ctx(mesh=None)
    eng = ServeEngine(params, cfg, ctx, slots=args.slots, max_len=64)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, 4 + i % 3).astype(np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    for r in reqs:
        print(f"req {r.rid}: prompt={r.prompt.tolist()} -> {r.out}")
    print(f"engine ticks: {eng.ticks} (continuous batching over "
          f"{args.slots} slots)")


if __name__ == "__main__":
    main()
