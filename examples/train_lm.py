"""End-to-end training driver: train a small qwen-family model on the
synthetic pipeline with the fault-tolerant driver + async checkpointing.

    PYTHONPATH=src python examples/train_lm.py --steps 200 [--arch qwen1_5_0_5b]

With --steps 200 on CPU this trains a ~3M-param reduced config and prints
the loss curve (which should fall from ~ln(vocab)).
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.data.pipeline import TokenPipeline
from repro.models import Ctx, init_params
from repro.runtime.fault_tolerance import TrainDriver
from repro.train.optimizer import AdamConfig
from repro.train.train_step import make_train_state, train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1_5_0_5b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--compression", action="store_true",
                    help="int8 gradient compression with error feedback")
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    cfg = dataclasses.replace(cfg, n_layers=max(cfg.n_layers, 2))
    ctx = Ctx(mesh=None)
    params = init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params / 1e6:.2f}M")

    state = make_train_state(params, compression=args.compression)
    pipe = TokenPipeline(vocab=cfg.vocab, batch=args.batch, seq_len=args.seq,
                         structured=True)
    stepper = jax.jit(lambda st, b: train_step(
        st, {k: jnp.asarray(v) for k, v in b.items()}, cfg, ctx,
        AdamConfig(lr=3e-4, warmup=20)))

    drv = TrainDriver(step_fn=stepper, state=state, pipeline=pipe,
                      ckpt_dir=args.ckpt, ckpt_every=50)
    drv.run(args.steps)
    log = drv.metrics_log
    for m in log[:: max(1, len(log) // 10)]:
        print(f"step {m['step']:>5}  loss {m['loss']:.4f}  "
              f"{m['dt'] * 1e3:.0f} ms")
    print(f"final loss {log[-1]['loss']:.4f} "
          f"(init ~{jnp.log(cfg.vocab):.2f}); stragglers: "
          f"{len(drv.straggler.slow_steps)}")


if __name__ == "__main__":
    main()
