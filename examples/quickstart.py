"""Quickstart: build a TPC-H database, run a query through the engine
ladder, and show the abstraction-without-regret effect.

    PYTHONPATH=src python examples/quickstart.py [--sf 0.02]
"""
import argparse
import time

from repro.core import CompiledQuery, VolcanoEngine, preset
from repro.core.ir import plan_repr
from repro.relational import Database
from repro.relational.queries import q6, q12


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sf", type=float, default=0.02)
    args = ap.parse_args()

    print(f"Generating TPC-H (sf={args.sf}) ...")
    db = Database.tpch(sf=args.sf)
    print(f"  lineitem rows: {db.table('lineitem').nrows:,}")

    print("\nQ12 logical plan:")
    print(plan_repr(q12()))

    print("\nInterpreted Volcano engine (the 'DBX' rung):")
    eng = VolcanoEngine(db)
    t0 = time.perf_counter()
    res = eng.execute(q12())
    t_volcano = time.perf_counter() - t0
    print(f"  {dict((k, v[:4]) for k, v in res.items())}")
    print(f"  time: {t_volcano * 1e3:.1f} ms")

    for config in ("naive", "opt"):
        cq = CompiledQuery(q12(), db, preset(config))
        cq.run()                     # warm up / compile
        t0 = time.perf_counter()
        res = cq.run()
        t = time.perf_counter() - t0
        print(f"\nStaged engine [{config}]:")
        print(plan_repr(cq.plan))
        print(f"  time: {t * 1e3:.1f} ms  "
              f"(speedup vs volcano: {t_volcano / t:.1f}x)")

    cq = CompiledQuery(q6(), db, preset("opt"))
    print("\nQ6 [opt] result:", cq.run())


if __name__ == "__main__":
    main()
