"""End-to-end analytics driver (the paper's kind of system): load TPC-H,
stage + compile every query with the full optimization pipeline, execute,
and report per-query timings, memory and compile cost.

    PYTHONPATH=src python examples/tpch_analytics.py [--sf 0.05] [--config opt]
"""
import argparse
import time

from repro.core import CompiledQuery, preset
from repro.relational import Database
from repro.relational.queries import QUERIES


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sf", type=float, default=0.05)
    ap.add_argument("--config", default="opt",
                    choices=["naive", "template", "tpch", "strdict", "opt",
                             "opt-pallas"])
    args = ap.parse_args()

    t0 = time.perf_counter()
    db = Database.tpch(sf=args.sf)
    print(f"load: {time.perf_counter() - t0:.2f}s  "
          f"({db.base_nbytes() / 1e6:.0f} MB)")

    print(f"{'query':<6} {'rows':>6} {'compile_ms':>11} {'exec_ms':>9} "
          f"{'mem_MB':>7}")
    for name, builder in sorted(QUERIES.items()):
        t0 = time.perf_counter()
        cq = CompiledQuery(builder(), db, preset(args.config))
        res = cq.run()                      # includes jit compile
        t_compile = time.perf_counter() - t0
        t0 = time.perf_counter()
        res = cq.run()
        t_exec = time.perf_counter() - t0
        nrows = len(next(iter(res.values())))
        print(f"{name:<6} {nrows:>6} {t_compile * 1e3:>11.1f} "
              f"{t_exec * 1e3:>9.2f} {cq.input_nbytes() / 1e6:>7.1f}")


if __name__ == "__main__":
    main()
