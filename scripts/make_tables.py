"""Regenerate the EXPERIMENTS.md roofline + hillclimb tables from the
results/ JSON caches."""
import glob
import json
import os


def load(pattern):
    out = []
    for p in sorted(glob.glob(pattern)):
        with open(p) as f:
            out.append(json.load(f))
    return out


def _analytic_mem_s(c):
    import sys
    sys.path.insert(0, "src")
    from repro.configs import get_config
    from repro.launch.roofline import HBM_BW, analytic_hbm_bytes
    from repro.models.config import SHAPES
    cfg = get_config(c["arch"])
    return analytic_hbm_bytes(cfg, SHAPES[c["shape"]], c["chips"]) / HBM_BW


def roofline_md(cells):
    rows = ["| arch | shape | mesh | compute s | memory s (HLO) | "
            "mem s (HBM est) | coll s | bottleneck* | frac* | 6ND/HLO | "
            "coll GB/dev |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    for c in sorted(cells, key=lambda c: (c["arch"], c["shape"], c["mesh"])):
        mem_a = _analytic_mem_s(c)
        bound = max(c["compute_s"], mem_a, c["collective_s"])
        bneck = {c["compute_s"]: "compute", mem_a: "memory",
                 c["collective_s"]: "collective"}[bound]
        frac = c["compute_s"] / max(bound, 1e-30)
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {c['compute_s']:.4g} | {c['memory_s']:.4g} "
            f"| {mem_a:.4g} | {c['collective_s']:.4g} "
            f"| {bneck} | {frac:.3f} "
            f"| {min(c['useful_flops_ratio'],99):.2f} "
            f"| {c['collective_bytes_per_dev']/1e9:.1f} |")
    rows.append("")
    rows.append("\\* bottleneck/fraction use the fused-HBM estimate for the "
                "memory term; the spec-mandated HLO-bytes term is also shown "
                "(it counts pre-fusion dataflow and calls every cell "
                "memory-bound — see EXPERIMENTS §Dry-run).")
    return "\n".join(rows)


def _corrected_bound(c):
    return max(c["compute_s"], _analytic_mem_s(c), c["collective_s"])


def hillclimb_md(base_cells):
    base = {(c["arch"], c["shape"]): c for c in base_cells}
    rows = ["| variant | arch/shape | compute s | mem s (HLO) | coll s | "
            "bound s* | frac* | Δbound | Δcoll | Δmem(HLO) |",
            "|---|---|---|---|---|---|---|---|---|---|"]

    def row(tag, c, b):
        bound = _corrected_bound(c)
        frac = c["compute_s"] / max(bound, 1e-30)
        if b is not None:
            b_bound = _corrected_bound(b)
            d_bound = f"{(1 - bound/b_bound)*100:+.1f}%"
            d_coll = f"{(1 - c['collective_s']/max(b['collective_s'],1e-30))*100:+.1f}%"
            d_mem = f"{(1 - c['memory_s']/max(b['memory_s'],1e-30))*100:+.1f}%"
        else:
            d_bound = d_coll = d_mem = "baseline"
        rows.append(f"| {tag} | {c['arch']}/{c['shape']} "
                    f"| {c['compute_s']:.4g} | {c['memory_s']:.4g} "
                    f"| {c['collective_s']:.4g} | {bound:.4g} | {frac:.3f} "
                    f"| {d_bound} | {d_coll} | {d_mem} |")

    seen = set()
    for d in sorted(glob.glob("results/hillclimb/*/*.json")):
        tag = d.split(os.sep)[2]
        c = json.load(open(d))
        key = (c["arch"], c["shape"])
        b = base.get(key)
        if b is not None and key not in seen:
            seen.add(key)
            row("baseline", b, None)
        row(tag, c, b)
    return "\n".join(rows)


if __name__ == "__main__":
    cells = load("results/dryrun/*.json")
    os.makedirs("results", exist_ok=True)
    with open("results/roofline_table.md", "w") as f:
        f.write(roofline_md(cells) + "\n")
    with open("results/hillclimb_table.md", "w") as f:
        f.write(hillclimb_md(cells) + "\n")
    print(f"{len(cells)} baseline cells -> results/roofline_table.md")
    print("hillclimb -> results/hillclimb_table.md")
