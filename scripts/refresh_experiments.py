"""Refresh the generated tables inside EXPERIMENTS.md from results/."""
import subprocess
import sys

subprocess.run([sys.executable, "scripts/make_tables.py"], check=True)
exp = open("EXPERIMENTS.md").read()


def splice(text, begin, end, payload):
    b = text.index(begin) + len(begin)
    e = text.index(end)
    return text[:b] + "\n" + payload.strip() + "\n" + text[e:]


exp = splice(exp, "<!-- ROOFLINE:BEGIN -->", "<!-- ROOFLINE:END -->",
             open("results/roofline_table.md").read())
# hillclimb table sits before the notes: replace only up to the notes marker
begin = "<!-- HILLCLIMB:BEGIN -->"
b = exp.index(begin) + len(begin)
notes_at = exp.index("**Iteration notes", b)
exp = exp[:b] + "\n" + open("results/hillclimb_table.md").read().strip() \
    + "\n\n" + exp[notes_at:]
open("EXPERIMENTS.md", "w").write(exp)
print("EXPERIMENTS.md refreshed")
