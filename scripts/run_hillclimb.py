"""§Perf hillclimb driver: re-runs the three chosen (arch × shape) cells
with one optimization lever flipped per variant, each in a fresh process
(the dry-run entrypoint must own jax initialization).

    python scripts/run_hillclimb.py            # all planned variants
    python scripts/run_hillclimb.py --only deepseek
"""
import argparse
import os
import subprocess
import sys

# (tag, arch, shape, env)   — one lever per step, cumulative per cell
PLAN = [
    # A. deepseek train_4k: worst memory term, most-MoE-representative
    ("A1_moe_group16", "deepseek_v2_236b", "train_4k",
     {"REPRO_MOE_GROUP": "16"}),
    ("A2_plus_cap125", "deepseek_v2_236b", "train_4k",
     {"REPRO_MOE_GROUP": "16", "REPRO_CAPACITY": "1.25"}),
    ("A3_plus_onehot", "deepseek_v2_236b", "train_4k",
     {"REPRO_MOE_GROUP": "16", "REPRO_CAPACITY": "1.25",
      "REPRO_LOSS_MODE": "onehot"}),
    # B. qwen train_4k: worst roofline fraction (tiny model, huge vocab) —
    # collective-dominated by FSDP gathers + vocab-gather in the loss
    ("B1_onehot_loss", "qwen1_5_0_5b", "train_4k",
     {"REPRO_LOSS_MODE": "onehot"}),
    ("B2_plus_nofsdp", "qwen1_5_0_5b", "train_4k",
     {"REPRO_LOSS_MODE": "onehot", "REPRO_NO_FSDP": "1"}),
    ("B3_plus_bf16params", "qwen1_5_0_5b", "train_4k",
     {"REPRO_LOSS_MODE": "onehot", "REPRO_NO_FSDP": "1",
      "REPRO_PARAM_DTYPE": "bfloat16"}),
    # C. phi3 train_4k: largest collective seconds of the dense cells
    ("C1_bf16_params", "phi3_medium_14b", "train_4k",
     {"REPRO_PARAM_DTYPE": "bfloat16"}),
    ("C2_plus_onehot", "phi3_medium_14b", "train_4k",
     {"REPRO_PARAM_DTYPE": "bfloat16", "REPRO_LOSS_MODE": "onehot"}),
    # D. head resharding: the B/C refutations traced the dominant all-reduce
    # to the f32 logits psum over `data` (the head's contraction dim is
    # FSDP-sharded) — reshard the weight, not the activations.
    ("D1_head_reshard_qwen", "qwen1_5_0_5b", "train_4k",
     {"REPRO_HEAD_RESHARD": "1"}),
    ("D2_head_reshard_phi3", "phi3_medium_14b", "train_4k",
     {"REPRO_HEAD_RESHARD": "1"}),
    ("D3_head_reshard_deepseek", "deepseek_v2_236b", "train_4k",
     {"REPRO_HEAD_RESHARD": "1", "REPRO_MOE_GROUP": "16"}),
    # D4: phi3's 674 GB/dev collectives are f32 activation all-gathers from
    # GSPMD resharding churn between blocks — pin the residual sharding.
    ("D4_block_constraint_phi3", "phi3_medium_14b", "train_4k",
     {"REPRO_HEAD_RESHARD": "1", "REPRO_BLOCK_CONSTRAINT": "1"}),
    ("D5_block_constraint_qwen", "qwen1_5_0_5b", "train_4k",
     {"REPRO_HEAD_RESHARD": "1", "REPRO_BLOCK_CONSTRAINT": "1"}),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    os.makedirs("results/hillclimb", exist_ok=True)
    for tag, arch, shape, env in PLAN:
        if args.only and args.only not in tag and args.only not in arch:
            continue
        outdir = f"results/hillclimb/{tag}"
        if os.path.exists(f"{outdir}/{arch}__{shape}__pod.json"):
            print(f"cached {tag}")
            continue
        print(f"=== {tag} ({arch} {shape}) env={env} ===", flush=True)
        e = dict(os.environ, PYTHONPATH="src", **env)
        subprocess.run([sys.executable, "-m", "repro.launch.dryrun",
                        "--arch", arch, "--shape", shape, "--out", outdir],
                       env=e, check=False)


if __name__ == "__main__":
    main()
