"""Roofline table from the dry-run JSON cache (results/dryrun/*.json).

Run `PYTHONPATH=src python -m repro.launch.dryrun --all` first (the dry-run
needs its own process: it forces 512 host devices before jax init).
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import csv

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "results/dryrun")


def load_cells() -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def markdown_table(cells: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "bottleneck | roofline frac | useful/HLO | bytes/dev |")
    sep = "|" + "---|" * 10
    rows = [hdr, sep]
    for c in sorted(cells, key=lambda c: (c["arch"], c["shape"], c["mesh"])):
        mem = c.get("memory", {}) or {}
        arg = (mem.get("argument_bytes") or 0) + (mem.get("temp_bytes") or 0)
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {c['compute_s']:.4f} | {c['memory_s']:.4f} "
            f"| {c['collective_s']:.4f} "
            f"| {c['bottleneck'].replace('_s', '')} "
            f"| {c['roofline_fraction']:.3f} "
            f"| {min(c['useful_flops_ratio'], 99.0):.2f} "
            f"| {arg / 1e9:.1f}GB |")
    return "\n".join(rows)


def run(out=print) -> list[dict]:
    cells = load_cells()
    if not cells:
        out(csv("roofline/no_dryrun_cache", 0.0,
                "run repro.launch.dryrun --all first"))
        return cells
    for c in cells:
        out(csv(f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}",
                c["bound_s"],
                f"bottleneck={c['bottleneck'].replace('_s', '')} "
                f"frac={c['roofline_fraction']:.3f}"))
    out(csv("roofline/cells_total", 0.0, str(len(cells))))
    return cells
