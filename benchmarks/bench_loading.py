"""Fig 21 / Table VII: loading-time overhead of the load-time structures
(string dictionaries incl. word tokenization, FK partitions, date
clusters).  Paper claim: ≤ ~1.5x slowdown (≈1.88x incl. word-token dicts).
"""
from __future__ import annotations

from repro.relational.loader import loading_cost

from benchmarks.common import csv, db


def run(out=print) -> dict:
    d = db()
    d.reset_aux()
    base = loading_cost(d, string_dict=False, partition=False,
                        date_index=False) + 1e-9
    t_dict = loading_cost(d, string_dict=True, partition=False,
                          date_index=False)
    d.reset_aux()
    t_part = loading_cost(d, string_dict=False, partition=True,
                          date_index=False)
    t_date = loading_cost(d, string_dict=False, partition=False,
                          date_index=True)
    results = {"base": base, "string_dict": t_dict, "partition": t_part,
               "date_index": t_date}
    out(csv("loading/string_dict", t_dict))
    out(csv("loading/partition", t_part))
    out(csv("loading/date_index", t_date))
    out(csv("loading/total_aux", t_dict + t_part + t_date))
    return results
