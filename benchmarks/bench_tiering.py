"""Execution tiers end-to-end: instant cold serving, background promotion,
warm-state persistence (docs/architecture.md §11).

Three claims, each measured and gated:

  1. cold serving — request 1 on a stone-cold tiered `PlanCache` is
     answered by the oracle tier at interpreter cost, NOT the multi-second
     staging+XLA compile a blocking cache charges its first caller.  Gate:
     first-request latency <= 10x the bare Volcano execution of the same
     plan (the oracle serve plus cache bookkeeping).
  2. background promotion — while the oracle serves, the promoter
     compiles the target tier and hot-swaps it in; results are
     bit-comparable to the Volcano oracle at EVERY tier (zero drift), and
     steady-state latency after the swap is the compiled tier's.
  3. warm restart — a converged cache (compaction feedback, capacity
     overrides) persisted with `PlanCache.save` and restored into a fresh
     process-stand-in serves request 1 at the pre-restart converged
     capacities: same capacity signature, zero overflows, no
     re-convergence.  The JAX persistent compilation cache is wired so
     the XLA executable itself is also reused across the restart.

Writes `BENCH_tiering.json` (or $REPRO_BENCH_TIERING_OUT).
Scale factor: REPRO_TIERING_SF, default 0.01 (serving-sized).
"""
from __future__ import annotations

import json
import os
import sys
import tempfile
import time

from repro.core import PlanCache, VolcanoEngine, preset
from repro.core import compile as compile_mod
from repro.core.persist import enable_compilation_cache
from repro.relational import Database
from repro.relational.queries import PARAM_QUERIES
from repro.relational.schema import days

from benchmarks.bench_compaction import _drift
from benchmarks.common import REPEATS

SF = float(os.environ.get("REPRO_TIERING_SF", "0.01"))
COLD_QUERIES = ["q1", "q6", "q12"]
COLD_RATIO_GATE = 10.0

# initial selective binding -> steady binding, as in
# bench_adaptive_compaction: drives the feedback loop so the warm-restart
# section has converged capacity overrides worth persisting
WARM_SCHEDULES = {
    "q3": {"cutoff": days("1998-11-01")},
    "q12": {"receipt_lo": days("1994-01-01"),
            "receipt_hi": days("1994-02-01")},
}
STEADY_RUNS = 8


def _min_time(fn, n) -> float:
    times = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return min(times)


def _bench_cold(database, oracle, settings, out) -> dict:
    section = {}
    for qname in COLD_QUERIES:
        build, defaults = PARAM_QUERIES[qname]
        # one-shot oracle cost: the fair baseline for a one-shot first
        # request (min-of-repeats is also recorded, but warm-loop timings
        # flatter the interpreter and would make the 10x gate jittery)
        t0 = time.perf_counter()
        oracle.execute(build(), defaults)
        oracle_s = time.perf_counter() - t0
        oracle_min_s = min(oracle_s, _min_time(
            lambda: oracle.execute(build(), defaults),
            max(2, REPEATS // 2)))

        cache = PlanCache(database, tiered=True)
        try:
            before = compile_mod.STAGINGS
            t0 = time.perf_counter()
            res1, tier1 = cache.execute_tiered(build(), settings, defaults)
            first_s = time.perf_counter() - t0
            stagings_inline = compile_mod.STAGINGS - before
            drift1 = _drift(res1, oracle.execute(build(), defaults))

            # requests until the hot swap lands (the promoter races real
            # traffic here, so this is a measurement, not a constant)
            promoted_after = 1 if tier1 != "oracle" else None
            for i in range(2, 65):
                if promoted_after is not None:
                    break
                _, t = cache.execute_tiered(build(), settings, defaults)
                if t != "oracle":
                    promoted_after = i
            cache.await_promotion(build(), settings, defaults, timeout=600)
            res_hot, tier_hot = cache.execute_tiered(build(), settings,
                                                     defaults)
            drift_hot = _drift(res_hot, oracle.execute(build(), defaults))
            hot_s = _min_time(
                lambda: cache.execute_tiered(build(), settings, defaults),
                max(3, REPEATS))

            # contrast: what request 1 costs when the first caller must
            # block on the full compile (fresh non-tiered cache)
            blocking = PlanCache(database)
            t0 = time.perf_counter()
            blocking.execute(build(), settings, defaults)
            blocking_cold_s = time.perf_counter() - t0

            section[qname] = {
                "oracle_s": oracle_s,
                "oracle_min_s": oracle_min_s,
                "first_request_s": first_s,
                "first_request_tier": tier1,
                "first_vs_oracle": first_s / max(oracle_s, 1e-9),
                "inline_stagings_on_request_1": stagings_inline,
                "blocking_cold_s": blocking_cold_s,
                "cold_speedup_vs_blocking":
                    blocking_cold_s / max(first_s, 1e-9),
                "requests_until_promoted": promoted_after,
                "steady_tier": tier_hot,
                "steady_s": hot_s,
                "promotions": cache.stats.promotions,
                "promote_failures": cache.stats.promote_failures,
                "tier_hits": dict(cache.stats.tier_hits),
                "max_rel_drift_vs_oracle": max(drift1, drift_hot),
            }
            out(f"tiering/{qname}/first_request,{first_s * 1e6:.1f},"
                f"{section[qname]['first_vs_oracle']:.2f}x oracle on "
                f"tier {tier1}")
            out(f"tiering/{qname}/blocking_cold,{blocking_cold_s * 1e6:.1f},"
                f"{section[qname]['cold_speedup_vs_blocking']:.1f}x slower "
                "than tiered request 1")
            out(f"tiering/{qname}/steady,{hot_s * 1e6:.1f},"
                f"tier {tier_hot} after "
                f"{promoted_after} request(s)")
        finally:
            cache.close()
    return section


def _converge(cache, settings, build, initial, steady) -> dict:
    cache.execute(build(), settings, initial)
    for _ in range(STEADY_RUNS):
        cache.execute(build(), settings, steady)
    cq, _ = cache.get(build(), settings, steady)
    return {"capacities": list(cq.capacities),
            "replans": cache.stats.replans,
            "overflows": cache.stats.overflows}


def _bench_warm_restart(database, settings, out, workdir) -> dict:
    xla_cache = os.path.join(workdir, "xla-cache")
    section = {"jax_compilation_cache_enabled":
               enable_compilation_cache(xla_cache)}
    for qname, init_overlay in WARM_SCHEDULES.items():
        build, defaults = PARAM_QUERIES[qname]
        initial = dict(defaults, **init_overlay)
        path = os.path.join(workdir, f"warm-{qname}.json")

        cache = PlanCache(database)
        pre = _converge(cache, settings, build, initial, defaults)
        saved = cache.save(path)

        # "restart": a fresh cache over the same data restores the
        # feedback store; its FIRST compile must plan at the converged
        # capacities and request 1 must not overflow
        restored_cache = PlanCache(database)
        n_restored = restored_cache.load(path)
        t0 = time.perf_counter()
        restored_cache.execute(build(), settings, defaults)
        first_s = time.perf_counter() - t0
        cq, _ = restored_cache.get(build(), settings, defaults)

        # a cold control: same fresh-cache first request WITHOUT the
        # restored state plans at the sketch estimate instead
        control = PlanCache(database)
        control.execute(build(), settings, defaults)
        ctrl_cq, _ = control.get(build(), settings, defaults)

        section[qname] = {
            "records_saved": saved,
            "records_restored": n_restored,
            "warm_hint": restored_cache.is_warm(build(), settings, defaults),
            "pre_restart_capacities": pre["capacities"],
            "pre_restart_replans": pre["replans"],
            "restored_first_request_s": first_s,
            "restored_capacities": list(cq.capacities),
            "capacities_match": list(cq.capacities) == pre["capacities"],
            "restored_first_overflows": cq.n_overflows,
            "cold_control_capacities": list(ctrl_cq.capacities),
        }
        out(f"tiering/restart/{qname},{first_s * 1e6:.1f},"
            f"caps {pre['capacities']} restored="
            f"{section[qname]['capacities_match']} "
            f"overflows={cq.n_overflows}")
    return section


def run(out=print) -> dict:
    database = Database.tpch(sf=SF, seed=0)
    oracle = VolcanoEngine(database)
    settings = preset("opt")
    results: dict = {"sf": SF}
    with tempfile.TemporaryDirectory(prefix="bench-tiering-") as workdir:
        results["cold_serving"] = _bench_cold(database, oracle, settings,
                                              out)
        results["warm_restart"] = _bench_warm_restart(database, settings,
                                                      out, workdir)

    cold = results["cold_serving"].values()
    warm = [v for k, v in results["warm_restart"].items()
            if isinstance(v, dict)]
    results["summary"] = {
        "max_first_vs_oracle": max(c["first_vs_oracle"] for c in cold),
        "cold_ratio_gate": COLD_RATIO_GATE,
        "all_promoted": all(c["steady_tier"] != "oracle" for c in cold),
        "max_drift": max(c["max_rel_drift_vs_oracle"] for c in cold),
        "inline_stagings_on_cold_requests":
            sum(c["inline_stagings_on_request_1"] for c in cold),
        "all_capacities_restored": all(w["capacities_match"] for w in warm),
        "restored_first_overflows":
            sum(w["restored_first_overflows"] for w in warm),
    }
    path = os.environ.get("REPRO_BENCH_TIERING_OUT", "BENCH_tiering.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=2)
    out(f"wrote {path}")
    return results


if __name__ == "__main__":
    res = run()
    s = res["summary"]
    # hard gates, mirroring the issue's acceptance criteria; raw latencies
    # stay advisory (recorded in the JSON) since CI runners vary
    ok = (s["max_first_vs_oracle"] <= s["cold_ratio_gate"]
          and s["all_promoted"]
          and s["max_drift"] < 1e-2
          and s["all_capacities_restored"]
          and s["restored_first_overflows"] == 0)
    sys.exit(0 if ok else 1)
