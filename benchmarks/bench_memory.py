"""Fig 20 / Table VII: memory consumption per query (Opt config).

Reports the bytes of base columns + auxiliary structures (partitions, date
clusters, dictionaries) actually referenced by each compiled query, and
the ratio to total database size — the paper's claim is avg ~1.16x, max
~2x of input size, with pruning pushing some queries well below 1x.
"""
from __future__ import annotations

from repro.core import CompiledQuery, preset
from repro.relational.queries import QUERIES

from benchmarks.common import csv, db


def run(out=print) -> dict:
    d = db()
    total = d.base_nbytes()
    out(csv("memory/database_total", 0.0, f"{total / 1e6:.1f}MB"))
    results = {}
    for qname in sorted(QUERIES):
        cq = CompiledQuery(QUERIES[qname](), d, preset("opt"))
        used = cq.input_nbytes()
        results[qname] = used
        out(csv(f"memory/{qname}", 0.0,
                f"{used / 1e6:.1f}MB ratio={used / total:.2f}"))
    avg = sum(results.values()) / len(results)
    out(csv("memory/avg_ratio", 0.0, f"{avg / total:.2f}"))
    return results
