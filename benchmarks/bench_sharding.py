"""Sharded-execution scaling: the 1→N device curve (beyond paper).

Compiles each TPC-H query at ``Settings(shards=N)`` for N in {1, 2, 4, 8}
and records, per query and mesh size:

  * best wall-clock per execution (same protocol as bench_ladder),
  * per-shard rows scanned (partition-root block + routed-child blocks;
    replicated tables count in full — every shard holds them),
  * per-shard resident input bytes (sharded arrays split N ways,
    replicated arrays counted whole),
  * Exchange-node count of the lowered plan, next to the join count
    (the verifier's `exchange-count` rule bounds the former by the
    non-co-partitioned consumers during optimize()).

The mesh needs 8 visible devices and XLA fixes its device list at the
first jax import, so when this process can't see 8 (the usual case —
`benchmarks/run.py` imported jax long ago) the benchmark re-executes
itself in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

Writes ``BENCH_sharding.json`` (or $REPRO_BENCH_SHARD_OUT).  Scale
factor comes from $REPRO_SF like every other bench; the nightly scaling
run sets REPRO_SF=0.1.
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys

MESHES = (1, 2, 4, 8)
QUICK_KEEP = {"q1", "q3", "q6", "q12"}


def _run_local() -> None:
    import jax

    from benchmarks.common import SF, csv, db, time_compiled
    from repro.core import CompiledQuery, preset
    from repro.core import ir
    from repro.core.passes.pipeline import optimize
    from repro.relational.queries import QUERIES

    d = db()
    n_dev = len(jax.devices())
    names = sorted(QUERIES)
    if os.environ.get("REPRO_QUICK") == "1":
        names = [q for q in names if q in QUICK_KEEP]
    out: dict = {"sf": SF, "devices": n_dev, "queries": {}}
    for qname in names:
        rows = []
        for n in MESHES:
            if n > n_dev:
                continue
            settings = dataclasses.replace(preset("opt"), shards=n)
            lowered = optimize(QUERIES[qname](), d, settings)
            nodes = list(ir.walk(lowered))
            n_ex = sum(isinstance(x, ir.Exchange) for x in nodes)
            n_join = sum(isinstance(x, ir.Join) for x in nodes)
            scanned = {x.table for x in nodes if isinstance(x, ir.Scan)}
            sp = d.shard_plan(n) if n > 1 else None
            shard_rows = sum(
                (sp.rows_per_shard(t)
                 if sp is not None and sp.part_of(t) is not None
                 else d.table(t).nrows)
                for t in scanned)
            cq = CompiledQuery(QUERIES[qname](), d, settings)
            shard_bytes = sum(
                v.nbytes // n if k in cq.sharded_keys else v.nbytes
                for k, v in cq.inputs.items())
            secs = time_compiled(cq)
            rows.append({
                "n_shards": n,
                "seconds": secs,
                "per_shard_rows": int(shard_rows),
                "per_shard_input_bytes": int(shard_bytes),
                "exchanges": n_ex,
                "joins": n_join,
            })
            print(csv(f"shard/{qname}/n{n}", secs,
                      f"rows={shard_rows};bytes={shard_bytes};"
                      f"exchanges={n_ex}"))
            sys.stdout.flush()
        out["queries"][qname] = rows
    path = os.environ.get("REPRO_BENCH_SHARD_OUT", "BENCH_sharding.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)


def run() -> None:
    import jax

    if len(jax.devices()) >= max(MESHES):
        _run_local()
        return
    # jax already pinned this process to fewer devices: rerun ourselves
    # with the simulation flag set before any import can touch jax.
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={max(MESHES)}"
    ).strip()
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_sharding"],
        env=env, capture_output=True, text=True)
    sys.stdout.write(proc.stdout)
    if proc.returncode != 0:
        sys.stderr.write(proc.stderr)
        raise RuntimeError(
            f"sharding sweep subprocess failed ({proc.returncode})")


if __name__ == "__main__":
    if "jax" not in sys.modules:
        flags = os.environ.get("XLA_FLAGS", "")
        if "host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                flags + f" --xla_force_host_platform_device_count"
                        f"={max(MESHES)}").strip()
    _run_local()
