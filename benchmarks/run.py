"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  Environment knobs:
  REPRO_SF       TPC-H scale factor (default 0.05)
  REPRO_REPEATS  timing repeats (default 5)
  REPRO_QUICK=1  ladder/ablation on a query subset
"""
import os
import sys


def main() -> None:
    from benchmarks import (bench_ablation, bench_adaptive_compaction,
                            bench_analysis, bench_batched_bindings,
                            bench_compaction, bench_compile, bench_kernels,
                            bench_ladder, bench_loading, bench_memory,
                            bench_plan_cache, bench_roofline, bench_serving,
                            bench_sharding, bench_tiering)

    quick = os.environ.get("REPRO_QUICK") == "1"
    print("name,us_per_call,derived")
    bench_kernels.run()
    bench_loading.run()
    bench_memory.run()
    bench_compile.run()
    bench_plan_cache.run()
    bench_batched_bindings.run()
    bench_compaction.run()
    bench_adaptive_compaction.run()
    bench_analysis.run()
    if quick:
        from repro.relational import queries as Q
        keep = {"q1", "q3", "q6", "q12"}
        full = dict(Q.QUERIES)
        Q.QUERIES.clear()
        Q.QUERIES.update({k: v for k, v in full.items() if k in keep})
        try:
            bench_ladder.run()
            bench_ablation.run()
        finally:
            Q.QUERIES.clear()
            Q.QUERIES.update(full)
    else:
        bench_ladder.run()
        bench_ablation.run()
    bench_roofline.run()
    bench_sharding.run()
    bench_serving.run()
    bench_tiering.run()
    sys.stdout.flush()


if __name__ == "__main__":
    main()
