"""Adaptive capacity feedback: parameterized plans converging to compacted
execution.

Before PR 5 every Param-bounded predicate was estimated at selectivity 1.0,
so parameterized plans — the entire plan-cache / bind-many value
proposition — never compacted at all.  This bench drives each
parameterized query through the feedback loop:

  1. a deliberately *selective* initial binding compiles the entry (its
     capacities are planned from the sketch-based initial estimate, so
     they undershoot the steady workload);
  2. the steady binding (the literal query's defaults) is executed
     repeatedly: the first `compact_replan_after` executions overflow and
     fall back to the uncompacted twin, then the plan cache re-plans the
     shape with capacities derived from the observed true counts;
  3. from that point on every binding runs compacted with zero overflows.

Per query the JSON records the convergence trajectory (per-binding
overflow / capacities / replans), the steady-state per-binding latency of
the converged compacted entry vs the static mask-only path (compaction
off — what every parameterized plan was stuck with before), and result
drift vs the Volcano oracle under both bindings.  q6/q14 are included as
counterexamples: their plans end in fusing scalar aggregations, so the
pass correctly plants no points and they report `no_points`.

Writes `BENCH_adaptive_compaction.json` (or $REPRO_BENCH_ADAPT_OUT).
Scale factor: REPRO_ADAPT_SF, default 0.01 (serving-sized, matching the
other runtime benches).
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import time

from repro.core import PlanCache, VolcanoEngine, preset
from repro.relational import Database
from repro.relational.queries import PARAM_QUERIES
from repro.relational.schema import days

from benchmarks.bench_compaction import _drift
from benchmarks.common import REPEATS

SF = float(os.environ.get("REPRO_ADAPT_SF", "0.01"))

# (initial selective binding overlay, steady binding overlay) per query:
# the initial binding undershoots the steady one so the feedback loop has
# something to correct.  Overlays apply over the query's defaults.
SCHEDULES = {
    "q3": ({"cutoff": days("1998-11-01")}, {}),
    "q6": ({"qty_max": 2.0}, {}),
    "q12": ({"receipt_lo": days("1994-01-01"),
             "receipt_hi": days("1994-02-01")}, {}),
    "q14": ({"ship_lo": days("1995-09-01"),
             "ship_hi": days("1995-09-08")}, {}),
}
STEADY_RUNS = 8


def _time_entry(cq, binding) -> float:
    import jax

    inputs = cq.bind(binding)
    jax.block_until_ready(cq._jitted(inputs))
    times = []
    for _ in range(max(5, REPEATS)):
        t0 = time.perf_counter()
        jax.block_until_ready(cq._jitted(inputs))
        times.append(time.perf_counter() - t0)
    return min(times)


def run(out=print) -> dict:
    database = Database.tpch(sf=SF, seed=0)
    oracle = VolcanoEngine(database)
    s_on = preset("opt")
    s_off = dataclasses.replace(s_on, compaction=False)
    k = s_on.compact_replan_after
    results: dict = {"sf": SF, "replan_after": k, "queries": {}}

    for qname, (init_overlay, steady_overlay) in SCHEDULES.items():
        build, defaults = PARAM_QUERIES[qname]
        initial = dict(defaults, **init_overlay)
        steady = dict(defaults, **steady_overlay)
        cache = PlanCache(database)

        res_init = cache.execute(build(), s_on, initial)
        drift = _drift(res_init, oracle.execute(build(), initial))
        caps0 = list(cache.key_for(build(), s_on, initial)[-1])
        if not caps0:
            out(f"adaptive/{qname}/no_points,0.0,skipped")
            results["queries"][qname] = {"class": "no_points"}
            continue

        hist = []
        converged_after = None
        for i in range(STEADY_RUNS):
            before_of = cache.stats.overflows
            got = cache.execute(build(), s_on, steady)
            overflowed = cache.stats.overflows > before_of
            caps = list(cache.key_for(build(), s_on, steady)[-1])
            hist.append({"binding": i + 1, "overflowed": overflowed,
                         "capacities": caps,
                         "replans": cache.stats.replans})
            if not overflowed and caps and converged_after is None:
                converged_after = i  # steady bindings spent overflowing
        drift = max(drift, _drift(got, oracle.execute(build(), steady)))

        cq_on, rt_on = cache.get(build(), s_on, steady)
        cache_off = PlanCache(database)
        cq_off, rt_off = cache_off.get(build(), s_off, steady)
        t_on = _time_entry(cq_on, rt_on)
        t_off = _time_entry(cq_off, rt_off)
        speedup = t_off / max(t_on, 1e-12)
        results["queries"][qname] = {
            "class": "converged" if converged_after is not None
                     else "not_converged",
            "initial_capacities": caps0,
            "bindings_to_converge": converged_after,
            "converged_capacities": list(cq_on.capacities),
            "replans": cache.stats.replans,
            "shrinks": cache.stats.shrinks,
            "trajectory": hist,
            "mask_only_s": t_off,
            "compacted_s": t_on,
            "speedup": speedup,
            "post_converge_overflows": cq_on.n_overflows,
            "max_rel_drift_vs_oracle": drift,
        }
        out(f"adaptive/{qname}/mask_only,{t_off * 1e6:.1f},us")
        out(f"adaptive/{qname}/converged,{t_on * 1e6:.1f},"
            f"{speedup:.2f}x after {converged_after} overflowing bindings "
            f"caps {caps0}->{list(cq_on.capacities)}")

    measured = [r for r in results["queries"].values()
                if r["class"] != "no_points"]
    results["summary"] = {
        "n_param_classes": len(SCHEDULES),
        "n_with_points": len(measured),
        "n_converged_within_k": sum(
            r["class"] == "converged"
            and r["bindings_to_converge"] <= k for r in measured),
        "n_speedup_ge_2x": sum(r["speedup"] >= 2.0 for r in measured),
        "max_drift": max((r["max_rel_drift_vs_oracle"] for r in measured),
                         default=0.0),
    }
    path = os.environ.get("REPRO_BENCH_ADAPT_OUT",
                          "BENCH_adaptive_compaction.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=2)
    out(f"wrote {path}")
    return results


if __name__ == "__main__":
    res = run()
    # hard gates: correctness, and the feedback loop actually converging a
    # previously-uncompactable parameterized class; wall-clock speedups on
    # shared CI runners stay advisory (recorded in the JSON)
    ok = (res["summary"]["max_drift"] < 1e-2
          and res["summary"]["n_converged_within_k"] >= 1)
    sys.exit(0 if ok else 1)
