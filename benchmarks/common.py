"""Shared benchmark helpers: timed query execution per engine config."""
from __future__ import annotations

import os
import time


from repro.core import CompiledQuery, VolcanoEngine, preset
from repro.relational import Database
from repro.relational.queries import QUERIES

SF = float(os.environ.get("REPRO_SF", "0.05"))
REPEATS = int(os.environ.get("REPRO_REPEATS", "5"))

_DB = None


def db() -> Database:
    global _DB
    if _DB is None:
        _DB = Database.tpch(sf=SF)
    return _DB


def time_volcano(qname: str) -> float:
    eng = VolcanoEngine(db())
    times = []
    for _ in range(max(2, REPEATS // 2)):
        t0 = time.perf_counter()
        eng.execute(QUERIES[qname]())
        times.append(time.perf_counter() - t0)
    return min(times)


def compiled_query(qname: str, config: str) -> CompiledQuery:
    return CompiledQuery(QUERIES[qname](), db(), preset(config))


def time_compiled(cq: CompiledQuery) -> float:
    import jax

    out = cq._jitted(cq.inputs)           # warmup (compiles)
    jax.block_until_ready(out)
    times = []
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        out = cq._jitted(cq.inputs)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return min(times)


def time_config(qname: str, config: str) -> float:
    if config == "dbx":
        return time_volcano(qname)
    return time_compiled(compiled_query(qname, config))


def csv(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"
