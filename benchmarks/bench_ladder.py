"""Fig 16/17 / Table V: the engine ladder.

DBX (interpreted volcano) -> Naive (whole-query jit, no domain passes) ->
Template (per-operator fusion barriers ~ HyPer scope) -> TPC-H
(+partitioning) -> StrDict -> Opt (all passes).  Reports seconds per query
per config and the speedup of Opt over DBX / Naive.
"""
from __future__ import annotations

from repro.relational.queries import QUERIES

from benchmarks.common import csv, time_config

CONFIGS = ["dbx", "naive", "template", "tpch", "strdict", "opt"]


def run(out=print) -> dict:
    results: dict[str, dict[str, float]] = {}
    for qname in sorted(QUERIES):
        results[qname] = {}
        for config in CONFIGS:
            t = time_config(qname, config)
            results[qname][config] = t
            out(csv(f"ladder/{qname}/{config}", t))
    for qname, row in results.items():
        out(csv(f"ladder/{qname}/speedup_opt_vs_dbx", row["opt"],
                f"{row['dbx'] / row['opt']:.1f}x"))
        out(csv(f"ladder/{qname}/speedup_opt_vs_naive", row["opt"],
                f"{row['naive'] / row['opt']:.1f}x"))
    geo = 1.0
    for row in results.values():
        geo *= row["dbx"] / row["opt"]
    geo **= 1.0 / len(results)
    out(csv("ladder/geomean_speedup_opt_vs_dbx", 0.0, f"{geo:.1f}x"))
    return results
