"""Selection-vector compaction: mask-only vs compacted latency.

The mask-carrying execution model pays full-table cost downstream of every
predicate; the Compaction pass (passes/compaction.py) gathers the valid
rows into statically-capacitied dense frames so joins, aggregations and
sorts run over the surviving cardinality instead.  For each selective
query, time the steady-state jitted execution under preset("opt") with
`Settings.compaction` off (mask-only) and on (compacted), verify zero
result drift against the Volcano oracle either way, and record the planted
capacity buckets plus any runtime overflows (an overflowing run falls back
to the uncompacted twin, so a non-zero overflow count means the speedup
column is measuring the fallback, not compaction).

Writes `BENCH_compaction.json` (or $REPRO_BENCH_COMPACT_OUT).  The scale
factor is serving-sized (REPRO_COMPACT_SF, default 0.01), matching the
plan-cache / batched-bindings benches.
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import time

import numpy as np

from repro.core import CompiledQuery, VolcanoEngine, preset
from repro.relational import Database
from repro.relational.queries import QUERIES

from benchmarks.common import REPEATS

SF = float(os.environ.get("REPRO_COMPACT_SF", "0.01"))

# the selective-query slice of the workload: every query whose predicates
# leave a small fraction of a large frame alive (the q6/q19 class)
SELECTIVE = ["q3", "q5", "q6", "q7", "q10", "q12", "q17", "q19"]


def _time(cq: CompiledQuery) -> float:
    import jax

    out = cq._jitted(cq.inputs)
    jax.block_until_ready(out)
    times = []
    for _ in range(max(5, REPEATS)):
        t0 = time.perf_counter()
        out = cq._jitted(cq.inputs)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return min(times)


def _drift(a: dict, b: dict) -> float:
    worst = 0.0
    for k in a:
        va, vb = np.asarray(a[k]), np.asarray(b[k])
        if va.shape != vb.shape:
            return float("inf")
        if va.dtype.kind in "fc" or vb.dtype.kind in "fc":
            va64 = np.sort(va.astype(np.float64))
            vb64 = np.sort(vb.astype(np.float64))
            scale = np.maximum(np.abs(vb64), 1.0)
            worst = max(worst, float(np.max(np.abs(va64 - vb64) / scale,
                                            initial=0.0)))
        elif not np.array_equal(np.sort(va, axis=0), np.sort(vb, axis=0)):
            return float("inf")
    return worst


def run(out=print) -> dict:
    database = Database.tpch(sf=SF, seed=0)
    oracle = VolcanoEngine(database)
    s_on = preset("opt")
    s_off = dataclasses.replace(s_on, compaction=False)
    results: dict = {"sf": SF, "queries": {}}

    for qname in SELECTIVE:
        cq_on = CompiledQuery(QUERIES[qname](), database, s_on)
        cq_off = CompiledQuery(QUERIES[qname](), database, s_off)
        caps = list(cq_on.capacities)
        if not caps:
            out(f"compaction/{qname}/no_points,0.0,skipped")
            results["queries"][qname] = {"capacities": []}
            continue
        want = oracle.execute(QUERIES[qname]())
        drift_on = _drift(cq_on.run(), want)
        drift_off = _drift(cq_off.run(), want)
        t_on = _time(cq_on)
        t_off = _time(cq_off)
        speedup = t_off / max(t_on, 1e-12)
        results["queries"][qname] = {
            "capacities": caps,
            "mask_only_s": t_off,
            "compacted_s": t_on,
            "speedup": speedup,
            "overflows": cq_on.n_overflows,
            "max_rel_drift_vs_oracle": max(drift_on, drift_off),
        }
        out(f"compaction/{qname}/mask_only,{t_off * 1e6:.1f},us")
        out(f"compaction/{qname}/compacted,{t_on * 1e6:.1f},"
            f"{speedup:.2f}x caps={caps} overflows={cq_on.n_overflows}")

    measured = [r for r in results["queries"].values() if "speedup" in r]
    results["summary"] = {
        "n_measured": len(measured),
        "n_speedup_ge_3x": sum(r["speedup"] >= 3.0 for r in measured),
        "n_overflowed": sum(r["overflows"] > 0 for r in measured),
        "max_drift": max((r["max_rel_drift_vs_oracle"] for r in measured),
                         default=0.0),
    }
    path = os.environ.get("REPRO_BENCH_COMPACT_OUT", "BENCH_compaction.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=2)
    out(f"wrote {path}")
    return results


if __name__ == "__main__":
    res = run()
    # correctness is the only hard gate: wall-clock speedups on shared CI
    # runners are advisory (the JSON records them for the nightly artifact)
    sys.exit(0 if res["summary"]["max_drift"] < 1e-2 else 1)
