"""Beyond-paper: Pallas kernel validation + analytic kernel roofline.

CPU wall-time of interpret-mode kernels is not meaningful; we validate
against the jnp oracle and report the *analytic* per-tile arithmetic
intensity of each kernel at TPU-relevant shapes (VMEM-tile FLOPs vs HBM
bytes), which is what determines the kernels' roofline position on chip.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from benchmarks.common import csv


def run(out=print) -> dict:
    results = {}
    rng = np.random.default_rng(0)

    # filter_agg @ Q1-like shape: 6 groups, 4 aggregates + count
    n, g, a, tile = 60_000, 6, 5, 2048
    mask = jnp.asarray(rng.random(n) < 0.95)
    gidx = jnp.asarray(rng.integers(0, g, n), dtype=jnp.int32)
    vals = jnp.asarray(rng.normal(size=(n, a)), dtype=jnp.float32)
    got = ops.filter_agg(mask, gidx, vals, g, tile=tile)
    want = ref.filter_agg_ref(mask, gidx, vals, g)
    err = float(jnp.max(jnp.abs(got - want)))
    flops_tile = 2 * tile * g * a           # one-hot matmul per tile
    bytes_tile = tile * (1 + 4 + 4 * a)     # mask+gidx+vals per tile
    results["filter_agg"] = {"max_err": err,
                             "intensity": flops_tile / bytes_tile}
    out(csv("kernels/filter_agg/max_err", 0.0, f"{err:.2e}"))
    out(csv("kernels/filter_agg/arith_intensity", 0.0,
            f"{flops_tile / bytes_tile:.2f} flop/byte"))

    # gather_join @ nation-join shape: K=25 parent rows, 3 columns
    k, c = 25, 3
    fk = jnp.asarray(rng.integers(0, k, n), dtype=jnp.int32)
    table = jnp.asarray(rng.normal(size=(k, c)), dtype=jnp.float32)
    got = ops.gather_join(fk, table, tile=1024)
    want = ref.gather_join_ref(fk, table)
    err = float(jnp.max(jnp.abs(got - want)))
    results["gather_join"] = {"max_err": err}
    out(csv("kernels/gather_join/max_err", 0.0, f"{err:.2e}"))

    # masked_topk @ Q3-like shape
    vals1 = jnp.asarray(rng.permutation(n).astype(np.float32))
    mask1 = jnp.asarray(rng.random(n) < 0.5)
    tv, ti = ops.masked_topk(vals1, mask1, 10, tile=4096)
    wv, wi = ref.masked_topk_ref(vals1, mask1, 10)
    ok = bool(jnp.all(tv == wv))
    results["masked_topk"] = {"exact": ok}
    out(csv("kernels/masked_topk/exact_match", 0.0, str(ok)))
    return results
