"""Beyond-paper: Pallas kernel validation + analytic kernel roofline.

CPU wall-time of interpret-mode kernels is not meaningful; we validate
against the jnp oracle and report *analytic* figures that determine the
kernels' on-chip position: per-tile arithmetic intensity (VMEM-tile FLOPs
vs HBM bytes), and — for the mega-kernel pipelines — modeled HBM traffic
of the fused one-pass form vs the >=3 passes XLA executes unfused.  When
a real TPU/GPU backend is attached (interpret resolves off) wall-clock
per kernel is measured too.

Writes `BENCH_kernels.json` next to the repo root (or $REPRO_BENCH_OUT).
"""
from __future__ import annotations

import json
import os
import time

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from benchmarks.common import csv

F32 = 4          # bytes
I32 = 4
BOOL = 1


def _wallclock(fn):
    """Median-of-5 wall time in ms; only called on a real backend."""
    fn()                                        # compile
    ts = []
    for _ in range(5):
        t0 = time.perf_counter()
        r = fn()
        jnp.asarray(r[0] if isinstance(r, tuple) else r).block_until_ready()
        ts.append((time.perf_counter() - t0) * 1e3)
    return float(np.median(ts))


def _pipeline_traffic(n, n_pred_cols, n_val_cols, cap, n_groups, n_aggs):
    """Modeled HBM bytes: fused single pass vs the unfused XLA schedule.

    Unfused (what the `opt` rung stages):
      pass 1  read predicate columns, write the mask
      pass 2  read mask (cumsum + searchsorted), write idx; gather every
              carried column down to `cap` rows (read column + idx, write
              compacted column)
      pass 3  consumer reads the compacted value columns and reduces
    Fused (`opt-pallas`): every referenced base column streams through
    VMEM exactly once; only the results (idx + group sums) hit HBM.
    """
    carried = n_pred_cols + n_val_cols
    unfused = (
        n * n_pred_cols * F32 + n * BOOL                  # pass 1
        + n * BOOL + cap * I32                            # pass 2: rank
        + carried * (n * F32 + cap * I32 + cap * F32)     # pass 2: gathers
        + cap * n_val_cols * F32 + n_groups * n_aggs * F32  # pass 3
    )
    fused = (n * carried * F32 + cap * I32
             + n_groups * n_aggs * F32)
    return unfused, fused


def run(out=print) -> dict:
    results = {}
    rng = np.random.default_rng(0)
    interpret = ops.resolve_interpret(None)
    results["interpret"] = bool(interpret)

    # filter_agg @ Q1-like shape: 6 groups, 4 aggregates + count
    n, g, a, tile = 60_000, 6, 5, 2048
    mask = jnp.asarray(rng.random(n) < 0.95)
    gidx = jnp.asarray(rng.integers(0, g, n), dtype=jnp.int32)
    vals = jnp.asarray(rng.normal(size=(n, a)), dtype=jnp.float32)
    got = ops.filter_agg(mask, gidx, vals, g, tile=tile)
    want = ref.filter_agg_ref(mask, gidx, vals, g)
    err = float(jnp.max(jnp.abs(got - want)))
    flops_tile = 2 * tile * g * a           # one-hot matmul per tile
    bytes_tile = tile * (1 + 4 + 4 * a)     # mask+gidx+vals per tile
    results["filter_agg"] = {"max_err": err,
                             "intensity": flops_tile / bytes_tile}
    out(csv("kernels/filter_agg/max_err", 0.0, f"{err:.2e}"))
    out(csv("kernels/filter_agg/arith_intensity", 0.0,
            f"{flops_tile / bytes_tile:.2f} flop/byte"))

    # gather_join @ nation-join shape: K=25 parent rows, 3 columns
    k, c = 25, 3
    fk = jnp.asarray(rng.integers(0, k, n), dtype=jnp.int32)
    table = jnp.asarray(rng.normal(size=(k, c)), dtype=jnp.float32)
    got = ops.gather_join(fk, table, tile=1024)
    want = ref.gather_join_ref(fk, table)
    err = float(jnp.max(jnp.abs(got - want)))
    results["gather_join"] = {"max_err": err}
    out(csv("kernels/gather_join/max_err", 0.0, f"{err:.2e}"))

    # masked_topk @ Q3-like shape
    vals1 = jnp.asarray(rng.permutation(n).astype(np.float32))
    mask1 = jnp.asarray(rng.random(n) < 0.5)
    tv, ti = ops.masked_topk(vals1, mask1, 10, tile=4096)
    wv, wi = ref.masked_topk_ref(vals1, mask1, 10)
    ok = bool(jnp.all(tv == wv))
    results["masked_topk"] = {"exact": ok}
    out(csv("kernels/masked_topk/exact_match", 0.0, str(ok)))

    # ---- single-pass compaction + the fused selective pipeline ----

    # compact @ selectivity sweep: validate, model HBM traffic
    results["compact"] = {}
    for sel in (0.005, 0.05, 0.5):
        m = jnp.asarray(rng.random(n) < sel)
        true = int(np.asarray(m).sum())
        cap = 1 << max(int(true - 1).bit_length(), 5)
        idx, count = ops.compact(m, cap, tile=2048)
        widx, _ = ref.compact_ref(m, cap)
        exact = bool(np.array_equal(np.asarray(idx), np.asarray(widx))
                     and int(count) == true)
        # unfused: read mask (cumsum), read mask + running count again
        # (searchsorted), write idx — vs one streamed mask pass
        unfused = 2 * n * BOOL + n * I32 + cap * I32
        fused = n * BOOL + cap * I32
        key = f"sel_{sel}"
        results["compact"][key] = {
            "exact": exact, "capacity": cap,
            "hbm_bytes_unfused": unfused, "hbm_bytes_fused": fused,
            "traffic_ratio": unfused / fused,
        }
        out(csv(f"kernels/compact/{key}/traffic_ratio", 0.0,
                f"{unfused / fused:.2f}x (cap {cap}, exact={exact})"))

    # fused pred->compact->agg pipeline @ q6-like shape: 3 predicate
    # columns, 2 value columns, scalar aggregates, ~2% selectivity
    n_pred_cols, n_val_cols, n_aggs, n_groups = 3, 2, 3, 1
    cols = {f"p{i}": jnp.asarray(rng.normal(size=n), jnp.float32)
            for i in range(n_pred_cols)}
    cols.update({f"v{i}": jnp.asarray(rng.normal(size=n), jnp.float32)
                 for i in range(n_val_cols)})
    scalars = [jnp.float32(-2.0)]

    def pred(c, s):
        return (c["p0"] < s[0]) & (c["p1"] < 0.0) & (c["p2"] < 0.0)

    def vfn(c, s):
        return [c["v0"] * c["v1"], c["v0"], jnp.float32(1.0)]

    cap = 2048
    got = ops.selective_filter_agg(cols, scalars, pred, vfn, None, n_aggs,
                                   n_groups, capacity=cap, tile=2048)
    want = ref.selective_filter_agg_ref(cols, scalars, pred, vfn, None,
                                        n_aggs, n_groups, cap, False)
    err = float(jnp.max(jnp.abs(jnp.asarray(got[0]) - jnp.asarray(want[0]))))
    unfused, fused = _pipeline_traffic(n, n_pred_cols, n_val_cols, cap,
                                       n_groups, n_aggs)
    results["selective_pipeline"] = {
        "max_err": err, "n": n, "capacity": cap,
        "hbm_bytes_unfused": unfused, "hbm_bytes_fused": fused,
        "traffic_ratio": unfused / fused, "hbm_passes_unfused": 3,
        "hbm_passes_fused": 1,
    }
    out(csv("kernels/selective_pipeline/max_err", 0.0, f"{err:.2e}"))
    out(csv("kernels/selective_pipeline/traffic_ratio", 0.0,
            f"{unfused / fused:.2f}x (3 passes -> 1)"))

    if not interpret:   # real accelerator attached: wall-clock is real
        results["compact"]["wall_ms"] = _wallclock(
            lambda: ops.compact(mask, 4096, tile=2048))
        results["selective_pipeline"]["wall_ms"] = _wallclock(
            lambda: ops.selective_filter_agg(
                cols, scalars, pred, vfn, None, n_aggs, n_groups,
                capacity=cap, tile=2048))

    path = os.environ.get("REPRO_BENCH_OUT", "BENCH_kernels.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=2)
    out(f"wrote {path}")
    return results


if __name__ == "__main__":
    run()
