"""Open-loop serving sweep: throughput vs tail latency with and without
the overload machinery (admission + degradation ladder + adaptive
windows).

Two seeded arrival traces — Poisson (exponential gaps) and bursty
(on/off periods at 8x / x/8 the base rate) — are replayed open-loop
(arrival times fixed in advance, submission never waits for results,
the real overload regime) against two servers:

  * `degrading`: bounded budget, degradation ladder, adaptive window —
    the hardened configuration;
  * `plain`: effectively unbounded budget, fixed tick, no ladder — the
    pre-hardening server.

The scale factor defaults to 0.1 — large enough that per-request scan
compute dominates the dispatch (a vmapped batch of k costs ~k× a
scalar run), so service capacity is genuinely finite and an arrival
rate above it grows a real queue.  Arrival rates are multiples of the
measured batched capacity.  Above saturation the plain server's queue
(and therefore its p99) grows with the trace length, while the
degrading server holds p99 roughly flat by shedding and rejecting: the
`divergence` section replays the top rate at increasing N to show
exactly that.  Every completed result is checked against the Volcano
oracle — degradation must never cost correctness (`oracle_drift` must
be 0).

Writes `BENCH_serving.json` (or $REPRO_BENCH_SERVING_OUT).  Knobs:
REPRO_SERVE_SF (default 0.1), REPRO_SERVE_N (requests per trace,
default 240).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import VolcanoEngine, degrade, preset
from repro.core.plan_cache import PlanCache
from repro.relational import Database
from repro.relational.queries import PARAM_QUERIES
from repro.serve.query_server import QueryServer

SF = float(os.environ.get("REPRO_SERVE_SF", "0.1"))
N = int(os.environ.get("REPRO_SERVE_N", "240"))
MULTS = (0.25, 2.0, 8.0)          # arrival rate / batched service capacity
DIVERGE_NS = (N // 2, N)          # trace lengths for the divergence replay
MAX_BATCH = 8
WORKERS = 2
BUDGET = 32                       # degrading server's admission budget
N_BINDINGS = 8
SEED = 0


def _bindings_pool() -> list[dict]:
    _, defaults = PARAM_QUERIES["q6"]
    return [dict(defaults, qty_max=10.0 + 2.0 * i)
            for i in range(N_BINDINGS)]


def _arrivals(kind: str, n: int, rate: float, rng) -> np.ndarray:
    """Cumulative arrival offsets (seconds) for an open-loop trace."""
    if kind == "poisson":
        gaps = rng.exponential(1.0 / rate, size=n)
    else:                          # bursty: alternating 8x / x/8 periods
        period = max(n // 8, 1)
        on = (np.arange(n) // period) % 2 == 0
        gaps = np.where(on, rng.exponential(1.0 / (8 * rate), size=n),
                        rng.exponential(8.0 / rate, size=n))
    return np.cumsum(gaps)


def _make_server(db, cache: PlanCache, hardened: bool) -> QueryServer:
    if hardened:
        return QueryServer(db, preset("opt"), cache=cache,
                           max_batch=MAX_BATCH, max_workers=WORKERS,
                           window_s=0.002, budget=BUDGET,
                           degradation=True, adaptive_window=True,
                           shed_batch_load=0.7, shed_plan_load=0.85)
    return QueryServer(db, preset("opt"), cache=cache,
                       max_batch=MAX_BATCH, max_workers=WORKERS,
                       window_s=0.002, budget=1 << 30, degradation=False,
                       adaptive_window=False)


def _warm(cache: PlanCache, pool: list[dict]) -> None:
    """Pay every compile/trace outside the timed traces: the scalar + the
    vmapped buckets for the full settings, and the degraded (mask-only)
    twin the ladder switches to under load.  One shared cache serves all
    the trace servers, so this runs once."""
    build, _ = PARAM_QUERIES["q6"]
    for settings in (preset("opt"), degrade(preset("opt"))):
        cq, runtime = cache.get(build(), settings, pool[0])
        cq.run(runtime)
        for bsz in (2, 4, MAX_BATCH):
            runtimes = [dict(runtime) for _ in range(bsz)]
            cache.run_many(cq, runtimes)


def _trace(db, cache: PlanCache, hardened: bool, kind: str, rate: float,
           n: int, pool: list[dict], want: list[dict]) -> dict:
    build, _ = PARAM_QUERIES["q6"]
    rng = np.random.default_rng(SEED)
    offsets = _arrivals(kind, n, rate, rng)
    binding_ix = rng.integers(0, len(pool), size=n)
    srv = _make_server(db, cache, hardened)
    degraded_before = cache.stats.degraded
    lat: list[float] = []
    drift = [0]

    def on_done(i: int, t_arrival: float):
        def _cb(f):
            if f.cancelled() or f.exception() is not None:
                return
            lat.append(time.monotonic() - t_arrival)
            got = f.result()
            w = want[binding_ix[i]]
            same = set(got) == set(w) and all(
                np.allclose(np.asarray(got[c], np.float64),
                            np.asarray(w[c], np.float64),
                            rtol=1e-4, atol=1e-4) for c in got)
            if not same:
                drift[0] += 1
        return _cb

    rejected = 0
    t0 = time.monotonic()
    for i in range(n):
        due = t0 + offsets[i]
        delay = due - time.monotonic()
        if delay > 0:
            time.sleep(delay)
        t_arr = time.monotonic()
        try:
            fut = srv.submit(build(), dict(pool[binding_ix[i]]),
                             tenant=f"t{i % 4}")
        except RuntimeError:       # Overloaded: the ladder's last rung
            rejected += 1
            continue
        fut.add_done_callback(on_done(i, t_arr))
    srv.drain()
    wall = time.monotonic() - t0
    srv.close()
    st = srv.stats
    lat_arr = np.sort(np.asarray(lat)) if lat else np.zeros(1)
    return {
        "n": n, "rate_per_s": rate, "completed": st.completed,
        "rejected": rejected, "shed_batch": st.shed_batch,
        "shed_plan": st.shed_plan, "deadline_misses": st.deadline_misses,
        "errors": st.errors, "retries": st.retries,
        "throughput_per_s": st.completed / wall if wall > 0 else 0.0,
        "p50_s": float(lat_arr[int(0.50 * (len(lat_arr) - 1))]),
        "p99_s": float(lat_arr[int(0.99 * (len(lat_arr) - 1))]),
        "hist_p99_s": st.latency.p99(),
        "oracle_drift": drift[0],
        "degraded_served": cache.stats.degraded - degraded_before,
    }


def run(out=print) -> dict:
    database = Database.tpch(sf=SF, seed=0)
    build, _ = PARAM_QUERIES["q6"]
    pool = _bindings_pool()
    oracle = VolcanoEngine(database)
    want = [oracle.execute(build(), b) for b in pool]

    cache = PlanCache(database)
    _warm(cache, pool)

    # measured batched capacity: the unit the arrival-rate sweep scales
    cq, runtime = cache.get(build(), preset("opt"), pool[0])
    runtimes = [dict(runtime) for _ in range(MAX_BATCH)]
    times = []
    for _ in range(5):
        t0 = time.perf_counter()
        cache.run_many(cq, runtimes)
        times.append(time.perf_counter() - t0)
    batch_s = min(times)
    # single-stream batched capacity (workers contend for the same
    # cores, so scaling by WORKERS would overestimate): x0.5 is real
    # underload, x2/x8 real overload
    base_rate = MAX_BATCH / batch_s
    out(f"serving/batch{MAX_BATCH}_time,{batch_s * 1e6:.1f},us")
    out(f"serving/capacity,{base_rate:.0f},req_per_s")

    results: dict = {"sf": SF, "n": N, "batch_s": batch_s,
                     "capacity_per_s": base_rate,
                     "traces": {}, "divergence": {}}
    total_drift = 0
    for kind in ("poisson", "bursty"):
        results["traces"][kind] = {}
        for m in MULTS:
            cell = {}
            for label, hardened in (("degrading", True), ("plain", False)):
                r = _trace(database, cache, hardened, kind, m * base_rate,
                           N, pool, want)
                cell[label] = r
                total_drift += r["oracle_drift"]
                out(f"serving/{kind}/x{m:g}/{label}/p99,"
                    f"{r['p99_s'] * 1e6:.1f},"
                    f"us thr={r['throughput_per_s']:.0f}/s "
                    f"rej={r['rejected']} shed={r['shed_batch']}"
                    f"+{r['shed_plan']}")
            results["traces"][kind][f"x{m:g}"] = cell

    # divergence: above saturation the plain p99 grows with trace length,
    # the degrading p99 must not
    top = max(MULTS)
    for n in DIVERGE_NS:
        cell = {}
        for label, hardened in (("degrading", True), ("plain", False)):
            r = _trace(database, cache, hardened, "poisson",
                       top * base_rate, n, pool, want)
            cell[label] = r
            total_drift += r["oracle_drift"]
            out(f"serving/diverge/n{n}/{label}/p99,"
                f"{r['p99_s'] * 1e6:.1f},us")
        results["divergence"][str(n)] = cell
    results["oracle_drift"] = total_drift

    path = os.environ.get("REPRO_BENCH_SERVING_OUT", "BENCH_serving.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=2)
    out(f"wrote {path}")
    return results


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
