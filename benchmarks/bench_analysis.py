"""Static-analysis overhead (PR 6): what the analysis layer and the
inter-pass verifier cost at optimize time.

Three measurements per query, writing ``BENCH_analysis.json``:

  analyze_us     one `analyze()` pass over the final optimized plan — the
                 price every analysis consumer (hash-map lowering,
                 compaction estimation, one verifier rule set) pays
  optimize_us    `optimize()` at the default settings (verifier ON — the
                 shipped configuration)
  optimize_off_us  `optimize()` with `verify_passes=False` (the serving
                 escape hatch)

The acceptance bound — analysis overhead ≤ 5% of optimize time — is
checked as analyze_us / optimize_us: one analysis pass against the
default optimize.  Against the verifier-off time the ratio is higher by
construction (analysis is the core work of two of the passes), so both
ratios are reported.  All of this is compile-time cost: a single XLA
trace is ~2 orders of magnitude above either number.
"""
from __future__ import annotations

import dataclasses
import json
import time

from repro.core import preset
from repro.core.analysis import analyze
from repro.core.passes.pipeline import optimize
from repro.relational.queries import QUERIES

from benchmarks.common import REPEATS, csv, db


def _best(fn, repeats: int) -> float:
    times = []
    for _ in range(max(3, repeats)):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return min(times)


def run(out=print, queries=None) -> dict:
    queries = queries or sorted(QUERIES)
    d = db()
    s_on = preset("opt")
    s_off = dataclasses.replace(s_on, verify_passes=False)
    results: dict[str, dict[str, float]] = {}
    for qname in queries:
        fn = QUERIES[qname]
        optimize(fn(), d, s_on)  # warm sketches/caches
        t_on = _best(lambda: optimize(fn(), d, s_on), REPEATS)
        t_off = _best(lambda: optimize(fn(), d, s_off), REPEATS)
        final = optimize(fn(), d, s_off)
        t_an = _best(lambda: analyze(final, d), REPEATS)
        results[qname] = {
            "analyze_us": t_an * 1e6,
            "optimize_us": t_on * 1e6,
            "optimize_off_us": t_off * 1e6,
            "analyze_over_optimize": t_an / t_on,
            "verify_ratio": t_on / t_off,
        }
        out(csv(f"analysis/{qname}/analyze", t_an,
                f"{100 * t_an / t_on:.1f}% of optimize"))
    with open("BENCH_analysis.json", "w") as f:
        json.dump(results, f, indent=2)
    return results


if __name__ == "__main__":
    run()
