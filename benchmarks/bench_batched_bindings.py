"""Vectorized bind-many throughput: one vmapped XLA dispatch for N
concurrent bindings vs the PR 2 sequential rebind loop.

The serving scenario: thousands of concurrent requests bind the *same*
cached plan under different `param/<name>` scalars.  PR 2's hit path
re-executes the scalar program once per request (N dispatches); the
batched path stacks the bindings on a leading axis and runs the vmapped
program once, with table data shared across the batch (`in_axes=None`).

For each parameterized query, measure per-binding latency at batch sizes
1/4/16/64 through `CompiledQuery.run_many` (power-of-two buckets, so each
size is its own trace exactly once), plus the sequential rebind loop over
the same 64 bindings.  Writes `BENCH_batched_bindings.json` (or
$REPRO_BENCH_BATCHED_OUT).

The scale factor is deliberately serving-sized (REPRO_BATCH_SF, default
0.01): dispatch overhead, not scan bandwidth, is what batching
amortizes, and the superlinear per-binding drop is the acceptance
criterion for the batched runtime layer.
"""
from __future__ import annotations

import json
import os
import time

from repro.core import PlanCache, preset
from repro.core import compile as compile_mod
from repro.relational import Database
from repro.relational.queries import PARAM_QUERIES
from repro.relational.schema import days

from benchmarks.common import REPEATS

SF = float(os.environ.get("REPRO_BATCH_SF", "0.01"))
BATCHES = (1, 4, 16, 64)


def bindings_for(qname: str, n: int) -> list[dict]:
    """n distinct bindings varying only *runtime* params, so every one
    shares the same plan key (and therefore the same batch group)."""
    _, defaults = PARAM_QUERIES[qname]
    out = []
    for i in range(n):
        b = dict(defaults)
        if qname == "q1":
            b["shipdate_hi"] = days("1996-01-01") + 13 * i
        elif qname == "q3":
            b["cutoff"] = days("1995-01-01") + 5 * i
        elif qname == "q6":
            b["qty_max"] = 10.0 + 0.35 * i
        elif qname == "q12":
            b["receipt_lo"] = days("1994-01-01") + 4 * i
            b["receipt_hi"] = days("1995-01-01") + 4 * i
        elif qname == "q14":
            b["ship_lo"] = days("1994-01-01") + 7 * i
            b["ship_hi"] = days("1994-02-01") + 7 * i
        elif qname == "q19":
            b["qty1_lo"] = 1.0 + 0.1 * i
            b["qty2_lo"] = 8.0 + 0.1 * i
            b["qty3_lo"] = 16.0 + 0.1 * i
        out.append(b)
    return out


def run(out=print) -> dict:
    database = Database.tpch(sf=SF, seed=0)
    cache = PlanCache(database)
    settings = preset("opt")
    repeats = max(3, REPEATS)
    results: dict = {"sf": SF, "batch_sizes": list(BATCHES)}

    for qname in sorted(PARAM_QUERIES):
        build, defaults = PARAM_QUERIES[qname]
        cq, _ = cache.get(build(), settings, defaults)
        per_binding: dict[int, float] = {}
        for bsz in BATCHES:
            bl = bindings_for(qname, bsz)
            runtimes = [{k: b[k] for k in cq.param_spec} for b in bl]
            cache.run_many(cq, runtimes)   # warm: trace + compile bucket
            times = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                cache.run_many(cq, runtimes)
                times.append(time.perf_counter() - t0)
            per_binding[bsz] = min(times) / bsz
            out(f"batched/{qname}/batch{bsz}/per_binding,"
                f"{per_binding[bsz] * 1e6:.1f},us")

        # the PR 2 baseline: N sequential scalar dispatches
        bl = bindings_for(qname, max(BATCHES))
        runtimes = [{k: b[k] for k in cq.param_spec} for b in bl]
        cq.run(runtimes[0])                # warm scalar program
        times = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            for r in runtimes:
                cq.run(r)
            times.append(time.perf_counter() - t0)
        loop_per_binding = min(times) / len(runtimes)
        out(f"batched/{qname}/rebind_loop/per_binding,"
            f"{loop_per_binding * 1e6:.1f},us")

        results[qname] = {
            "per_binding_s": {str(b): per_binding[b] for b in BATCHES},
            "rebind_loop_per_binding_s": loop_per_binding,
            "speedup_batch64_vs_batch1":
                per_binding[1] / max(per_binding[64], 1e-12),
            "speedup_batch64_vs_rebind_loop":
                loop_per_binding / max(per_binding[64], 1e-12),
            "batch_traces": cq.n_batch_traces,
        }
        out(f"batched/{qname}/speedup_64_vs_1,"
            f"{results[qname]['speedup_batch64_vs_batch1']:.1f},x")

    results["cache_stats"] = {
        "compiles": cache.stats.compiles,
        "batch_traces": cache.stats.batch_traces,
        "padded_slots": cache.stats.padded_slots,
        "stagings": compile_mod.STAGINGS,
    }
    path = os.environ.get("REPRO_BENCH_BATCHED_OUT",
                          "BENCH_batched_bindings.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=2)
    out(f"wrote {path}")
    return results


if __name__ == "__main__":
    run()
