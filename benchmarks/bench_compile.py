"""Fig 22 / Table VII: compilation overheads per query (Opt config).

Splits the cost the way the paper does: SC-analogue optimization time
(pass pipeline + staging/collection walk) vs backend code generation
(XLA lower + compile).  Paper claim: ≲1.2 s per query end to end.
"""
from __future__ import annotations

from repro.core import CompiledQuery, preset
from repro.relational.queries import QUERIES

from benchmarks.common import csv, db


def run(out=print) -> dict:
    results = {}
    for qname in sorted(QUERIES):
        cq = CompiledQuery(QUERIES[qname](), db(), preset("opt"))
        cq.compile()
        r = {"passes": cq.pass_time, "staging": cq.stage_time,
             "xla_lower": cq.lower_time, "xla_compile": cq._compile_time}
        results[qname] = r
        total = sum(r.values())
        out(csv(f"compile/{qname}/passes", r["passes"]))
        out(csv(f"compile/{qname}/staging", r["staging"]))
        out(csv(f"compile/{qname}/xla", r["xla_lower"] + r["xla_compile"]))
        out(csv(f"compile/{qname}/total", total))
    return results
