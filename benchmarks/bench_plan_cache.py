"""Plan-cache amortization: cold compile vs cached re-bind latency.

Extends `bench_compile.py` (Fig 22 / Table VII measured one-shot
compilation cost) to the runtime layer's serving story: for each
parameterized query, measure

  cold      — first execution through the PlanCache (passes + staging +
              XLA JIT + run);
  rebind    — subsequent executions with *different* parameter bindings
              (cache hit: bind scalars + run the jitted callable);
  amortization = cold / rebind.

Writes `BENCH_plan_cache.json` next to the repo root (or $REPRO_BENCH_OUT).
"""
from __future__ import annotations

import json
import os
import time

from repro.core import PlanCache, preset
from repro.core import compile as compile_mod
from repro.relational.queries import PARAM_ALT_BINDINGS as ALT_BINDINGS
from repro.relational.queries import PARAM_QUERIES

from benchmarks.common import REPEATS, csv, db


def run(out=print) -> dict:
    database = db()
    cache = PlanCache(database)
    settings = preset("opt")
    results = {}
    for qname in sorted(PARAM_QUERIES):
        build, defaults = PARAM_QUERIES[qname]
        alt = dict(defaults, **ALT_BINDINGS[qname])

        before = compile_mod.STAGINGS
        t0 = time.perf_counter()
        cache.execute(build(), settings, defaults)
        cold = time.perf_counter() - t0
        assert compile_mod.STAGINGS - before == 1

        rebinds = []
        for i in range(max(3, REPEATS)):
            bindings = alt if i % 2 == 0 else defaults
            t0 = time.perf_counter()
            cache.execute(build(), settings, bindings)
            rebinds.append(time.perf_counter() - t0)
        rebind = min(rebinds)
        assert compile_mod.STAGINGS - before == 1, "rebind must not re-stage"

        results[qname] = {"cold_s": cold, "rebind_s": rebind,
                          "amortization": cold / max(rebind, 1e-9)}
        out(csv(f"plan_cache/{qname}/cold", cold))
        out(csv(f"plan_cache/{qname}/rebind", rebind))
        out(f"plan_cache/{qname}/amortization,"
            f"{results[qname]['amortization']:.1f},x")

    results["cache_stats"] = {
        "hits": cache.stats.hits, "misses": cache.stats.misses,
        "compiles": cache.stats.compiles,
    }
    path = os.environ.get("REPRO_BENCH_OUT", "BENCH_plan_cache.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=2)
    out(f"wrote {path}")
    return results


if __name__ == "__main__":
    run()
