"""Fig 19 / Table VI: per-optimization impact.

Starting from the full Opt configuration, disable one optimization at a
time and report the slowdown — the paper's additive analysis inverted
(theirs adds optimizations; ours removes them, which isolates each pass's
marginal contribution under composition).
"""
from __future__ import annotations

import dataclasses

from repro.core import CompiledQuery, preset
from repro.relational.queries import QUERIES

from benchmarks.common import csv, db, time_compiled

ABLATIONS = {
    "no_partitioning": {"partitioning": False},
    "no_dense_agg": {"dense_agg": False},
    "no_date_index": {"date_index": False},
    "no_string_dict": {"string_dict": False},
    "no_column_pruning": {"column_pruning": False},
    "no_hoist": {"hoist": False},
    "no_cse": {"cse": False},
    "no_fusion": {"fusion": False},
    "with_row_layout": {"layout": "row"},
}


def run(out=print, queries=None) -> dict:
    queries = queries or sorted(QUERIES)
    results: dict[str, dict[str, float]] = {}
    for qname in queries:
        base = time_compiled(CompiledQuery(QUERIES[qname](), db(), preset("opt")))
        results[qname] = {"opt": base}
        out(csv(f"ablation/{qname}/opt", base))
        for name, overrides in ABLATIONS.items():
            settings = dataclasses.replace(preset("opt"), **overrides)
            t = time_compiled(CompiledQuery(QUERIES[qname](), db(), settings))
            results[qname][name] = t
            out(csv(f"ablation/{qname}/{name}", t, f"{t / base:.2f}x"))
    return results
